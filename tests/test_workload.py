"""Workload generation (paper §4.1–4.2, Table 1, Fig. 6)."""
import numpy as np
import pytest

from repro.core.workload import (chatlmsys_like, cumulative_rate_distribution,
                                 piecewise_poisson_trace, power_law_rates,
                                 sharegpt_lengths, synthesize, table1_models)


def test_table1_mix():
    models = table1_models()
    assert len(models) == 19                     # 12 + 4 + 2 + 1
    sizes = [m.param_count() for m in models]
    assert sum(1 for s in sizes if s < 8e9) == 12
    assert sum(1 for s in sizes if s > 41e9) == 1


def test_power_law_skew():
    names = [f"m{i}" for i in range(20)]
    r_low = power_law_rates(names, alpha=0.9, max_rate=20)
    r_high = power_law_rates(names, alpha=2.1, max_rate=20)
    cdf_low = cumulative_rate_distribution(r_low)
    cdf_high = cumulative_rate_distribution(r_high)
    top20 = max(1, len(names) // 5)
    # paper: α=0.9 → top 20% take ~50%; α=2.1 → ~90%
    assert 0.35 <= cdf_low[top20 - 1] <= 0.65
    assert cdf_high[top20 - 1] >= 0.8
    assert cdf_high[top20 - 1] > cdf_low[top20 - 1]


def test_max_rate_respected():
    r = power_law_rates([f"m{i}" for i in range(10)], 1.3, max_rate=20)
    assert np.isclose(max(r.values()), 20)


def test_poisson_arrival_counts():
    wl = synthesize([f"m{i}" for i in range(4)], alpha=1.0, max_rate=8.0,
                    horizon=200.0, seed=0)
    for m, rate in wl.rates.items():
        n = sum(1 for r in wl.requests if r.model == m)
        expect = rate * wl.horizon
        assert abs(n - expect) < 5 * np.sqrt(expect) + 5, (m, n, expect)
    arr = [r.arrival for r in wl.requests]
    assert arr == sorted(arr)


def test_sharegpt_lengths():
    rng = np.random.default_rng(0)
    p, o = sharegpt_lengths(rng, 20000)
    assert 100 <= p.mean() <= 240            # mean prompt ≈ 161
    assert 230 <= o.mean() <= 470            # mean output ≈ 338
    assert p.min() >= 4 and p.max() <= 2048


def test_piecewise_segment_rates():
    """Per-segment arrival counts follow that segment's rates (a
    popularity flip at t=H/2), and the trace's ``rates`` field is the
    time-averaged mix."""
    H = 400.0
    wl = piecewise_poisson_trace(
        [(0.0, {"a": 6.0, "b": 1.0}), (H / 2, {"a": 1.0, "b": 6.0})],
        horizon=H, seed=0)
    assert wl.rates == {"a": 3.5, "b": 3.5}
    for model, pre_rate, post_rate in (("a", 6.0, 1.0), ("b", 1.0, 6.0)):
        pre = sum(1 for r in wl.requests
                  if r.model == model and r.arrival < H / 2)
        post = sum(1 for r in wl.requests
                   if r.model == model and r.arrival >= H / 2)
        for n, rate in ((pre, pre_rate), (post, post_rate)):
            expect = rate * H / 2
            assert abs(n - expect) < 5 * np.sqrt(expect) + 5, \
                (model, n, expect)
    arr = [r.arrival for r in wl.requests]
    assert arr == sorted(arr)
    assert max(arr) < H


def test_piecewise_deterministic():
    seg = [(0.0, {"a": 4.0}), (2.0, {"a": 0.5, "b": 8.0})]
    w1 = piecewise_poisson_trace(seg, horizon=6.0, seed=3)
    w2 = piecewise_poisson_trace(seg, horizon=6.0, seed=3)
    w3 = piecewise_poisson_trace(seg, horizon=6.0, seed=4)
    as_tuples = lambda wl: [(r.model, r.arrival, r.prompt_len, r.output_len)
                            for r in wl.requests]
    assert as_tuples(w1) == as_tuples(w2)
    assert as_tuples(w1) != as_tuples(w3)


def test_piecewise_rejects_bad_segments():
    with pytest.raises(AssertionError):
        piecewise_poisson_trace([(1.0, {"a": 1.0})], horizon=2.0)
    with pytest.raises(AssertionError):
        piecewise_poisson_trace([(0.0, {"a": 1.0}), (3.0, {"a": 2.0})],
                                horizon=2.0)


def test_chatlmsys_like():
    wl = chatlmsys_like(n_models=16, horizon=100.0, avg_rate=2.0, seed=1)
    assert len(wl.rates) == 16
    cdf = cumulative_rate_distribution(wl.rates)
    assert 0.3 <= cdf[2] <= 0.75              # ~20% models ≈ 50% traffic
    assert len(wl.requests) > 0
