"""Serve-step consistency: prefill + incremental decode must equal the
full causal forward, for every architecture family (the correctness
contract of disaggregated prefill/decode — paper §2.1/§3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps
from repro.models.transformer import init_params, forward

from conftest import ALL_ARCHS


def _gen(cfg, params, n_new=4, S_prompt=16, windowed=False, window=None):
    key = jax.random.PRNGKey(0)
    B = 2
    S_cache = S_prompt + n_new
    toks = jax.random.randint(key, (B, S_prompt), 0, cfg.vocab_size)
    lens = jnp.full((B,), S_prompt, jnp.int32)
    prefix = None
    if cfg.frontend_dim:
        prefix = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.float32)
    pre = steps.make_prefill_step(cfg, moe_dropless=True,
                                  window=window)
    out = pre(params, toks, lens) if prefix is None else \
        pre(params, toks, lens, prefix)
    dec = steps.make_decode_step(cfg, windowed=windowed, moe_dropless=True)
    fam = cfg.family
    seq, logits = toks, out["logits"]
    n_pre = 0 if prefix is None else prefix.shape[1]

    if fam in ("dense", "moe", "vlm", "audio"):
        if windowed:
            W = cfg.sliding_window
            wk = jnp.zeros((cfg.n_layers, B, cfg.n_kv_heads, W, cfg.hd),
                           jnp.float32)
            wv = jnp.zeros_like(wk)
            # fill ring buffer from prefill cache
            for p in range(S_prompt + n_pre):
                wk = wk.at[:, :, :, p % W].set(out["cache_k"][:, :, p])
                wv = wv.at[:, :, :, p % W].set(out["cache_v"][:, :, p])
            for t in range(n_new):
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                seq = jnp.concatenate([seq, nxt[:, None]], 1)
                lens2 = jnp.full((B,), n_pre + S_prompt + t + 1, jnp.int32)
                o = dec(params, wk, wv, nxt, lens2)
                logits, wk, wv = o["logits"], o["wkey"], o["wval"]
        else:
            ck = jnp.zeros((cfg.n_layers, B, S_cache + n_pre,
                            cfg.n_kv_heads, cfg.hd), jnp.float32)
            cv = jnp.zeros_like(ck)
            ck = ck.at[:, :, :S_prompt + n_pre].set(out["cache_k"])
            cv = cv.at[:, :, :S_prompt + n_pre].set(out["cache_v"])
            for t in range(n_new):
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                seq = jnp.concatenate([seq, nxt[:, None]], 1)
                lens2 = jnp.full((B,), n_pre + S_prompt + t + 1, jnp.int32)
                o = dec(params, ck, cv, nxt, lens2)
                logits, ck, cv = o["logits"], o["cache_k"], o["cache_v"]
    elif fam == "ssm":
        st, tail = out["ssm_state"], out["conv_tail"]
        for t in range(n_new):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            seq = jnp.concatenate([seq, nxt[:, None]], 1)
            o = dec(params, st, tail, nxt,
                    jnp.full((B,), S_prompt + t + 1, jnp.int32))
            logits, st, tail = o["logits"], o["ssm_state"], o["conv_tail"]
    else:  # hybrid
        st, tail = out["ssm_state"], out["conv_tail"]
        La = cfg.n_layers // cfg.attn_every
        ck = jnp.zeros((La, B, S_cache, cfg.n_kv_heads, cfg.hd),
                       jnp.float32)
        cv = jnp.zeros_like(ck)
        ck = ck.at[:, :, :S_prompt].set(out["cache_k"])
        cv = cv.at[:, :, :S_prompt].set(out["cache_v"])
        for t in range(n_new):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            seq = jnp.concatenate([seq, nxt[:, None]], 1)
            o = dec(params, st, tail, ck, cv, nxt,
                    jnp.full((B,), S_prompt + t + 1, jnp.int32))
            logits, st, tail, ck, cv = (o["logits"], o["ssm_state"],
                                        o["conv_tail"], o["cache_k"],
                                        o["cache_v"])
    return seq, logits, prefix


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    seq, logits, prefix = _gen(cfg, params)
    ref, _ = forward(params, cfg, seq, prefix_emb=prefix, remat=False,
                     moe_dropless=True)
    err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
    assert err < 5e-4, f"{arch}: decode diverges from forward by {err}"
    assert not np.isnan(np.asarray(logits)).any()


def test_windowed_decode_matches_windowed_forward():
    """Ring-buffer sliding-window decode == windowed full attention."""
    cfg = configs.get_reduced("qwen2-7b")
    W = cfg.sliding_window
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    seq, logits, _ = _gen(cfg, params, n_new=4, S_prompt=16,
                          windowed=True, window=W)
    ref, _ = forward(params, cfg, seq, remat=False, window=W)
    err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
    assert err < 5e-4, err


def test_windowed_decode_evicts():
    """With prompt longer than the window, the ring buffer must hold
    only the last W positions (== windowed forward, != full forward)."""
    cfg = configs.get_reduced("qwen2-7b")
    W = cfg.sliding_window  # 64
    params = init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    seq, logits, _ = _gen(cfg, params, n_new=3, S_prompt=W + 16,
                          windowed=True, window=W)
    ref_w, _ = forward(params, cfg, seq, remat=False, window=W)
    err = float(jnp.max(jnp.abs(logits - ref_w[:, -1])))
    assert err < 5e-4, err


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_respects_lens(arch):
    """Padded positions beyond lens must not change the last-token
    logits (continuous batching mixes lengths in one prefill)."""
    cfg = configs.get_reduced(arch)
    if cfg.frontend_dim:
        pytest.skip("prefix archs append embeddings; lens semantics "
                    "covered by dense/audio variants without prefix")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    key = jax.random.PRNGKey(2)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lens = jnp.array([16, 16], jnp.int32)
    pre = steps.make_prefill_step(cfg, moe_dropless=True)
    o1 = pre(params, toks, lens)
    toks2 = toks.at[:, 16:].set(1)          # scribble past lens
    o2 = pre(params, toks2, lens)
    if cfg.family in ("ssm", "hybrid"):
        err = float(jnp.max(jnp.abs(o1["logits"] - o2["logits"])))
        assert err < 5e-4, err
    else:
        # attention archs: lens picks the logit position; KV past lens
        # is masked at decode time instead (engine contract)
        err = float(jnp.max(jnp.abs(o1["logits"] - o2["logits"])))
        assert err < 5e-4, err
