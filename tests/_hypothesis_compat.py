"""Hypothesis pass-through with graceful degradation.

CI installs the real hypothesis via pyproject's ``[test]`` extra.  In
environments without it, property tests decorated with ``@given`` are
skipped *individually* — the plain unit tests in the same module still
collect and run (a bare ``from hypothesis import ...`` would fail the
whole module at collection instead).
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False

    class _Strategies:
        """Accepts any strategy expression at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[test])")
