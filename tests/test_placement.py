"""Placement (Alg. 1 + Alg. 2) and throughput estimator (Eq. 3)."""
import math


from repro.core import costmodel as cm
from repro.core.costmodel import A100, TPU_V5E
from repro.core.estimator import (LLMSpec, request_throughput, solve_batch,
                                  token_block_usage, unit_throughput)
from repro.core.placement import (mesh_groups, parallel_candidates, place,
                                  place_memory_greedy, place_spatial)
from repro.core.workload import llama_config


# ---------------------------------------------------------------------------
# cost model (Fig. 3 reproduction properties)
# ---------------------------------------------------------------------------
def test_decode_latency_flat_in_f():
    """Decode is memory-bound: halving compute fraction changes latency
    far less than prefill (paper Fig. 3)."""
    cfg = llama_config("llama-7b")
    d_full = cm.decode_latency(cfg, 16, 400, f=1.0)
    d_half = cm.decode_latency(cfg, 16, 400, f=0.5)
    p_full = cm.prefill_latency(cfg, 1, 512, f=1.0)
    p_half = cm.prefill_latency(cfg, 1, 512, f=0.5)
    decode_blowup = d_half / d_full
    prefill_blowup = p_half / p_full
    assert decode_blowup < 1.2, "decode should be ~flat in f"
    assert prefill_blowup > 1.8, "prefill should scale ~1/f"


def test_tp_reduces_prefill_latency():
    cfg = llama_config("llama-30b")
    t1 = cm.prefill_latency(cfg, 1, 1024, tp=1)
    t4 = cm.prefill_latency(cfg, 1, 1024, tp=4)
    assert t4 < t1


def test_weight_devices_needed():
    big = llama_config("llama-65b")
    assert cm.weight_devices_needed(big, A100) >= 3
    small = llama_config("llama-7b")
    assert cm.weight_devices_needed(small, A100) == 1
    # v5e has 16GB → 7B bf16 needs 2
    assert cm.weight_devices_needed(small, TPU_V5E) >= 2


# ---------------------------------------------------------------------------
# estimator (Eq. 3)
# ---------------------------------------------------------------------------
def _spec(name="llama-7b", rate=4.0, **kw):
    return LLMSpec(llama_config(name), rate, **kw)


def test_throughput_capped_by_rate():
    s = _spec(rate=0.5)
    t = request_throughput(s, 64, [s])
    assert t <= 0.5 + 1e-9


def test_throughput_monotone_in_batch():
    s = _spec(rate=1e9)  # uncapped
    ts = [request_throughput(s, b, [s]) for b in (1, 4, 16, 64)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_solve_batch_meets_rate():
    s = _spec(rate=2.0)
    b, t = solve_batch(s, [s])
    assert t >= 2.0 - 1e-9
    if b > 1:
        assert request_throughput(s, b - 1, [s]) < 2.0


def test_colocation_lowers_single_llm_throughput():
    """Eq. 3: other LLMs' prefills serialize into the denominator."""
    a = _spec(rate=1e9)
    b = LLMSpec(llama_config("llama-13b"), 1e9)
    alone = request_throughput(a, 32, [a])
    shared = request_throughput(a, 32, [a, b])
    assert shared < alone


def test_token_block_usage_normalized_by_rate():
    lo = _spec(rate=1.0)
    hi = _spec(rate=10.0)
    assert token_block_usage(lo, 16) > token_block_usage(hi, 16)


def test_unit_throughput_memory_infeasible():
    specs = [LLMSpec(llama_config("llama-65b", tag=f"-{i}"), 1.0)
             for i in range(8)]
    assert unit_throughput(specs, 1, A100) == float("-inf")


# ---------------------------------------------------------------------------
# Alg. 2 candidates
# ---------------------------------------------------------------------------
def test_parallel_candidates_minimal_sm():
    cfg = llama_config("llama-7b")
    cands = parallel_candidates(cfg, rate=1.0, max_tp=8)
    assert cands, "must produce candidates"
    for c in cands:
        assert c.tp in (1, 2, 4, 8)
        # Alg. 2: smallest fraction that meets the rate → lowering it
        # one notch must miss the rate (when f > 0.1 met the rate)
    tps = [c.tp for c in cands]
    assert len(set(tps)) == len(tps), "one candidate per TP degree"


def test_candidates_fraction_decreases_with_tp():
    """More TP → each device needs a smaller compute fraction."""
    cfg = llama_config("llama-13b")
    cands = parallel_candidates(cfg, rate=2.0, max_tp=8)
    by_tp = {c.tp: c.sm_frac for c in cands}
    if 1 in by_tp and 8 in by_tp:
        assert by_tp[8] <= by_tp[1]


# ---------------------------------------------------------------------------
# mesh-group enumeration
# ---------------------------------------------------------------------------
def test_mesh_groups_partition():
    groups = mesh_groups(16, node_size=8)
    assert groups
    for g in groups:
        assert sum(g) == 16
        assert all(s in (1, 2, 4, 8) for s in g)


# ---------------------------------------------------------------------------
# Alg. 1 end-to-end placement
# ---------------------------------------------------------------------------
def _skewed_models(n_small=3, rate_hot=12.0, rate_cold=0.4):
    ms = [(llama_config("llama-7b", f"-{i}"),
           rate_hot if i == 0 else rate_cold) for i in range(n_small)]
    ms.append((llama_config("llama-30b", "-x"), rate_cold))
    return ms


def test_place_covers_all_models():
    models = _skewed_models()
    pl = place(models, n_devices=8, group_limit=64)
    placed = [s.name for m in pl.meshes for s in m.specs]
    assert sorted(placed) == sorted(cfg.name for cfg, _ in models)
    assert sum(m.n_devices for m in pl.meshes) == 8
    assert math.isfinite(pl.total_tpt) and pl.total_tpt > 0


def test_place_beats_memory_greedy_on_skewed():
    """Fig. 8: computation-first placement ≥ memory-greedy."""
    models = _skewed_models()
    a = place(models, n_devices=8, group_limit=64).total_tpt
    b = place_memory_greedy(models, n_devices=8).total_tpt
    assert a >= b * 0.999, (a, b)


def test_place_beats_spatial_on_skewed():
    """Colocation must not lose to dedicated GPUs under skew."""
    models = _skewed_models()
    a = place(models, n_devices=8, group_limit=64).total_tpt
    c = place_spatial(models, n_devices=8).total_tpt
    assert a >= c * 0.95, (a, c)


def test_spatial_gives_every_model_own_mesh():
    models = _skewed_models()
    pl = place_spatial(models, n_devices=16)
    assert len(pl.meshes) == len(models)
    for m in pl.meshes:
        assert len(m.specs) == 1
