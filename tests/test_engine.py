"""Engine + MuxScheduler: the CPU-scale runtime over the unified pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import forward, init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler


def _engine(arch, quota=100_000, n_blocks=200_000, max_slots=4, seed=0):
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    pool = UnifiedKVPool(n_blocks, cfg.hd if cfg.hd else 64,
                         dtype=jnp.float32)
    view = pool.register_model(cfg, quota)
    return Engine(cfg, params, view, max_slots=max_slots), pool, cfg, params


@pytest.mark.parametrize("arch", ["qwen2-7b", "zamba2-1.2b", "mamba2-2.7b"])
def test_engine_generates_greedy_match(arch):
    """Engine prefill+decode (paged pool) == full-forward greedy."""
    eng, pool, cfg, params = _engine(arch)
    prompt = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 12))
    req = Request(req_id=0, model=cfg.name, prompt=prompt, max_new_tokens=5)
    assert eng.prefill([req]) > 0
    while not req.done:
        eng.decode()
    # reference greedy generation by full recompute
    seq = list(prompt)
    for _ in range(5):
        logits, _ = forward(params, cfg, jnp.asarray([seq]), remat=False,
                            moe_dropless=True)
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert req.output == seq[len(prompt):], (req.output, seq[len(prompt):])


def test_engine_batched_consistency():
    """Two requests served together == each served alone (isolation)."""
    eng, pool, cfg, params = _engine("qwen2-7b")
    rng = np.random.default_rng(1)
    p1 = list(rng.integers(1, cfg.vocab_size, 9))
    p2 = list(rng.integers(1, cfg.vocab_size, 14))
    r1 = Request(0, cfg.name, p1, 4)
    r2 = Request(1, cfg.name, p2, 4)
    eng.prefill([r1, r2])
    while not (r1.done and r2.done):
        eng.decode()

    eng2, _, _, _ = _engine("qwen2-7b")
    a1 = Request(0, cfg.name, p1, 4)
    eng2.prefill([a1])
    while not a1.done:
        eng2.decode()
    assert r1.output == a1.output


def test_engine_slot_reuse():
    eng, pool, cfg, _ = _engine("qwen2-7b", max_slots=2)
    rng = np.random.default_rng(2)
    reqs = [Request(i, cfg.name, list(rng.integers(1, cfg.vocab_size, 6)), 2)
            for i in range(5)]
    served = 0
    pending = list(reqs)
    for _ in range(50):
        if pending:
            eng.prefill(pending[:len(eng.free_slots())])
        eng.decode()
        pending = [r for r in pending if not r.output]
        served = sum(1 for r in reqs if r.done)
        if served == 5:
            break
    assert served == 5
    assert pool.allocator.used == 0, "all cache freed after completion"


def test_mux_scheduler_two_llms():
    """Two colocated reduced LLMs share the pool under ADBS and both
    finish; outputs match single-LLM serving."""
    cfg_a = configs.get_reduced("qwen2-7b")
    cfg_b = configs.get_reduced("musicgen-medium")
    pool = UnifiedKVPool(200_000, 64, dtype=jnp.float32)
    pa = init_params(jax.random.PRNGKey(0), cfg_a, jnp.float32)
    pb = init_params(jax.random.PRNGKey(1), cfg_b, jnp.float32)
    va = pool.register_model(cfg_a, 100_000)
    vb = pool.register_model(cfg_b, 100_000)
    engines = {cfg_a.name: Engine(cfg_a, pa, va, max_slots=2),
               cfg_b.name: Engine(cfg_b, pb, vb, max_slots=2)}
    mux = MuxScheduler(engines, pool, policy="adbs")
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(3):
        reqs.append(Request(i, cfg_a.name,
                            list(rng.integers(1, cfg_a.vocab_size, 8)), 3))
        reqs.append(Request(10 + i, cfg_b.name,
                            list(rng.integers(1, cfg_b.vocab_size, 8)), 3))
    for r in reqs:
        mux.submit(r)
    stats = mux.run(max_ticks=200)
    assert len(stats.finished) == 6
    assert stats.prefill_tokens > 0 and stats.decode_tokens > 0
    assert pool.allocator.used == 0

    # isolation: serving alone gives the same tokens
    solo_pool = UnifiedKVPool(200_000, 64, dtype=jnp.float32)
    sv = solo_pool.register_model(cfg_a, 100_000)
    solo = Engine(cfg_a, pa, sv, max_slots=2)
    q = Request(0, cfg_a.name, reqs[0].prompt, 3)
    solo.prefill([q])
    while not q.done:
        solo.decode()
    muxed = next(r for r in stats.finished
                 if r.model == cfg_a.name and r.prompt == reqs[0].prompt)
    assert muxed.output == q.output


def test_batch_admission_accounts_for_pending():
    """A single prefill batch must not overcommit the quota: each
    candidate is checked against headroom minus the lifetime blocks of
    requests already selected for the batch."""
    cfg = configs.get_reduced("qwen2-7b")
    # group_size = 4 head-blocks per 16-token block; quota 12 = 3
    # groups, but each 22-token lifetime needs 2 → only one fits.
    pool = UnifiedKVPool(1000, cfg.hd, dtype=jnp.float32)
    view = pool.register_model(cfg, 12)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = Engine(cfg, params, view, max_slots=2)
    rng = np.random.default_rng(0)
    r1 = Request(0, cfg.name, list(rng.integers(1, 512, 20)), 8)
    r2 = Request(1, cfg.name, list(rng.integers(1, 512, 20)), 8)
    eng.prefill([r1, r2])                    # must not crash or corrupt
    assert len(eng.active_slots()) == 1      # second request deferred


def test_decode_quota_overcommit_rolls_back():
    """Admitted sequences' future growth is not reserved, so requests
    admitted in separate batches can overcommit a small quota; decode
    must stall-and-retry the loser (rolling back the unreservable
    token) rather than corrupt its KV."""
    cfg = configs.get_reduced("qwen2-7b")
    # quota 12 = 3 groups; each request's lifetime is 2 groups, but at
    # admission time each sees enough headroom (growth unreserved).
    pool = UnifiedKVPool(1000, cfg.hd, dtype=jnp.float32)
    view = pool.register_model(cfg, 12)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = Engine(cfg, params, view, max_slots=2)
    rng = np.random.default_rng(0)
    r1 = Request(0, cfg.name, list(rng.integers(1, 512, 14)), 8)
    r2 = Request(1, cfg.name, list(rng.integers(1, 512, 14)), 8)
    eng.prefill([r1])
    eng.prefill([r2])
    assert len(eng.active_slots()) == 2      # both admitted (overcommit)
    for _ in range(60):
        eng.decode()
        if r1.done and r2.done:
            break
    assert r1.done and r2.done
    assert not eng.preempted                 # r1 kept progressing
    assert pool.allocator.used == 0
    # no corruption: the stalled request's tokens match uncontended runs
    pool2 = UnifiedKVPool(1000, cfg.hd, dtype=jnp.float32)
    eng2 = Engine(cfg, params, pool2.register_model(cfg, 1000),
                  max_slots=2)
    for r in (r1, r2):
        q = Request(9, cfg.name, list(r.prompt), 8)
        eng2.prefill([q])
        while not q.done:
            eng2.decode()
        assert r.output == q.output


def test_decode_overcommit_hybrid_state_revert():
    """Hybrid (SSM + shared attention) under quota overcommit: a
    rolled-back decode step must also revert the SSM carry, or the
    retry re-advances the state and commits a different token than an
    uncontended run."""
    cfg = configs.get_reduced("zamba2-1.2b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(4)
    p1 = list(rng.integers(1, cfg.vocab_size, 14))
    p2 = list(rng.integers(1, cfg.vocab_size, 14))
    max_new = 24
    # probe the quota analytically: admit r1, leave exactly one more
    # lifetime of headroom so r2 admits but their growth overcommits
    probe_pool = UnifiedKVPool(50_000, cfg.hd, dtype=jnp.float32)
    probe = Engine(cfg, params,
                   probe_pool.register_model(cfg, 50_000), max_slots=2)
    pr = Request(0, cfg.name, list(p1), max_new)
    lifetime = probe.lifetime_blocks(pr)
    probe.prefill([pr])
    used_p = probe_pool.views[cfg.name].used
    assert lifetime > used_p, "need unreserved growth for overcommit"
    quota = used_p + lifetime

    pool = UnifiedKVPool(50_000, cfg.hd, dtype=jnp.float32)
    eng = Engine(cfg, params, pool.register_model(cfg, quota),
                 max_slots=2)
    mux = MuxScheduler({cfg.name: eng}, pool, policy="adbs")
    r1 = Request(0, cfg.name, list(p1), max_new)
    r2 = Request(1, cfg.name, list(p2), max_new)
    mux.submit(r1)
    mux.submit(r2)
    stats = mux.run(max_ticks=600)
    assert len(stats.finished) == 2
    assert pool.allocator.used == 0
    # outputs must match uncontended serving despite rollback/preempt
    pool2 = UnifiedKVPool(50_000, cfg.hd, dtype=jnp.float32)
    eng2 = Engine(cfg, params, pool2.register_model(cfg, 50_000),
                  max_slots=2)
    for r in (r1, r2):
        q = Request(9, cfg.name, list(r.prompt), max_new)
        eng2.prefill([q])
        while not q.done:
            eng2.decode()
        assert r.output == q.output, r.req_id


def test_quota_regrant_for_oversized_head_request():
    """A request whose lifetime exceeds its LLM's (shrunken) quota
    must not re-queue forever: the scheduler pulls spare quota back
    from other views before admission."""
    cfg_a = configs.get_reduced("qwen2-7b")
    cfg_b = configs.get_reduced("qwen3-14b")
    pool = UnifiedKVPool(100_000, 64, dtype=jnp.float32)
    pa = init_params(jax.random.PRNGKey(0), cfg_a, jnp.float32)
    pb = init_params(jax.random.PRNGKey(1), cfg_b, jnp.float32)
    va = pool.register_model(cfg_a, 4)           # as if adapt shrank it
    vb = pool.register_model(cfg_b, 50_000)
    engines = {cfg_a.name: Engine(cfg_a, pa, va, max_slots=2),
               cfg_b.name: Engine(cfg_b, pb, vb, max_slots=2)}
    mux = MuxScheduler(engines, pool, policy="adbs")
    rng = np.random.default_rng(6)
    r = Request(0, cfg_a.name, list(rng.integers(1, 512, 14)), 8)
    assert engines[cfg_a.name].lifetime_blocks(r) > va.quota
    mux.submit(r)
    stats = mux.run(max_ticks=100)
    assert len(stats.finished) == 1 and r.done
    assert va.quota >= engines[cfg_a.name].lifetime_blocks(r)
    assert pool.allocator.used == 0


def test_stall_escape_preemption_unblocks_deadlock():
    """Cross-batch growth overcommit can stall every active sequence
    at once (admission reserves nothing beyond the prompt); the stall
    escape must preempt one sequence so the rest finish, and the
    scheduler must restart the evicted request to completion."""
    cfg = configs.get_reduced("qwen2-7b")
    # quota 12 = 3 groups.  A (lifetime 3 groups) admitted first and
    # B (lifetime 2 groups, fits 12-4=8 headroom) in a later batch:
    # once A holds 2 groups and B holds 2, headroom is 0 with both
    # mid-lifetime → every decode tick rolls back.
    pool = UnifiedKVPool(1000, cfg.hd, dtype=jnp.float32)
    view = pool.register_model(cfg, 12)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = Engine(cfg, params, view, max_slots=2)
    mux = MuxScheduler({cfg.name: eng}, pool, policy="adbs")
    rng = np.random.default_rng(1)
    ra = Request(0, cfg.name, list(rng.integers(1, 512, 14)), 28)
    rb = Request(1, cfg.name, list(rng.integers(1, 512, 14)), 8)
    mux.submit(ra)
    mux.submit(rb)
    stats = mux.run(max_ticks=400)
    assert len(stats.finished) == 2, [r.req_id for r in stats.finished]
    assert len(ra.output) == 28 and len(rb.output) == 8
    assert pool.allocator.used == 0
    # the preempted request's restart must be output-identical
    pool2 = UnifiedKVPool(1000, cfg.hd, dtype=jnp.float32)
    eng2 = Engine(cfg, params, pool2.register_model(cfg, 1000),
                  max_slots=2)
    for r in (ra, rb):
        q = Request(9, cfg.name, list(r.prompt), r.max_new_tokens)
        eng2.prefill([q])
        while not q.done:
            eng2.decode()
        assert r.output == q.output


@pytest.mark.parametrize("policy", ["adbs", "fcfs", "round_robin"])
def test_mux_policies_drain(policy):
    cfg = configs.get_reduced("qwen3-14b")
    pool = UnifiedKVPool(100_000, cfg.hd, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    view = pool.register_model(cfg, 100_000)
    mux = MuxScheduler({cfg.name: Engine(cfg, params, view, max_slots=2)},
                       pool, policy=policy)
    rng = np.random.default_rng(0)
    for i in range(3):
        mux.submit(Request(i, cfg.name,
                           list(rng.integers(1, cfg.vocab_size, 5)), 2))
    stats = mux.run(max_ticks=100)
    assert len(stats.finished) == 3
