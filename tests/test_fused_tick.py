"""Fused multi-LLM decode tick (DESIGN.md §2): parity with the serial
tick, pool block-table state equivalence, and heterogeneous fallback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import replace
from repro.models.transformer import init_params
from repro.serving import cache_ops
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool, fused_block_tables
from repro.serving.mux import MuxScheduler


def _colocated(archs, fused, max_slots=2, quota=30_000, n_blocks=100_000):
    """Build a unit of colocated reduced engines (repeated archs get
    distinct weights + names) and a MuxScheduler over them."""
    pool = UnifiedKVPool(n_blocks, 64, dtype=jnp.float32)
    engines = {}
    for i, a in enumerate(archs):
        cfg = replace(configs.get_reduced(a), name=f"m{i}")
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, quota)
        engines[cfg.name] = Engine(cfg, params, view, max_slots=max_slots)
    return MuxScheduler(engines, pool, policy="adbs", fused=fused), pool


def _submit(mux, n_reqs, max_new=4, seed=7):
    rng = np.random.default_rng(seed)
    names = list(mux.engines)
    reqs = []
    for i in range(n_reqs):
        name = names[i % len(names)]
        vocab = mux.engines[name].cfg.vocab_size
        r = Request(i, name, list(rng.integers(1, vocab, 6 + i % 5)), max_new)
        reqs.append(r)
        mux.submit(r)
    return reqs


def _pool_state(mux):
    """Canonical host-side cache-state snapshot: per-model per-seq
    token counts and block counts, per-view usage accounting, and the
    arena's used-block total.  Physical base ids are deliberately NOT
    compared — allocation ORDER is scheduler-path-dependent (serial
    ticks allocate in rotated engine order, the fused sweep in group
    order), so bases may differ while the logical state is identical.
    Quotas are NOT compared either: the fused scheduler grants the
    head-blocks reclaimed by weight de-duplication to the group's
    views (DESIGN.md §2), so fused quotas are larger by design.
    """
    state = {}
    for name, eng in mux.engines.items():
        state[name] = ({sid: (len(sc.bases), sc.n_tokens)
                        for sid, sc in eng.view.seqs.items()},
                       eng.view.used)
    state["__used__"] = mux.pool.allocator.used
    return state


@pytest.mark.parametrize("n_models", [2, 3])
def test_fused_parity_with_serial(n_models):
    """Fused decode == serial decode: identical tokens AND identical
    canonical pool state at every tick, for colocated same-arch
    engines with distinct weights.  max_new crosses a 16-token block
    boundary mid-decode so decode-time allocation is exercised, not
    just prefill-time."""
    archs = ["qwen2-7b"] * n_models
    mux_s, pool_s = _colocated(archs, fused=False)
    mux_f, pool_f = _colocated(archs, fused=True)
    assert len(mux_f.fused_groups) == 1
    assert len(mux_f.fused_groups[0].engines) == n_models
    assert mux_f._serial_names == []

    _submit(mux_s, 2 * n_models, max_new=20)
    reqs_f = _submit(mux_f, 2 * n_models, max_new=20)

    for _ in range(400):
        if not (mux_s.pending() or mux_f.pending()):
            break
        mux_s.tick()
        mux_f.tick()
        assert _pool_state(mux_s) == _pool_state(mux_f)

    assert len(mux_s.stats.finished) == len(mux_f.stats.finished) \
        == 2 * n_models
    outs_s = {r.req_id: r.output for r in mux_s.stats.finished}
    for r in reqs_f:
        assert r.output == outs_s[r.req_id], r.req_id
    assert pool_s.allocator.used == 0 and pool_f.allocator.used == 0
    assert mux_s.stats.decode_tokens == mux_f.stats.decode_tokens


def test_fused_heterogeneous_fallback():
    """Transformer + mamba2 colocation: no fusable pair exists, the
    fused scheduler serves both on the serial path, and results match
    the serial scheduler exactly."""
    archs = ["qwen2-7b", "mamba2-2.7b"]
    mux_s, _ = _colocated(archs, fused=False)
    mux_f, pool_f = _colocated(archs, fused=True)
    assert mux_f.fused_groups == []          # SSM is fusion-ineligible
    assert set(mux_f._serial_names) == set(mux_f.engines)

    _submit(mux_s, 6)
    reqs_f = _submit(mux_f, 6)
    mux_s.run(max_ticks=200)
    mux_f.run(max_ticks=200)

    assert len(mux_f.stats.finished) == 6
    outs_s = {r.req_id: r.output for r in mux_s.stats.finished}
    for r in reqs_f:
        assert r.output == outs_s[r.req_id]
    assert pool_f.allocator.used == 0


def test_fused_mixed_group_and_fallback():
    """Two fusable same-arch engines + one SSM engine in one unit: the
    pair fuses, the SSM engine decodes serially, everything drains."""
    archs = ["qwen2-7b", "qwen2-7b", "mamba2-2.7b"]
    mux_f, pool_f = _colocated(archs, fused=True)
    assert len(mux_f.fused_groups) == 1
    assert len(mux_f.fused_groups[0].engines) == 2
    assert mux_f._serial_names == ["m2"]

    mux_s, _ = _colocated(archs, fused=False)
    _submit(mux_s, 6)
    reqs_f = _submit(mux_f, 6)
    mux_s.run(max_ticks=200)
    mux_f.run(max_ticks=200)
    assert len(mux_f.stats.finished) == 6
    outs_s = {r.req_id: r.output for r in mux_s.stats.finished}
    for r in reqs_f:
        assert r.output == outs_s[r.req_id]
    assert pool_f.allocator.used == 0


def test_fusion_signature_eligibility():
    cfg_t = configs.get_reduced("qwen2-7b")
    cfg_s = configs.get_reduced("mamba2-2.7b")
    pool = UnifiedKVPool(50_000, 64, dtype=jnp.float32)
    pt = init_params(jax.random.PRNGKey(0), cfg_t, jnp.float32)
    ps = init_params(jax.random.PRNGKey(1), cfg_s, jnp.float32)
    et = Engine(cfg_t, pt, pool.register_model(cfg_t, 10_000))
    es = Engine(cfg_s, ps, pool.register_model(cfg_s, 10_000))
    assert et.fusion_signature() is not None
    assert es.fusion_signature() is None     # SSM keeps its own scan
    # a different block-table width must not fuse (padding mismatch)
    cfg_t2 = replace(cfg_t, name="t2")
    et2 = Engine(cfg_t2, pt, pool.register_model(cfg_t2, 10_000),
                 max_blocks_per_seq=32)
    assert et2.fusion_signature() != et.fusion_signature()


def test_fused_block_tables_assembly():
    """Combined block-table padding: −1 tables / len-1 rows for padded
    entries, real rows resolved through each model's own view."""
    cfg = replace(configs.get_reduced("qwen2-7b"), name="a")
    cfg2 = replace(configs.get_reduced("qwen2-7b"), name="b")
    pool = UnifiedKVPool(50_000, 64, dtype=jnp.float32)
    va = pool.register_model(cfg, 20_000)
    vb = pool.register_model(cfg2, 20_000)
    assert va.append_tokens(0, 20)           # 2 token-blocks
    assert vb.append_tokens(0, 5)            # 1 token-block
    tables, lens = fused_block_tables([(va, [0]), (vb, [0])],
                                      rows=2, max_blocks=4)
    assert tables.shape == (2, 2, 4) and lens.shape == (2, 2)
    np.testing.assert_array_equal(tables[0, 0],
                                  va.block_table([0], 4)[0])
    np.testing.assert_array_equal(tables[1, 0],
                                  vb.block_table([0], 4)[0])
    assert (tables[0, 1] == -1).all() and (tables[1, 1] == -1).all()
    np.testing.assert_array_equal(lens[:, 0], [20, 5])
    np.testing.assert_array_equal(lens[:, 1], [1, 1])


def test_fused_kernel_matches_oracle():
    """Pallas fused_paged_decode_attention (interpret mode) == XLA
    oracle on a cross-model row batch with pre-resolved phys ids."""
    from repro.kernels.paged_attention import fused_paged_decode_attention
    key = jax.random.PRNGKey(3)
    bt, nb, kv, h, hd = 16, 4, 2, 4, 64
    pool_k = jax.random.normal(key, (256, bt, hd), jnp.float32)
    pool_v = jax.random.normal(jax.random.PRNGKey(4), (256, bt, hd),
                               jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(5), (4, h, hd), jnp.float32)
    # rows from two "models": different layer offsets in the same arena
    t0 = np.array([[0, 8, -1, -1], [16, 24, 32, -1]], np.int32)
    t1 = np.array([[40, 48, -1, -1], [56, 64, 72, 80]], np.int32)
    phys = jnp.concatenate([
        cache_ops.resolve_physical_blocks(jnp.asarray(t0), 0, kv),
        cache_ops.resolve_physical_blocks(jnp.asarray(t1), 1, kv)])
    lens = jnp.asarray(np.array([20, 40, 30, 64], np.int32))
    oracle = cache_ops.fused_paged_decode_attention(
        q, pool_k, pool_v, phys, lens)
    out = fused_paged_decode_attention(q, pool_k, pool_v, phys, lens,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
