"""Unified KV pool + block allocator: unit + property tests."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.config import BLOCK_TOKENS
from repro.serving.kvcache import BlockAllocator, UnifiedKVPool


# ---------------------------------------------------------------------------
# allocator properties (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 64)),
                min_size=1, max_size=80))
def test_allocator_invariants(ops):
    """Random alloc/free interleavings keep the free-space accounting
    exact and ranges disjoint."""
    alloc = BlockAllocator(1024)
    live = []  # (start, n)
    for is_alloc, n in ops:
        if is_alloc:
            s = alloc.alloc(n)
            if s is not None:
                assert 0 <= s and s + n <= 1024
                for (s2, n2) in live:
                    assert s + n <= s2 or s2 + n2 <= s, "overlap!"
                live.append((s, n))
        elif live:
            s, n = live.pop(np.random.default_rng(n).integers(0, len(live)))
            alloc.free(s, n)
        assert alloc.used == sum(n for _, n in live)
        assert alloc.free_blocks == 1024 - alloc.used
    # free everything → one coalesced range
    for s, n in live:
        alloc.free(s, n)
    assert alloc.free_blocks == 1024
    assert alloc.largest_free_range() == 1024
    assert alloc.fragmentation() == 0.0


def test_allocator_exhaustion():
    a = BlockAllocator(10)
    assert a.alloc(8) == 0
    assert a.alloc(4) is None          # doesn't fit
    assert a.alloc(2) == 8
    assert a.alloc(1) is None
    a.free(0, 8)
    assert a.alloc(8) == 0


def test_allocator_shrink_exact_inverse_of_grow_when_idle():
    a = BlockAllocator(128)
    a.grow(64)
    assert a.n_blocks == 192 and a.free_blocks == 192
    assert a.shrink(64) == 64
    assert a.n_blocks == 128 and a.free_blocks == 128
    assert a.largest_free_range() == 128
    # idle arena shrinks all the way to zero if asked
    assert a.shrink(1_000) == 128
    assert a.n_blocks == 0 and a.free_blocks == 0


def test_allocator_shrink_refuses_in_use_tail():
    a = BlockAllocator(64)
    s = a.alloc(64)
    assert a.shrink(16) == 0, "a fully-used arena must not shrink"
    assert a.n_blocks == 64
    a.free(s, 64)
    # now only the free tail is reclaimable past a live head range
    s = a.alloc(16)                     # occupies [0, 16)
    assert a.shrink(64) == 48, "clamp to the free tail"
    assert a.n_blocks == 16 and a.free_blocks == 0
    a.free(s, 16)
    assert a.free_blocks == 16


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 256), st.integers(0, 256), st.integers(0, 512))
def test_allocator_grow_shrink_roundtrip(base, grown, live):
    """grow(n) then shrink(n) restores the arena exactly whenever the
    grown tail stayed idle, regardless of interior allocations."""
    a = BlockAllocator(base)
    s = a.alloc(min(live, base)) if live and min(live, base) > 0 else None
    used = a.used
    a.grow(grown)
    assert a.free_blocks == base - used + grown
    assert a.shrink(grown) == grown
    assert a.n_blocks == base and a.used == used
    if s is not None:
        a.free(s, min(live, base))
    assert a.free_blocks == base


def test_pool_shrink_inverse_of_grow():
    pool = _pool(1024)
    k0, v0 = pool.k.shape, pool.v.shape
    assert pool.grow(512) == 512
    assert pool.k.shape[0] == 1536
    assert pool.shrink(512) == 512
    assert pool.n_head_blocks == 1024
    assert pool.k.shape == k0 and pool.v.shape == v0
    assert pool.allocator.free_blocks == 1024


def test_pool_shrink_clamped_by_live_blocks():
    pool = _pool(256)
    cfg = configs.get_reduced("qwen2-7b")
    view = pool.register_model(cfg, quota=256)
    assert view.append_tokens(0, BLOCK_TOKENS)   # head of the arena live
    pool.grow(64)
    removed = pool.shrink(1_000)
    assert removed == 256 + 64 - view.used, \
        "shrink stops at the in-use head range"
    assert pool.n_head_blocks == view.used
    assert pool.k.shape[0] == view.used
    view.free_seq(0)
    assert pool.allocator.used == 0


# ---------------------------------------------------------------------------
# pool + per-model views
# ---------------------------------------------------------------------------
def _pool(n_blocks=4096, hd=64):
    return UnifiedKVPool(n_blocks, hd)


def test_view_quota_enforced():
    pool = _pool()
    cfg = configs.get_reduced("qwen2-7b")
    group = cfg.n_layers * cfg.n_kv_heads
    view = pool.register_model(cfg, quota=group * 4)  # 4 token-blocks
    assert view.append_tokens(0, BLOCK_TOKENS * 4)     # exactly quota
    assert view.used == group * 4
    assert not view.append_tokens(0, 1), "over quota must fail"
    view.free_seq(0)
    assert view.used == 0
    assert pool.allocator.used == 0


def test_register_model_rejects_mismatched_head_dim():
    """Regression: the head-dim guard was a tautology (`... or True`)
    until PR 10, silently admitting views whose pages could never fit
    the arena rows.  A mismatched attention model must be rejected;
    attention-free models carry no KV pages and register anywhere."""
    from repro.config import replace
    pool = _pool(hd=64)
    cfg = configs.get_reduced("qwen2-7b")
    bad = replace(cfg, name="bad-hd", head_dim=48)
    with pytest.raises(AssertionError, match="head_dim"):
        pool.register_model(bad, quota=256)
    assert "bad-hd" not in pool.views
    # matching head_dim and attention-free both still register
    pool.register_model(cfg, quota=256)
    ssm = configs.get_reduced("mamba2-2.7b")
    assert ssm.attn_free
    view = pool.register_model(ssm, quota=256)
    assert view.group_size == 0


def test_two_models_share_pool():
    """Two different reduced models allocate from one arena."""
    pool = _pool()
    a = configs.get_reduced("qwen2-7b")
    b = configs.get_reduced("musicgen-medium")
    va = pool.register_model(a, quota=2048)
    vb = pool.register_model(b, quota=2048)
    assert va.append_tokens(0, 40)
    assert vb.append_tokens(0, 40)
    assert pool.allocator.used == va.used + vb.used
    va.free_seq(0)
    vb.free_seq(0)
    assert pool.allocator.used == 0


def test_quota_adaptation_moves_to_hot_model():
    pool = _pool(8192)
    a = configs.get_reduced("qwen2-7b")
    b = configs.get_reduced("deepseek-coder-33b")
    va = pool.register_model(a, quota=256)
    vb = pool.register_model(b, quota=256)
    # b is busy (>20% of quota), a idle
    for i in range(6):
        assert vb.append_tokens(i, 64)
    q_a, q_b = va.quota, vb.quota
    pool.adapt_quotas()
    assert vb.quota > q_b and va.quota < q_a, \
        "quota must flow from idle to busy LLM (Alg. 3)"


def test_ssm_state_accounted():
    pool = _pool()
    m = configs.get_reduced("mamba2-2.7b")
    v = pool.register_model(m, quota=1024)
    assert v.group_size == 0                     # no attention blocks
    assert v._ssm_blocks_per_seq > 0
    assert v.append_tokens(0, 100)
    assert v.used == v._ssm_blocks_per_seq      # O(1) in tokens
    v.append_tokens(0, 400)
    assert v.used == v._ssm_blocks_per_seq
    v.free_seq(0)
    assert v.used == 0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 200), min_size=1, max_size=12))
def test_block_table_roundtrip(lens):
    pool = _pool(65536)
    cfg = configs.get_reduced("qwen3-14b")
    view = pool.register_model(cfg, quota=65536)
    ok_ids = []
    for sid, n in enumerate(lens):
        if view.append_tokens(sid, n):
            ok_ids.append(sid)
    tbl = view.block_table(ok_ids, max_blocks=16)
    sl = view.seq_lens(ok_ids)
    for i, sid in enumerate(ok_ids):
        n_blocks = -(-lens[sid] // BLOCK_TOKENS)
        got = (tbl[i] >= 0).sum()
        assert got == min(n_blocks, 16)
        assert sl[i] == lens[sid]
    for sid in ok_ids:
        view.free_seq(sid)
    assert pool.allocator.used == 0

# ---------------------------------------------------------------------------
# grow/shrink/alloc under grant-debt settlement (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 96)),
                min_size=1, max_size=60))
def test_pool_grant_debt_interleaving(ops):
    """Random interleavings of seq alloc-to-exhaustion, frees, and the
    fused-group grant algebra (``MuxScheduler``: build settles debt
    before growing, dissolve shrinks and books the unreclaimed tail as
    debt) keep the arena exactly sized: no block is double-freed, none
    is minted, and ``n_head_blocks == base + granted + debt`` at every
    step.  This is the accounting a block-loss fault (``pool.shrink``
    mid-flight, serving/faults.py) and a crash recovery (dissolve +
    rebuild) both lean on."""
    base = 512
    pool = _pool(base)
    cfg = configs.get_reduced("qwen2-7b")
    view = pool.register_model(cfg, quota=10**9)
    granted = debt = 0
    live: list = []
    next_sid = 0
    for kind, n in ops:
        if kind == 0:                      # alloc (may exhaust: ok=False)
            if view.append_tokens(next_sid, n * BLOCK_TOKENS):
                live.append(next_sid)
            next_sid += 1
        elif kind == 1 and live:           # free a live seq
            view.free_seq(live.pop(n % len(live)))
        elif kind == 2 and granted == 0:   # build: settle debt, grow rest
            want = n
            settle = min(debt, want)
            debt -= settle
            grown = pool.grow(want - settle)
            assert grown == want - settle, "grow is unconditional"
            granted = grown + settle
        elif kind == 3 and granted > 0:    # dissolve: shrink, book debt
            got = pool.shrink(granted)
            assert 0 <= got <= granted
            debt += granted - got
            granted = 0
        assert debt >= 0 and granted >= 0
        assert pool.n_head_blocks == base + granted + debt, \
            "arena size must equal base + outstanding grant + debt"
        assert pool.allocator.used == view.used, "accounting exact"
        assert pool.allocator.free_blocks \
            == pool.n_head_blocks - pool.allocator.used
        assert pool.k.shape[0] == pool.n_head_blocks
    # cleanup: free everything, dissolve, settle all debt — the arena
    # returns to its seed size with zero leaked blocks
    for sid in list(live):
        view.free_seq(sid)
    if granted:
        debt += granted - pool.shrink(granted)
    assert pool.shrink(debt) == debt, "idle tail settles all debt"
    assert pool.n_head_blocks == base and pool.allocator.used == 0
    assert pool.allocator.free_blocks == base

# ---------------------------------------------------------------------------
# refcounted sharing (prefix caching, DESIGN.md §13)
# ---------------------------------------------------------------------------
def test_allocator_share_refcounts_and_double_free():
    a = BlockAllocator(16)
    s = a.alloc(4)
    a.share(s, 4)
    assert a.used == 8 and a.physical_used == 4
    assert a.refcount(s) == 2
    a.free(s, 4)                        # one holder lets go...
    assert a.used == 4 and a.physical_used == 4, \
        "a block must never be reclaimed while refcount > 0"
    assert a.alloc(16) is None, "shared blocks still occupy the arena"
    a.free(s, 4)                        # ...now the last one does
    assert a.used == 0 and a.free_blocks == 16
    with pytest.raises(ValueError):
        a.free(s, 4)                    # double free must raise
    with pytest.raises(ValueError):
        a.share(s, 1)                   # sharing free space is a bug
    assert a.alloc(16) == 0


def test_fragmentation_vs_shrinkable_tail():
    """Regression: ``largest_free_range``/``fragmentation`` describe
    interior allocatability and must NOT be read as shrink capacity —
    a single pinned tail block clamps ``shrink`` regardless of how big
    the interior free space is.  ``shrinkable_tail`` is the honest
    shrink figure."""
    a = BlockAllocator(64)
    s1 = a.alloc(48)
    s2 = a.alloc(16)                    # pins [48, 64): the tail
    a.free(s1, 48)                      # huge interior free run
    assert a.largest_free_range() == 48
    assert a.fragmentation() == 0.0
    assert a.shrinkable_tail() == 0, "pinned tail → nothing shrinkable"
    assert a.shrink(16) == 0, "shrink must refuse the pinned tail"
    assert a.n_blocks == 64
    a.free(s2, 16)
    assert a.shrinkable_tail() == 64


def test_pool_shrinkable_tail_exposed():
    pool = _pool(256)
    cfg = configs.get_reduced("qwen2-7b")
    view = pool.register_model(cfg, quota=10**6)
    assert pool.shrinkable_tail() == 256
    assert view.append_tokens(0, BLOCK_TOKENS)
    assert pool.shrinkable_tail() == 256 - view.used
    view.free_seq(0)
    assert pool.shrinkable_tail() == 256


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 64)),
                min_size=1, max_size=50))
def test_pool_sharing_interleaving(ops):
    """Random interleavings of seq allocation, frees, prefix sharing
    (``share_prefix``), copy-on-write appends into shared tails, and
    the fused-grant grow/shrink/debt algebra keep every allocator
    invariant exact: ``n_head_blocks == base + granted + debt``,
    ``used`` equals the refcount-weighted live set, ``physical_used``
    counts distinct live blocks, and the free list stays sorted,
    coalesced, disjoint from live blocks and in-bounds.  No block is
    reclaimed while a holder remains (DESIGN.md §13)."""
    base = 512
    pool = UnifiedKVPool(base, 16)
    from repro.config import replace
    cfg = replace(configs.get_reduced("qwen2-7b"), head_dim=16)
    view = pool.register_model(cfg, quota=10**9)
    gs = view.group_size
    granted = debt = 0
    live: list = []
    next_sid = 0
    for kind, n in ops:
        if kind == 0:                      # new seq (may exhaust: ok=False)
            if view.append_tokens(next_sid, (n % 8 + 1) * BLOCK_TOKENS):
                live.append(next_sid)
            next_sid += 1
        elif kind == 1 and live:           # free a live seq
            view.free_seq(live.pop(n % len(live)))
        elif kind == 2 and granted == 0:   # build: settle debt, grow rest
            settle = min(debt, n)
            debt -= settle
            pool.grow(n - settle)
            granted = n
        elif kind == 3 and granted > 0:    # dissolve: shrink, book debt
            got = pool.shrink(granted)
            debt += granted - got
            granted = 0
        elif kind == 4 and live:           # adopt a donor's prefix
            donor = view.seqs[live[n % len(live)]]
            if donor.bases:
                k = 1 + n % len(donor.bases)
                tok = (k - 1) * BLOCK_TOKENS + 1 + n % BLOCK_TOKENS
                if view.share_prefix(next_sid, donor.bases[:k], tok):
                    live.append(next_sid)
                next_sid += 1
        elif kind == 5 and live:           # append (COW on shared tails)
            view.append_tokens(live[n % len(live)], n)
        alloc = pool.allocator
        assert pool.n_head_blocks == base + granted + debt
        refs = alloc.refcounts()
        assert alloc.used == sum(refs.values()) == view.used
        assert view.used == sum(len(view.seqs[s].bases) * gs for s in live)
        assert alloc.physical_used == len(refs)
        assert alloc.free_blocks == pool.n_head_blocks - len(refs)
        free_set: set = set()
        prev_end = -1
        for s, e in alloc._free:
            assert 0 <= s < e <= alloc.n_blocks, "free range out of bounds"
            assert s > prev_end, "free list must stay sorted + coalesced"
            prev_end = e
            free_set.update(range(s, e))
        assert len(free_set) == alloc.free_blocks
        assert not free_set & refs.keys(), \
            "a live (possibly shared) block leaked into the free list"
        for sid in live:
            sc = view.seqs[sid]
            assert sc.shared <= len(sc.bases)
            assert all(b + gs <= pool.n_head_blocks for b in sc.bases)
    for sid in list(live):
        view.free_seq(sid)
    if granted:
        debt += granted - pool.shrink(granted)
    assert pool.shrink(debt) == debt, "idle tail settles all debt"
    assert pool.n_head_blocks == base and pool.allocator.used == 0
    assert pool.allocator.free_blocks == base
