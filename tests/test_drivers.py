"""CLI drivers + engine↔Pallas integration."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels import ops
from repro.models.transformer import init_params
from repro.serving import cache_ops
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool


def _run(args, timeout=480):
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, timeout=timeout,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu",
                               "HOME": "/tmp"})


def test_train_driver_cli():
    r = _run(["-m", "repro.launch.train", "--arch", "qwen2-7b",
              "--steps", "6", "--batch", "2", "--seq", "16",
              "--log-every", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss=" in r.stdout


def test_serve_driver_cli():
    r = _run(["-m", "repro.launch.serve", "--archs", "qwen2-7b",
              "--rate", "1.0", "--horizon", "2", "--max-new", "3"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "finished" in r.stdout
    assert "SLO[" in r.stdout          # attainment report is part of CLI


def test_serve_driver_cli_placement_bridge(tmp_path):
    """launch/serve.py --placement runs a unit built from a
    core/placement.py plan end-to-end (the acceptance path)."""
    plan = {
        "total_tpt": 2.0,
        "meshes": [{"mesh_id": 0, "n_devices": 2, "specs": [
            {"name": "qwen2-7b#0", "arch": "qwen2-7b", "rate": 1.5,
             "tp": 2, "sm_frac": 0.5, "mean_prompt": 16, "mean_output": 4},
            {"name": "qwen2-7b#1", "arch": "qwen2-7b", "rate": 0.5,
             "tp": 2, "sm_frac": 0.5, "mean_prompt": 16, "mean_output": 4},
        ]}],
    }
    path = tmp_path / "plan.json"
    path.write_text(__import__("json").dumps(plan))
    r = _run(["-m", "repro.launch.serve", "--placement", str(path),
              "--policy", "adbs", "--fused", "--chunk-tokens", "16",
              "--horizon", "2", "--deterministic", "--mean-output", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "placement plan" in r.stdout
    assert "fused group (2 engines)" in r.stdout
    assert "SLO[" in r.stdout


def test_engine_pool_matches_pallas_kernel():
    """The engine's XLA paged-attention path and the Pallas kernel
    (interpret mode) agree on a pool the engine actually filled."""
    cfg = configs.get_reduced("qwen2-7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    pool = UnifiedKVPool(50_000, cfg.hd, dtype=jnp.float32)
    view = pool.register_model(cfg, 50_000)
    eng = Engine(cfg, params, view, max_slots=2)
    rng = np.random.default_rng(0)
    reqs = [Request(i, cfg.name,
                    list(rng.integers(1, cfg.vocab_size, 10 + 3 * i)), 2)
            for i in range(2)]
    eng.prefill(reqs)

    seq_ids = [r._seq_id for r in reqs]
    table = jnp.asarray(view.block_table(seq_ids, 8))
    lens = jnp.asarray(view.seq_lens(seq_ids))
    q = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.n_heads, cfg.hd), jnp.float32)
    for layer in (0, cfg.n_layers - 1):
        ref = cache_ops.paged_decode_attention(
            q, pool.k, pool.v, table, lens, layer, cfg.n_kv_heads)
        pal = ops.paged_attention(q, pool.k, pool.v, table, lens, layer,
                                  n_kv=cfg.n_kv_heads,
                                  backend="interpret")
        np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                                   rtol=1e-4, atol=1e-4)
