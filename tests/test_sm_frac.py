"""Runtime sm_frac enforcement (DESIGN.md §11): the share-aware
deterministic clock (``TickCostModel.tick_dt``), solo-reference edge
cases against actual solo runs, the placement → runtime share
threading, and sim↔runtime throughput-ordering parity for a shared
placement + shares."""
import pytest

from repro import configs
from repro.config import replace
from repro.core.estimator import LLMSpec
from repro.core.placement import Mesh, Placement
from repro.core.simulator import simulate
from repro.core.workload import synthesize
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  serve_requests, serve_workload,
                                  units_from_placement)
from repro.serving.engine import Request

COST = TickCostModel()


# ---------------------------------------------------------------------------
# share-aware tick cost (TickCostModel.tick_dt)
# ---------------------------------------------------------------------------
def test_tick_dt_solo_full_share_matches_legacy():
    """A solo full-share engine must charge exactly the legacy
    temporal dt for every phase mix — share enforcement cannot change
    the meaning of a dedicated unit's clock (and the analytic solo
    reference stays consistent with actual solo runs)."""
    sh = {"m": 1.0}
    # prefill-only, decode-only, and mixed ticks
    assert COST.tick_dt({"m": 32}, {}, sh) == pytest.approx(COST.dt(32, 0))
    assert COST.tick_dt({}, {"m": 4}, sh) == pytest.approx(COST.dt(0, 4))
    assert COST.tick_dt({"m": 32}, {"m": 4}, sh) \
        == pytest.approx(COST.dt(32, 4))
    # device scaling applies to the per-token cost only
    assert COST.tick_dt({"m": 32}, {"m": 4}, sh, devices=4) \
        == pytest.approx(COST.dt(32, 4, devices=4))


def test_tick_dt_decode_overlap_beats_temporal():
    """Colocated decode jobs under planned shares overlap (Eq. 3's
    max over decode times) instead of serializing — the tick must be
    strictly cheaper than the legacy temporal charge, and never
    cheaper than the slowest single decode job."""
    shares = {"a": 0.5, "b": 0.3, "c": 0.2}
    dec = {"a": 4, "b": 4, "c": 4}
    pre = {"a": 16, "b": 16, "c": 16}
    spatial = COST.tick_dt(pre, dec, shares)
    temporal = COST.dt(sum(pre.values()), sum(dec.values()))
    assert spatial < temporal
    slowest = max(COST.phase_time(t, COST.decode_tok, COST.rho_decode,
                                  shares[n]) for n, t in dec.items())
    assert spatial >= COST.base + slowest - 1e-12


def test_tick_dt_small_share_pays_roofline_penalty():
    """Below the decode compute intensity the 1/share scaling bites:
    a tiny share decodes strictly slower, and monotonically so."""
    t = [COST.tick_dt({}, {"m": 8}, {"m": f}) for f in (1.0, 0.3, 0.1, 0.05)]
    assert t[0] == pytest.approx(t[1])          # memory-bound: flat
    assert t[1] < t[2] < t[3]                    # compute-bound: 1/f


def test_tick_dt_oversubscription_normalizes_shares():
    """Shares summing past 1 cannot buy more than the mesh has: six
    colocated full-share decode jobs are charged exactly like an
    honest 1/6-each split, and the contention-normalized shares pay
    the sub-rho roofline penalty a lone full-share job does not."""
    dec = {n: 8 for n in "abcdef"}
    over = COST.tick_dt({}, dec, {n: 1.0 for n in dec})
    fair = COST.tick_dt({}, dec, {n: 1 / 6 for n in dec})
    assert over == pytest.approx(fair)
    solo = COST.tick_dt({}, {"a": 8}, {"a": 1.0})
    assert over > solo, "oversubscription is not free"


def test_tick_dt_prefill_fills_residual_share():
    """With small decode shares the prefill phase overlaps into the
    residual compute: the tick charges max(prefill, decode) instead of
    their sum; with full decode shares it falls back to the serial
    dispatch (never worse than legacy)."""
    pre, dec = {"p": 64}, {"d": 2}
    small = COST.tick_dt(pre, dec, {"p": 0.5, "d": 0.2})
    t_d = COST.phase_time(2, COST.decode_tok, COST.rho_decode, 0.2)
    t_p_serial = COST.phase_time(64, COST.prefill_tok, COST.rho_prefill, 1.0)
    assert small < COST.base + t_p_serial + t_d  # overlap won
    full = COST.tick_dt(pre, dec, {"p": 1.0, "d": 1.0})
    assert full == pytest.approx(COST.base + t_p_serial
                                 + COST.phase_time(2, COST.decode_tok,
                                                   COST.rho_decode, 1.0))


# ---------------------------------------------------------------------------
# solo reference vs actual solo deterministic runs (edge cases)
# ---------------------------------------------------------------------------
def _solo_run(arch: str, prompt_len: int, max_new: int,
              chunk_tokens: int = 16):
    unit = build_unit_from_specs([("solo", arch, 1.0)], pool_blocks=4_096,
                                 max_slots=2, chunk_tokens=chunk_tokens,
                                 seed=0, policy="adbs")
    req = Request(0, "solo", list(range(1, prompt_len + 1)), max_new,
                  arrival=0.0)
    rep = serve_requests([unit], [req], slo_scales=(1.0,), cost=COST)
    return req, rep


def test_solo_reference_prompt_exact_chunk_multiple():
    """prompt_len an exact multiple of chunk_tokens: ceil has no slack
    to hide an off-by-one chunk tick.  The actual solo E2E matches the
    analytic reference to within the final tick (timestamps are
    stamped before the tick's cost is charged)."""
    ref = COST.solo_reference(32, 4, chunk_tokens=16)
    assert ref == pytest.approx(
        (2 + 3) * COST.base + 32 * COST.prefill_tok + 3 * COST.decode_tok)
    req, rep = _solo_run("qwen2-7b", 32, 4)
    assert len(req.output) == 4
    e2e = req.finish - req.arrival
    assert 0.0 <= ref - e2e <= 2 * (COST.base + COST.decode_tok) + 1e-9
    assert rep.per_llm["solo"].attainment[1.0] == 1.0


def test_solo_reference_output_len_one():
    """output_len == 1: the single output token is committed by the
    prefill tick itself — no decode tick is billed, and the engine
    must emit exactly one token (not one-plus-a-spurious-decode)."""
    ref = COST.solo_reference(32, 1, chunk_tokens=16)
    assert ref == pytest.approx(2 * COST.base + 32 * COST.prefill_tok)
    req, rep = _solo_run("qwen2-7b", 32, 1)
    assert len(req.output) == 1, \
        "a max_new_tokens=1 request must finish at prefill"
    e2e = req.finish - req.arrival
    assert 0.0 <= ref - e2e <= COST.base + 16 * COST.prefill_tok + 1e-9
    assert rep.per_llm["solo"].attainment[1.0] == 1.0


def test_solo_reference_output_len_zero():
    """output_len == 0 (prefill-only probe): the reference bills only
    prefill ticks and prompt tokens, and the engine finalizes the
    request at prompt end without committing any token."""
    ref = COST.solo_reference(32, 0, chunk_tokens=16)
    assert ref == pytest.approx(2 * COST.base + 32 * COST.prefill_tok)
    req, rep = _solo_run("qwen2-7b", 32, 0)
    assert req.output == []
    assert req.finish >= 0 and req.first_token >= 0
    assert rep.per_llm["solo"].attainment[1.0] == 1.0


def test_solo_reference_whole_prompt_prefill():
    """chunk_tokens=None: one prefill tick regardless of prompt
    length (the unchunked engine path)."""
    ref = COST.solo_reference(48, 3, chunk_tokens=None)
    assert ref == pytest.approx(
        (1 + 2) * COST.base + 48 * COST.prefill_tok + 2 * COST.decode_tok)
    req, rep = _solo_run("qwen2-7b", 48, 3, chunk_tokens=0)
    assert len(req.output) == 3
    e2e = req.finish - req.arrival
    assert 0.0 <= ref - e2e <= 2 * (COST.base + COST.decode_tok) + 1e-9
    assert rep.per_llm["solo"].attainment[1.0] == 1.0


def test_solo_reference_ssm_engine():
    """An SSM engine (no paged KV, state-carry chunked prefill) meters
    the same token counts, so the shared reference applies unchanged."""
    req, rep = _solo_run("mamba2-2.7b", 32, 4)
    assert len(req.output) == 4
    ref = COST.solo_reference(32, 4, chunk_tokens=16)
    e2e = req.finish - req.arrival
    assert 0.0 <= ref - e2e <= 2 * (COST.base + COST.decode_tok) + 1e-9
    assert rep.per_llm["solo"].attainment[1.0] == 1.0


# ---------------------------------------------------------------------------
# placement → runtime share threading
# ---------------------------------------------------------------------------
def _shared_plan():
    cfg = configs.get("qwen2-7b")

    def spec(name, rate, f):
        return LLMSpec(replace(cfg, name=name), rate, mean_prompt=16,
                       mean_output=6, tp=1, sm_frac=f, arch="qwen2-7b")

    return Placement(
        meshes=[Mesh(0, 4, [spec("hot", 12.0, 0.5), spec("mid", 6.0, 0.3),
                            spec("cold", 3.0, 0.2)])],
        total_tpt=21.0)


def test_units_consume_plan_shares():
    """units_from_placement must thread each spec's sm_frac into its
    unit (the runtime used to drop it on the floor) and the resulting
    report must surface the shares it actually ran."""
    pl = _shared_plan()
    (u,) = units_from_placement(pl, pool_blocks=12_000, max_slots=2,
                                chunk_tokens=16, fused=True)
    assert u.enforce_shares
    assert u.sm_frac == {"hot": 0.5, "mid": 0.3, "cold": 0.2}
    # the temporal baseline arm builds the same unit without shares
    (t,) = units_from_placement(pl, pool_blocks=12_000, max_slots=2,
                                chunk_tokens=16, fused=True,
                                enforce_shares=False)
    assert not t.enforce_shares
    assert t.sm_frac == {"hot": 1.0, "mid": 1.0, "cold": 1.0}


def test_report_surfaces_shares():
    pl = _shared_plan()
    wl = synthesize(["hot", "mid", "cold"], alpha=2.1, max_rate=6.0,
                    horizon=1.0, seed=0, mean_prompt=16, mean_output=6,
                    max_len=64)
    units = units_from_placement(pl, pool_blocks=12_000, max_slots=4,
                                 chunk_tokens=16, fused=True)
    rep = serve_workload(units, wl, seed=1, slo_scales=(2.0,), cost=COST)
    assert rep.sm_frac == {"hot": 0.5, "mid": 0.3, "cold": 0.2}
    assert "sm_frac" in rep.summary()
    assert rep.to_json()["sm_frac"]["hot"] == 0.5


def test_realtime_accepts_reconfig_with_analytic_refs():
    """Wall-clock + reconfig used to be rejected (startup solo-probe
    references go stale after a migration).  The driver now computes
    ANALYTIC references from a TickCostModel at the owning mesh's
    current size, so the combination is accepted and references follow
    migrated engines without probe traffic."""
    from repro.serving.reconfig import ReconfigController
    pl = _shared_plan()
    units = units_from_placement(pl, pool_blocks=12_000, max_slots=2,
                                 chunk_tokens=16)
    ctrl = ReconfigController(pl, units)
    rep = serve_requests(units, [], cost=None, warm=False, reconfig=ctrl)
    assert not rep.deterministic
    assert rep.aggregate.submitted == 0
    # the analytic reference must be devices-aware: the same request
    # shape is cheaper on a wider mesh
    c = COST
    assert c.solo_reference(64, 8, 16, devices=4) \
        < c.solo_reference(64, 8, 16, devices=1)


# ---------------------------------------------------------------------------
# sim ↔ runtime parity
# ---------------------------------------------------------------------------
def test_runtime_throughput_ordering_matches_simulator():
    """For one shared placement (same shares, rates and trace), the
    runtime's per-LLM throughput ordering must match the discrete-event
    simulator's — the deterministic clock's share accounting and the
    sim's Eq.-3 rounds are two views of one model, not two models."""
    pl = _shared_plan()
    names = ["hot", "mid", "cold"]
    wl = synthesize(names, alpha=2.1, max_rate=16.0, horizon=2.0, seed=0,
                    mean_prompt=16, mean_output=6, max_len=128)
    sim = simulate(pl, wl, mode="spatial-temporal", policy="adbs")
    assert set(sim.per_llm_tpt) == set(names)
    units = units_from_placement(pl, pool_blocks=20_000, max_slots=4,
                                 chunk_tokens=16, fused=True)
    rep = serve_workload(units, wl, seed=1, slo_scales=(2.0, 4.0),
                         cost=COST)
    run_tpt = {n: rep.per_llm[n].throughput for n in names}
    sim_order = sorted(names, key=lambda n: -sim.per_llm_tpt[n])
    run_order = sorted(names, key=lambda n: -run_tpt[n])
    assert sim_order == run_order, (sim.per_llm_tpt, run_tpt)
