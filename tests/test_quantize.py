"""int8 serving quantization (§Perf W8/KV8 variant) + seq-parallel SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.launch import steps
from repro.models.mamba2 import (causal_conv, causal_conv_slabbed,
                                 ssd_chunked, ssd_seq_parallel)
from repro.models.transformer import init_params
from repro.serving.quantize import (QLayerView, qmatmul, quantize_kv,
                                    dequantize_kv, quantize_params,
                                    quantize_tensor)


def test_quantize_tensor_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 3.0
    q, s = quantize_tensor(w, axis=-1)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * s
    rel = float(jnp.abs(back - w).max() / jnp.abs(w).max())
    assert rel < 0.01, rel


def test_qmatmul_matches_dequant():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32)) * 2.0
    q, s = quantize_tensor(w, axis=-1)
    y1 = qmatmul(x, q, s)
    y2 = x @ (q.astype(jnp.float32) * s)
    # qmatmul runs the GEMM in bf16 — bound the error relative to the
    # output magnitude rather than elementwise
    rel = float(np.abs(np.asarray(y1, np.float32) - np.asarray(y2)).max()
                / np.abs(np.asarray(y2)).max())
    assert rel < 0.05, rel


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_kv_quant_roundtrip(seed):
    k = jax.random.normal(jax.random.PRNGKey(seed), (2, 3, 16)) * 5
    q, s = quantize_kv(k)
    back = dequantize_kv(q, s)
    assert float(jnp.abs(back - k).max()) < float(jnp.abs(k).max()) * 0.02


def test_quantize_params_structure():
    cfg = configs.get_reduced("qwen2-7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    qp = quantize_params(params)
    assert "wq_q" in qp["layers"] and "wq_s" in qp["layers"]
    assert qp["layers"]["wq_q"].dtype == jnp.int8
    assert "ln1" in qp["layers"]            # norms untouched
    assert "embed_q" in qp["tok"]
    # QLayerView dequantizes per layer
    view = QLayerView(qp["layers"], 0)
    w = view["wq"]
    assert w.shape == (1,) + params["layers"]["wq"].shape[1:]
    rel = float(jnp.abs(w[0].astype(jnp.float32)
                        - params["layers"]["wq"][0]).max())
    assert rel < float(jnp.abs(params["layers"]["wq"][0]).max()) * 0.02


@pytest.mark.parametrize("arch", ["deepseek-coder-33b",
                                  "command-r-plus-104b", "qwen3-14b"])
def test_w8kv8_decode_matches_bf16(arch):
    """W8/KV8 decode: small relative logit error, same greedy tokens."""
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    qparams = quantize_params(params)
    B, Sp, n_new = 2, 16, 3
    Sc = Sp + n_new
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sp), 0,
                              cfg.vocab_size)
    lens = jnp.full((B,), Sp, jnp.int32)
    out = steps.make_prefill_step(cfg)(params, toks, lens)
    dec_q = steps.make_decode_step_w8kv8(cfg)
    dec_f = steps.make_decode_step(cfg)

    pk, pv = out["cache_k"], out["cache_v"]
    amax = jnp.abs(pk).max(-1)
    s = jnp.maximum(amax, 1e-8) / 127.0
    ck = jnp.zeros((cfg.n_layers, B, Sc, cfg.n_kv_heads, cfg.hd),
                   jnp.int8)
    ck = ck.at[:, :, :Sp].set(
        jnp.clip(jnp.round(pk / s[..., None]), -127, 127).astype(jnp.int8))
    sk = jnp.zeros((cfg.n_layers, B, Sc, cfg.n_kv_heads), jnp.float32)
    sk = sk.at[:, :, :Sp].set(s)
    amax = jnp.abs(pv).max(-1)
    s = jnp.maximum(amax, 1e-8) / 127.0
    cv = jnp.zeros_like(ck)
    cv = cv.at[:, :, :Sp].set(
        jnp.clip(jnp.round(pv / s[..., None]), -127, 127).astype(jnp.int8))
    sv = jnp.zeros_like(sk)
    sv = sv.at[:, :, :Sp].set(s)
    ckf = jnp.zeros((cfg.n_layers, B, Sc, cfg.n_kv_heads, cfg.hd),
                    jnp.float32).at[:, :, :Sp].set(pk)
    cvf = jnp.zeros_like(ckf).at[:, :, :Sp].set(pv)

    logits_f = out["logits"]
    for t in range(n_new):
        nxt = jnp.argmax(logits_f, -1).astype(jnp.int32)
        lens2 = jnp.full((B,), Sp + t + 1, jnp.int32)
        oq = dec_q(qparams, ck, cv, sk, sv, nxt, lens2)
        of = dec_f(params, ckf, cvf, nxt, lens2)
        ck, cv, sk, sv = (oq["cache_k"], oq["cache_v"], oq["scale_k"],
                          oq["scale_v"])
        logits_f, ckf, cvf = of["logits"], of["cache_k"], of["cache_v"]
        rel = float(jnp.abs(oq["logits"] - logits_f).max()
                    / jnp.abs(logits_f).max())
        assert rel < 0.1, f"{arch} step {t}: rel err {rel}"
        # greedy tokens must agree except on reference near-ties
        # (random-init logits can put two tokens within quantization
        # noise of each other; a flip there is not a correctness bug)
        aq = jnp.argmax(oq["logits"], -1)
        af = jnp.argmax(logits_f, -1)
        gap = (jnp.max(logits_f, -1)
               - jnp.take_along_axis(logits_f, aq[..., None], -1)[..., 0])
        spread = logits_f.max(-1) - logits_f.min(-1)
        ok = (aq == af) | (gap <= 0.01 * spread)
        assert bool(ok.all()), \
            f"{arch} step {t}: greedy mismatch beyond near-tie " \
            f"(gap={gap.tolist()}, spread={spread.tolist()})"


# ---------------------------------------------------------------------------
# sequence-parallel SSD + slabbed conv (§Perf, mamba2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("slabs", [2, 4, 8])
def test_ssd_seq_parallel_exact(slabs):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, s, h, p, n = 2, 128, 4, 16, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    B = jax.random.normal(ks[2], (b, s, 1, n))
    C = jax.random.normal(ks[3], (b, s, 1, n))
    d = jnp.ones((h,))
    y1, f1 = ssd_chunked(x, dt, a_log, B, C, d, 16)
    y2, f2 = ssd_seq_parallel(x, dt, a_log, B, C, d, 16, slabs=slabs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("slabs", [2, 8])
def test_causal_conv_slabbed_exact(slabs):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (2, 64, 12))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 12)) * 0.3
    b = jnp.zeros((12,))
    y1 = causal_conv(x, w, b)
    y2 = causal_conv_slabbed(x, w, b, slabs=slabs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
