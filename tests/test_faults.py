"""Fault injection & graceful degradation (serving/faults.py,
DESIGN.md §12): plan parsing/nesting, and one consistency test per
fault class — crash recovery, block loss, transient escalation,
migration abort — each asserting exact post-fault pool accounting,
rebuilt fused groups and zero silent drops.  Plus the degradation
ladder itself: backpressure, deadline shedding, deterministic requeue
order and the serving-loop watchdog."""
import numpy as np
import pytest

from repro.core.placement import Mesh, Placement
from repro.serving.driver import (LogicalClock, TickCostModel,
                                  build_unit_from_specs, serve_requests)
from repro.serving.engine import Request
from repro.serving.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  RecoveryCostModel)
from repro.serving.mux import MuxScheduler

COST = TickCostModel()


def _unit(policy="adbs", clock=None, **kw):
    """Two fused qwen2-7b engines on one small pool."""
    u = build_unit_from_specs(
        [("a", "qwen2-7b", 3.0), ("b", "qwen2-7b", 1.0)],
        pool_blocks=4_000, max_slots=4, chunk_tokens=16, seed=0,
        policy=policy, fused=True, **kw)
    clock = clock or LogicalClock()
    u.clock = clock
    for e in u.engines.values():
        e.clock = clock
    return u, clock


def _requests(n_a=4, n_b=2, plen=24, out=6):
    rng = np.random.default_rng(5)
    reqs = [Request(i, "a", list(rng.integers(1, 500, plen)), out,
                    arrival=0.0) for i in range(n_a)]
    reqs += [Request(100 + i, "b", list(rng.integers(1, 500, plen)), out,
                     arrival=0.0) for i in range(n_b)]
    return reqs


def _accounting_exact(u):
    """The allocator's global usage equals the per-view sum, every
    engine's view is registered, and no grant debt is outstanding."""
    pool = u.pool
    assert pool.allocator.used == sum(v.used for v in pool.views.values())
    assert set(pool.views) == set(u.engines)
    for name, eng in u.engines.items():
        assert eng.view is pool.views[name], name
    assert u._grant_debt == 0, "no outstanding fused-grant debt"


def _drain(u, max_ticks=800):
    for _ in range(max_ticks):
        if not u.pending():
            return
        u.tick()
        u.clock.advance(0.005)
    raise AssertionError("unit did not drain")


# ---------------------------------------------------------------------------
# plan parsing / severity nesting
# ---------------------------------------------------------------------------
def test_fault_plan_parse_all_kinds_and_sorting():
    plan = FaultPlan.parse("block_loss:b:256@1.5, crash:a@0.5,"
                           "transient:a:3@2.0,migration_abort@0.1")
    assert [e.kind for e in plan.events] == [
        "migration_abort", "engine_crash", "block_loss", "transient_step"]
    assert plan.targets() == ["a", "b"]
    ev = plan.events[2]
    assert (ev.target, ev.magnitude, ev.at) == ("b", 256, 1.5)
    # round-trip through the JSON wire form
    back = FaultPlan([FaultEvent(**d) for d in plan.to_json()])
    assert back.to_json() == plan.to_json()


@pytest.mark.parametrize("bad", [
    "crash:a",                  # missing @time
    "crash:a@soon",             # bad time
    "explode:a@1",              # unknown kind
    "crash@1",                  # crash needs a target
    "block_loss:a@1",           # block_loss needs :blocks
    "transient:a:x@1",          # non-integer magnitude
    "migration_abort:a@1",      # abort takes no target
])
def test_fault_plan_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_fault_plan_random_severity_nested():
    """Severity-s plans are prefixes of the severity-1 master list —
    more severity strictly adds faults (the chaos bench's monotonicity
    gate rests on this) — and severity 0 is the empty plan."""
    names = ["a", "b", "c"]
    full = FaultPlan.random(names, 8.0, 1.0, seed=3).to_json()
    assert len(full) == 3 * len(names) + 1
    prev: set = set()
    for sev in (0.0, 0.25, 0.5, 0.75, 1.0):
        sub = {str(e) for e in
               FaultPlan.random(names, 8.0, sev, seed=3).to_json()}
        assert prev <= sub <= {str(e) for e in full}, sev
        prev = sub
    assert FaultPlan.random(names, 8.0, 0.0, seed=3).events == []


# ---------------------------------------------------------------------------
# fault class 1: engine crash
# ---------------------------------------------------------------------------
def test_crash_recovery_consistent_state():
    """A crash with live in-flight work tears the engine down and
    rebuilds it fused: every request still finishes exactly once, the
    evicted ones carry a requeue mark, pool accounting stays exact and
    the fused group re-forms around the fresh engine."""
    u, clock = _unit()
    plan = FaultPlan.parse("crash:a@0.02")
    u.injector = FaultInjector(plan)
    reqs = _requests()
    for r in reqs:
        u.submit(r)
    for _ in range(6):                     # get work in flight, then fire
        u.tick()
        clock.advance(0.005)
    assert any(rec["kind"] == "engine_crash" for rec in u.fault_events)
    rec = next(rec for rec in u.fault_events
               if rec["kind"] == "engine_crash")
    assert rec["target"] == "a" and rec["requeued"] >= 1
    _accounting_exact(u)
    assert len(u.fused_groups) == 1, "crash must re-fuse the rebuilt engine"
    assert sorted(u.fused_groups[0].names) == ["a", "b"]
    _drain(u)
    fin = {r.req_id for r in u.stats.finished}
    assert fin == {r.req_id for r in reqs}, "zero drops, zero dups"
    assert len(u.stats.finished) == len(reqs)
    assert any(r.requeues >= 1 for r in u.stats.finished)
    assert not u.injector.unfired()
    _accounting_exact(u)
    assert u.pool.allocator.used == 0


# ---------------------------------------------------------------------------
# fault class 2: block loss
# ---------------------------------------------------------------------------
def test_block_loss_exact_shrink_and_requeue():
    """Losing the arena tail evicts exactly the sequences with pages
    there, requeues them at the queue head in arrival order, and
    shrinks the pool by exactly the lost blocks."""
    u, clock = _unit()
    reqs = _requests(n_a=4, n_b=2)
    for r in reqs:
        u.submit(r)
    for _ in range(4):
        u.tick()
        clock.advance(0.005)
    pool = u.pool
    assert pool.allocator.used > 0, "need live KV to victimize"
    # doom every block from the highest occupied base upward so at
    # least one live sequence is a victim
    occ = max(b for v in pool.views.values()
              for sc in v.seqs.values() for b in sc.bases)
    n_before = pool.n_head_blocks
    n_lose = n_before - occ
    rec = u._lose_blocks(n_lose)
    assert rec["blocks"] == n_lose, "shrink must remove exactly the loss"
    assert pool.n_head_blocks == n_before - n_lose
    assert rec["requeued"] >= 1
    _accounting_exact(u)
    # no survivor holds a page in the doomed region
    for v in pool.views.values():
        for sc in v.seqs.values():
            assert all(b + v.group_size <= pool.n_head_blocks
                       for b in sc.bases)
    _drain(u)
    assert {r.req_id for r in u.stats.finished} == {r.req_id for r in reqs}
    assert len(u.stats.finished) == len(reqs)
    assert u.pool.allocator.used == 0


# ---------------------------------------------------------------------------
# fault class 3: transient step failures
# ---------------------------------------------------------------------------
def test_transient_marks_down_then_escalates():
    """A transient window freezes its engine (work retried, nothing
    dropped); a window longer than the retry budget escalates to a
    full crash recovery and clears the wedged window."""
    u, clock = _unit()
    u.retry_budget = 2
    plan = FaultPlan.parse("transient:a:10@0.0")
    u.injector = FaultInjector(plan)
    reqs = _requests()
    for r in reqs:
        u.submit(r)
    # tick 1..2: down but within budget — no recovery yet
    u.tick()
    assert "a" in u._down
    assert not any(r["kind"] == "engine_crash" for r in u.fault_events)
    u.tick()
    # tick 3: budget exhausted → escalation
    u.tick()
    esc = [r for r in u.fault_events if r["kind"] == "engine_crash"]
    assert esc and esc[0]["reason"] == "transient"
    assert u.injector._transient_left.get("a", 0) == 0, \
        "escalation must clear the remaining window"
    _accounting_exact(u)
    _drain(u)
    assert {r.req_id for r in u.stats.finished} == {r.req_id for r in reqs}


def test_transient_within_budget_is_pure_delay():
    """A short hiccup (window ≤ budget) never tears anything down —
    the same work runs a tick later and the fault log stays empty."""
    u, clock = _unit()
    u.retry_budget = 5
    u.injector = FaultInjector(FaultPlan.parse("transient:a:2@0.0"))
    reqs = _requests()
    for r in reqs:
        u.submit(r)
    _drain(u)
    assert not [r for r in u.fault_events if r["kind"] == "engine_crash"]
    assert {r.req_id for r in u.stats.finished} == {r.req_id for r in reqs}
    assert all(r.requeues == 0 for r in u.stats.finished)


# ---------------------------------------------------------------------------
# fault class 4: migration abort
# ---------------------------------------------------------------------------
def test_migration_abort_rehomes_engine():
    """An abort mid-move re-homes the engine on its source unit through
    the fragmentation-rollback path: nothing detaches, evicted
    prefills are requeued with a retry mark, and the plan records the
    spec back at the source mesh."""
    from repro import configs
    from repro.config import replace
    from repro.core.estimator import LLMSpec
    from repro.serving.reconfig import MigrationExecutor

    clock = LogicalClock()
    uA, _ = _unit(clock=clock)
    uB = build_unit_from_specs([("c", "qwen2-7b", 1.0)], pool_blocks=4_000,
                               max_slots=4, chunk_tokens=16, seed=7)
    uB.clock = clock
    for e in uB.engines.values():
        e.clock = clock
    uA.mesh_id, uB.mesh_id = 0, 1
    reqs = _requests()
    for r in reqs:
        uA.submit(r)
    for _ in range(4):
        uA.tick()
        clock.advance(0.005)
    ex = MigrationExecutor({0: uA, 1: uB})
    ex.injector = FaultInjector(FaultPlan.parse("migration_abort@0.0"))

    def spec(name, rate):
        return LLMSpec(replace(configs.get("qwen2-7b"), name=name), rate,
                       mean_prompt=24, mean_output=8, tp=1, sm_frac=1.0,
                       arch="qwen2-7b")
    new_pl = Placement([Mesh(0, 2, [spec("b", 1.0)]),
                        Mesh(1, 2, [spec("c", 1.0), spec("a", 3.0)])], 5.0)
    stats = ex.execute([("a", 0, 1)], new_pl, now=clock())
    assert stats["executed"] == [] and stats["skipped"] == [("a", 0, 1)]
    assert "a" in uA.engines and "a" not in uB.engines
    assert ex.injector.records[0]["kind"] == "migration_abort"
    assert any(s.name == "a" for m in new_pl.meshes if m.mesh_id == 0
               for s in m.specs), "spec must return to the source mesh"
    _accounting_exact(uA)
    _drain(uA)
    assert {r.req_id for r in uA.stats.finished} == {r.req_id for r in reqs}


# ---------------------------------------------------------------------------
# degradation ladder: backpressure / deadline / requeue order / watchdog
# ---------------------------------------------------------------------------
def test_backpressure_sheds_new_arrivals_only():
    u, _ = _unit(max_queue=2, shed_policy="reject")
    reqs = _requests(n_a=5, n_b=0)
    for r in reqs:
        u.submit(r)
    assert len(u.queues["a"]) == 2
    assert len(u.stats.shed) == 3
    assert all(r.shed and r.shed_reason == "queue_full"
               for r in u.stats.shed)
    # requeues (appendleft) bypass the bound: in-flight work is never
    # dropped by backpressure
    u.queues["a"].appendleft(reqs[4])
    assert len(u.queues["a"]) == 3


def test_deadline_shed_pops_expired_heads():
    u, clock = _unit(shed_policy="deadline")
    reqs = _requests(n_a=3, n_b=1)
    reqs[0].deadline = 0.01                # expires before service
    reqs[1].deadline = 1e9
    for r in reqs:
        u.submit(r)
    clock.advance(0.05)
    u.tick()
    assert [r.req_id for r in u.stats.shed] == [reqs[0].req_id]
    assert u.stats.shed[0].shed_reason == "deadline"
    _drain(u)
    fin = {r.req_id for r in u.stats.finished}
    assert fin == {r.req_id for r in reqs} - {reqs[0].req_id}
    assert len(fin) + len(u.stats.shed) == len(reqs)


def test_shed_policy_none_never_drops():
    u, _ = _unit(shed_policy="none")
    reqs = _requests(n_a=6, n_b=0)
    for r in reqs:
        r.deadline = 0.0                   # long expired
        u.submit(r)
    _drain(u)
    assert not u.stats.shed
    assert len(u.stats.finished) == len(reqs)


def test_harvest_requeues_in_arrival_order():
    """Stall-escape preemptions re-enter the queue in (arrival,
    req_id) order, not eviction order — the deterministic-requeue pin
    (DESIGN.md §12)."""
    u, _ = _unit()
    eng = u.engines["a"]
    later = Request(9, "a", [1] * 8, 4, arrival=3.0)
    u.queues["a"].append(later)
    r1 = Request(1, "a", [1] * 8, 4, arrival=1.0)
    r2 = Request(2, "a", [1] * 8, 4, arrival=2.0)
    r0 = Request(0, "a", [1] * 8, 4, arrival=0.5)
    eng.preempted.extend([r2, r0, r1])     # scrambled eviction order
    u._harvest()
    assert [r.req_id for r in u.queues["a"]] == [0, 1, 2, 9]


def test_watchdog_terminates_hard_stall():
    """A unit that makes zero progress forever must not hang the
    serving loop: after ``watchdog_ticks`` busy ticks the watchdog
    sheds everything pending and the run ends with submitted =
    finished + shed."""
    class WedgedScheduler(MuxScheduler):
        def tick(self):
            self.stats.ticks += 1          # burns a tick, moves nothing

    base, _ = _unit()
    u = WedgedScheduler(base.engines, base.pool, policy="adbs", fused=False)
    reqs = _requests(n_a=3, n_b=1)
    rep = serve_requests([u], reqs, slo_scales=(2.0,), cost=COST,
                         watchdog_ticks=5)
    assert rep.aggregate.finished == 0
    assert rep.aggregate.shed == len(reqs)
    assert rep.aggregate.submitted == rep.aggregate.finished \
        + rep.aggregate.shed
    assert rep.faults is not None and rep.faults.watchdog_trips >= 1
    assert any(ev["kind"] == "watchdog" for ev in rep.faults.log)
    assert all(r.shed_reason == "watchdog" for r in reqs)


# ---------------------------------------------------------------------------
# driver integration: counters, clock charging, determinism
# ---------------------------------------------------------------------------
def test_driver_charges_recovery_and_reports_counters():
    u, _ = _unit()
    reqs = _requests()
    plan = FaultPlan.parse("crash:a@0.02")
    rc = RecoveryCostModel()
    rep = serve_requests([u], reqs, slo_scales=(2.0, 8.0), cost=COST,
                         faults=plan, recovery_cost=rc)
    fs = rep.faults
    assert fs is not None and fs.recoveries == 1 and fs.unfired == 0
    assert fs.dt_charged >= rc.base, "recovery stall must hit the clock"
    agg = rep.aggregate
    assert agg.submitted == agg.finished + agg.shed == len(reqs)
    assert agg.retried >= 1 and agg.recovered >= 1
    assert "shed=" in rep.summary() and "faults:" in rep.summary()
    j = rep.to_json()
    assert j["faults"]["recoveries"] == 1
    assert j["per_llm"]["a"]["retried"] >= 1


def test_faulted_run_deterministic():
    """Same plan + fresh unit ⇒ bit-identical faulted report: the
    injector holds no RNG and fault costs are fixed by the event."""
    def run():
        u, _ = _unit()
        reqs = _requests()
        return serve_requests(
            [u], reqs, slo_scales=(2.0, 8.0), cost=COST,
            faults=FaultPlan.parse("crash:a@0.02,block_loss:b:128@0.04"))
    a, b = run(), run()
    assert a.horizon == b.horizon and a.ticks == b.ticks
    assert a.aggregate.attainment == b.aggregate.attainment
    assert a.faults.dt_charged == b.faults.dt_charged
    assert a.faults.to_json()["log"] == b.faults.to_json()["log"]
