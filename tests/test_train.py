"""Training substrate: optimizer, data pipeline, checkpointing, and a
real short training run that must reduce loss."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, synth_batch
from repro.train.optimizer import (AdamWConfig, apply_updates,
                                   clip_by_global_norm, cosine_lr,
                                   init_state)
from repro.train.train_step import make_eval_step, make_train_step


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
    assert np.isclose(float(cosine_lr(cfg, jnp.int32(10))), 1e-3)
    mid = float(cosine_lr(cfg, jnp.int32(60)))
    assert 1e-4 < mid < 1e-3
    end = float(cosine_lr(cfg, jnp.int32(110)))
    assert np.isclose(end, 1e-4, rtol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), np.sqrt(90 + 160))
    cn = float(jnp.sqrt(sum((x ** 2).sum() for x in jax.tree.leaves(clipped))))
    assert np.isclose(cn, 1.0, rtol=1e-5)
    # below threshold → untouched
    c2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(g["a"]))


def test_adamw_decay_mask():
    """Norm/bias/scalar leaves must not get weight decay: with zero
    grads, matrices shrink, norms stay."""
    params = {"w_gate": jnp.ones((4, 4)), "ln1": jnp.ones((4,))}
    state = init_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.5, warmup_steps=0,
                      total_steps=10)
    p2, _, _ = apply_updates(params, grads, state, cfg)
    assert float(p2["w_gate"].mean()) < 1.0
    assert float(jnp.abs(p2["ln1"] - 1.0).max()) == 0.0


def test_data_deterministic_and_learnable():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=7)
    t1, l1, _ = synth_batch(cfg, 3)
    t2, l2, _ = synth_batch(cfg, 3)
    np.testing.assert_array_equal(t1, t2)
    t3, _, _ = synth_batch(cfg, 4)
    assert not np.array_equal(t1, t3)
    assert l1.shape == t1.shape
    assert (l1[:, -1] == -100).all()
    # the markov structure: most transitions follow next=(a*cur+b)%V
    match = (l1[:, :-1] == t1[:, 1:]).mean()
    assert match > 0.99


def test_prefix_stub():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=2, seed=0,
                     frontend_dim=16, n_prefix_tokens=4)
    _, _, prefix = synth_batch(cfg, 0)
    assert prefix.shape == (2, 4, 16)


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m",
                                  "mamba2-2.7b", "zamba2-1.2b",
                                  "musicgen-medium"])
def test_loss_decreases(arch):
    """~40 steps on the reduced config must cut the loss vs step 0
    (the data has learnable Markov structure)."""
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                      grad_clip=1.0)
    state = init_state(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=8, seed=1, n_patterns=2,
                      frontend_dim=cfg.frontend_dim,
                      n_prefix_tokens=cfg.n_prefix_tokens)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    losses = []
    for i in range(40):
        toks, labels, prefix = synth_batch(dcfg, i)
        args = [params, state, jnp.asarray(toks), jnp.asarray(labels)]
        if prefix is not None:
            args.append(jnp.asarray(prefix))
        params, state, m = step(*args)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < losses[0] - 0.5,\
        f"{arch}: {losses[0]:.3f} → {np.mean(losses[-5:]):.3f}"


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_reduced("qwen2-7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    state = init_state(params)
    tree = {"params": params, "opt": state}
    path = ckpt.save(str(tmp_path), tree, step=12, extra={"note": "hi"})
    assert os.path.isdir(path)
    like = {"params": init_params(jax.random.PRNGKey(9), cfg, jnp.float32),
            "opt": init_state(params)}
    restored, step, extra = ckpt.restore(str(tmp_path), like)
    assert step == 12 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_checkpoint_resume_continues_training(tmp_path):
    """Save at step N, restore, keep training — loss stays sane and the
    optimizer step counter continues."""
    cfg = configs.get_reduced("qwen2-7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    state = init_state(params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=4, seed=2)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))
    for i in range(3):
        t, l, _ = synth_batch(dcfg, i)
        params, state, _ = step_fn(params, state, jnp.asarray(t),
                                   jnp.asarray(l))
    ckpt.save(str(tmp_path), {"p": params, "o": state}, step=3)
    like = {"p": params, "o": init_state(params)}
    restored, st, _ = ckpt.restore(str(tmp_path), like)
    assert int(restored["o"].step) == 3
    t, l, _ = synth_batch(dcfg, 3)
    _, _, m = step_fn(restored["p"], restored["o"], jnp.asarray(t),
                      jnp.asarray(l))
    assert np.isfinite(float(m["loss"]))


def test_eval_step():
    cfg = configs.get_reduced("qwen3-14b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=2, seed=0)
    t, l, _ = synth_batch(dcfg, 0)
    m = jax.jit(make_eval_step(cfg))(params, jnp.asarray(t), jnp.asarray(l))
    assert np.isfinite(float(m["loss"]))
