"""Assigned-architecture configs: exact fields, derived quantities,
tensor-parallel geometry."""

import pytest

from repro import configs
from repro.config import SHAPES
from repro.launch.sharding import physical_config

from conftest import ALL_ARCHS

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table
ASSIGNED = {
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_assigned_fields(arch):
    cfg = configs.get(arch)
    L, d, h, kv, f, v = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == f
    assert cfg.vocab_size == v
    assert cfg.source, "every config must cite its source"


def test_moe_fields():
    g = configs.get("granite-moe-3b-a800m")
    assert g.moe.n_experts == 32 and g.moe.top_k == 8
    q = configs.get("qwen3-moe-235b-a22b")
    assert q.moe.n_experts == 128 and q.moe.top_k == 8


def test_ssm_fields():
    m = configs.get("mamba2-2.7b")
    assert m.ssm.d_state == 128 and m.family == "ssm"
    z = configs.get("zamba2-1.2b")
    assert z.ssm.d_state == 64 and z.family == "hybrid"
    assert z.shared_attn and z.attn_every == 6


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_scale(arch):
    """Analytic parameter counts land in the model's nominal bucket."""
    expected = {
        "musicgen-medium": (1.1e9, 2.2e9),
        "qwen2-7b": (6e9, 8.5e9),
        "granite-moe-3b-a800m": (2e9, 4e9),
        "zamba2-1.2b": (0.9e9, 1.9e9),
        "qwen3-14b": (12e9, 16.5e9),
        "phi-3-vision-4.2b": (3.3e9, 4.6e9),
        "command-r-plus-104b": (90e9, 115e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "qwen3-moe-235b-a22b": (200e9, 250e9),
        "deepseek-coder-33b": (28e9, 36e9),
    }[arch]
    n = configs.get(arch).param_count()
    assert expected[0] <= n <= expected[1], f"{arch}: {n:.3e}"


def test_active_params_moe():
    q = configs.get("qwen3-moe-235b-a22b")
    act = q.active_param_count()
    assert 15e9 <= act <= 30e9, f"A22B point: {act:.3e}"
    assert act < q.param_count() / 5


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_variants(arch):
    r = configs.get_reduced(arch)
    assert r.n_layers <= 4
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.n_experts <= 4
    assert r.family == configs.get(arch).family


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("tp", [8, 16])
def test_tp_geometry_divides(arch, tp):
    cfg = configs.get(arch)
    if cfg.family == "ssm":
        assert cfg.d_inner // cfg.ssm.head_dim % tp == 0
        return
    p = physical_config(cfg, tp)
    assert p.n_heads % tp == 0
    assert p.n_kv_heads % tp == 0
    assert p.n_heads % p.n_kv_heads == 0
    assert p.hd == cfg.hd
    # padding never more than 2× q-head waste
    assert p.n_heads <= 2 * max(cfg.n_heads, cfg.n_kv_heads)


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_kv_bytes_per_token():
    q = configs.get("qwen2-7b")
    # 2 (k,v) × 28 L × 4 kv × 128 hd × 2 B
    assert q.kv_bytes_per_token() == 2 * 28 * 4 * 128 * 2
    m = configs.get("mamba2-2.7b")
    assert m.kv_bytes_per_token() == 0
