"""muxlint static passes + runtime invariant sanitizer (PR 10).

One positive (violation detected) and one negative (idiomatic code
stays clean) fixture per static pass, the suppression machinery
(inline pragma with mandatory reason, reviewed baseline, stale-entry
failure), CLI exit codes, and the sanitizer's corruption detectors —
each planted corruption must raise ``SanitizeError`` naming the law
it broke, and a clean sanitized run must be bit-identical to an
unsanitized one (modulo the wall-clock diagnostic).
"""
import json
import textwrap

import numpy as np
import pytest

from repro.serving.driver import (LogicalClock, TickCostModel,
                                  ServeSession, build_unit_from_specs,
                                  serve_requests)
from repro.serving.engine import Request
from repro.serving.sanitize import (PoolSanitizer, SanitizeError,
                                    SchedulerSanitizer)
from tools.muxlint.core import (Source, all_passes, lint_paths,
                                load_baseline, match_baseline)
from tools.muxlint.__main__ import main as muxlint_main

COST = TickCostModel()


# ---------------------------------------------------------------------------
# static passes: one positive + one negative fixture each
# ---------------------------------------------------------------------------
def _lint(text, path="src/repro/serving/x.py", select=None):
    src = Source.parse(path, textwrap.dedent(text))
    passes = all_passes()
    if select:
        passes = {k: v for k, v in passes.items() if k in select}
    out = []
    for fn in passes.values():
        out.extend(f for f in fn(src) if not src.suppressed(f))
    return out


def test_layering_flags_upward_import():
    bad = _lint("from repro.serving.mux import MuxScheduler\n",
                path="src/repro/kernels/paged.py")
    assert [f.rule for f in bad] == ["layering"]
    assert "kernels -> serving" in bad[0].message


def test_layering_allows_declared_edges():
    assert not _lint("import repro.paging\nfrom repro.config import replace\n",
                     path="src/repro/kernels/paged.py")
    assert not _lint("from repro.core.estimator import estimate\n",
                     path="src/repro/serving/mux.py")
    # files outside repro/ (tools, tests) are unconstrained
    assert not _lint("from repro.launch.serve import main\n",
                     path="tools/muxlint/x.py")


def test_clock_flags_wallclock_in_serving():
    bad = _lint("""\
        import time
        def tick(self):
            return time.perf_counter()
        """)
    assert [f.rule for f in bad] == ["clock"]
    assert "perf_counter" in bad[0].message
    bad = _lint("from time import monotonic\n",
                path="src/repro/core/simulator.py")
    assert [f.rule for f in bad] == ["clock"]


def test_clock_exemptions():
    # a WallClock class is the one structural owner of wall time
    assert not _lint("""\
        import time
        class WallClock:
            def __call__(self):
                return time.perf_counter()
        """)
    # outside serving/core the clock pass does not apply
    assert not _lint("import time\nt = time.time()\n",
                     path="src/repro/launch/bench.py")


def test_rng_flags_unseeded_draws():
    bad = _lint("""\
        import numpy as np
        import random
        a = np.random.default_rng()
        b = np.random.uniform()
        c = random.random()
        """)
    assert [f.rule for f in bad] == ["rng"] * 3
    assert "explicit seed" in bad[0].message


def test_rng_allows_seeded_generators():
    assert not _lint("""\
        import numpy as np
        import jax
        rng = np.random.default_rng(0)
        x = rng.uniform()
        key = jax.random.PRNGKey(0)
        y = jax.random.uniform(key)
        """)


def test_jit_hazard_flags_host_escapes():
    bad = _lint("""\
        def decode_impl(q, lens):
            n = int(lens)
            q.item()
            if lens > 0:
                print(q)
            return q if lens else n
        """, select={"jit-hazard"})
    rules = sorted(f.message.split("`")[1] for f in bad)
    assert len(bad) == 5
    assert any(".item" in f.message or "item" in f.message for f in bad)
    assert any("retraces" in f.message for f in bad)
    assert any("ternary" in f.message for f in bad)
    assert rules  # each message names the offending construct


def test_jit_hazard_static_kwargs_and_plain_functions_ok():
    # kw-only params are the static-config convention — not traced
    assert not _lint("""\
        def step_impl(x, *, cfg):
            if cfg.fused:
                return x + 1
            return x
        """, select={"jit-hazard"})
    # host code that is never jitted is out of scope
    assert not _lint("""\
        def summarize(report):
            n = int(report.ticks)
            if n > 0:
                print(n)
            return n
        """, select={"jit-hazard"})


def test_jit_hazard_scopes_jax_jit_targets():
    bad = _lint("""\
        import jax
        def fwd(x):
            return int(x)
        f = jax.jit(fwd)
        """, select={"jit-hazard"})
    assert len(bad) == 1 and "concretizes" in bad[0].message


def test_dead_assert_flags():
    bad = _lint("""\
        def f(x, q):
            assert x == 1 or True
            assert x == x
            assert True
            assert (x, "message")
            assert (y := x) > 0
            assert q.pop() is not None
        """, path="src/repro/serving/y.py", select={"dead-assert"})
    assert len(bad) == 6
    msgs = " | ".join(f.message for f in bad)
    for frag in ("tautological", "self-comparison", "truthy constant",
                 "non-empty tuple", "walrus", "side-effecting"):
        assert frag in msgs, frag


def test_dead_assert_negative():
    assert not _lint("""\
        def f(x, items):
            assert x > 0, "positive"
            assert x == len(items)
            if x > 10:
                assert False, "unreachable"
        """, select={"dead-assert"})


# ---------------------------------------------------------------------------
# suppression machinery
# ---------------------------------------------------------------------------
def test_pragma_needs_a_reason():
    justified = "import time\nt = time.time()  # muxlint: ok[clock] probe\n"
    bare = "import time\nt = time.time()  # muxlint: ok[clock]\n"
    src = Source.parse("src/repro/serving/x.py", justified)
    f = next(iter(all_passes()["purity"](src)))
    assert src.suppressed(f)
    src = Source.parse("src/repro/serving/x.py", bare)
    f = next(iter(all_passes()["purity"](src)))
    assert not src.suppressed(f), "a pragma without a reason is inert"


def test_baseline_match_and_stale_split():
    src = Source.parse("src/repro/serving/x.py",
                       "import time\nt = time.time()\n")
    findings = list(all_passes()["purity"](src))
    hit = {"rule": "clock", "path": "src/repro/serving/x.py",
           "line_text": "t = time.time()", "why": "reviewed"}
    stale = {"rule": "clock", "path": "src/repro/serving/gone.py",
             "line_text": "t = time.time()", "why": "reviewed"}
    kept, stale_out = match_baseline(findings, [hit, stale])
    assert kept == [] and stale_out == [stale]


def test_baseline_rejects_missing_why(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suppressions": [
        {"rule": "clock", "path": "a.py", "line_text": "x", "why": ""}]}))
    with pytest.raises(ValueError, match="why"):
        load_baseline(str(p))


def test_cli_exit_codes(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text("assert True\n")
    assert muxlint_main([str(clean), "--no-baseline"]) == 0
    assert muxlint_main([str(dirty), "--no-baseline"]) == 1
    stale = tmp_path / "baseline.json"
    stale.write_text(json.dumps({"suppressions": [
        {"rule": "clock", "path": "nope.py", "line_text": "z = 1",
         "why": "obsolete"}]}))
    assert muxlint_main([str(clean), "--baseline", str(stale)]) == 2


def test_cli_nonzero_per_violation_class(tmp_path):
    """One planted violation per pass, each through the real CLI."""
    plants = {
        "kernels/bad_layer.py": "from repro.serving import mux\n",
        "serving/bad_clock.py": "import time\nt = time.time()\n",
        "serving/bad_jit.py": "def step_impl(x):\n    return int(x)\n",
        "serving/bad_assert.py": "def f(x):\n    assert x or True\n",
    }
    for rel, code in plants.items():
        root = tmp_path / rel.replace("/", "_")
        target = root / "src" / "repro" / rel
        target.parent.mkdir(parents=True)
        target.write_text(code)
        assert muxlint_main([str(target), "--root", str(root),
                             "--no-baseline"]) == 1, rel


def test_repo_src_is_clean():
    """The CI gate: the shipped tree has zero unsuppressed findings
    and no stale baseline entries."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    assert muxlint_main(["src", "--root", str(root)]) == 0


def test_lint_paths_reports_parse_errors_nonfatal(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    (tmp_path / "fine.py").write_text("assert True\n")
    kept, _sup, errors = lint_paths([str(tmp_path)])
    assert len(errors) == 1 and "broken.py" in errors[0]
    assert any(f.rule == "dead-assert" for f in kept), \
        "a syntax error in one file must not mask findings in others"


# ---------------------------------------------------------------------------
# runtime sanitizer: clean runs pass, planted corruption is caught
# ---------------------------------------------------------------------------
def _unit(**kw):
    u = build_unit_from_specs(
        [("a", "qwen2-7b", 3.0), ("b", "qwen2-7b", 1.0)],
        pool_blocks=4_000, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=True, **kw)
    clock = LogicalClock()
    u.clock = clock
    for e in u.engines.values():
        e.clock = clock
    return u


def _requests(n_a=3, n_b=2, plen=16, out=3):
    rng = np.random.default_rng(5)
    reqs = [Request(i, "a", list(rng.integers(1, 500, plen)), out,
                    arrival=0.0) for i in range(n_a)]
    reqs += [Request(100 + i, "b", list(rng.integers(1, 500, plen)), out,
                     arrival=0.0) for i in range(n_b)]
    return reqs


def test_pool_sanitizer_clean_then_corrupted():
    from repro import configs
    from repro.serving.kvcache import BLOCK_TOKENS, UnifiedKVPool
    pool = UnifiedKVPool(2_048, 64)
    cfg = configs.get_reduced("qwen2-7b")
    view = pool.register_model(cfg, quota=2_048)
    assert view.append_tokens(0, BLOCK_TOKENS * 2)
    san = PoolSanitizer(pool)
    san.check("clean")

    pool.allocator.used += 3                     # refcount-weighted law
    with pytest.raises(SanitizeError, match="refcount-weighted"):
        san.check("corrupted")
    pool.allocator.used -= 3
    san.check("restored")

    view.used += 1                               # view charge law
    with pytest.raises(SanitizeError, match="recomputed"):
        san.check("view-corrupted")
    view.used -= 1


def test_pool_sanitizer_detects_free_live_overlap():
    from repro import configs
    from repro.serving.kvcache import BLOCK_TOKENS, UnifiedKVPool
    pool = UnifiedKVPool(2_048, 64)
    cfg = configs.get_reduced("qwen2-7b")
    view = pool.register_model(cfg, quota=2_048)
    assert view.append_tokens(0, BLOCK_TOKENS)
    base = view.seqs[0].bases[0]
    # plant a live block on the free list (a double-free would do this)
    pool.allocator._free.insert(0, (base, base + 1))
    with pytest.raises(SanitizeError, match="free and live|covers"):
        PoolSanitizer(pool).check("double-free")


def test_scheduler_sanitizer_grant_algebra():
    u = _unit()
    san = SchedulerSanitizer(u)
    assert u.sanitizer is san, "attach installs the fault-report hook"
    san.check("clean")
    u._grant_debt += 5                           # phantom debt
    with pytest.raises(SanitizeError, match="grant algebra"):
        san.check("debt-corrupted")
    u._grant_debt -= 5
    san.check("restored")


def test_session_sanitizer_clean_run_and_parity():
    """A sanitized deterministic run completes with every tick checked
    and produces a bit-identical report (the sanitizer is a pure
    reader) — wall_s is the one real-wall-time diagnostic field."""
    reqs = _requests()
    reports = []
    for sanitize in (False, True):
        u = _unit()
        rep = serve_requests([u], [Request(r.req_id, r.model,
                                           list(r.prompt),
                                           r.max_new_tokens,
                                           arrival=r.arrival)
                                   for r in reqs],
                             cost=COST, warm=False, sanitize=sanitize)
        d = rep.to_json()
        d.pop("wall_s")
        reports.append(d)
    assert reports[0] == reports[1], \
        "sanitizer must not perturb scheduling"


def test_session_sanitizer_detects_silently_lost_request():
    u = _unit()
    sess = ServeSession([u], _requests(), cost=COST, warm=False,
                        sanitize=True)
    assert sess.sanitizer is not None
    status, _ = sess.step()                      # submits + first tick
    assert status == "tick"
    # vanish one held request: not finished/shed/cancelled, yet in no
    # queue, slot, or preempt buffer — the silent-loss bug class
    for q in u.queues.values():
        if q:
            q.popleft()
            break
    else:
        for eng in u.engines.values():
            for i, r in enumerate(eng.slots):
                if r is not None:
                    eng.slots[i] = None
                    break
    with pytest.raises(SanitizeError, match="SILENTLY LOST"):
        sess.sanitizer.check("after-theft")
