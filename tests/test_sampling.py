"""Sampling properties."""
import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.serving.sampling import SamplingConfig, sample


def test_greedy():
    logits = jnp.array([[0.1, 3.0, -1.0], [2.0, 0.0, 5.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplingConfig())
    np.testing.assert_array_equal(np.asarray(out), [1, 2])


def test_top_k_masks():
    logits = jnp.array([[0.0, 1.0, 2.0, 3.0]])
    cfg = SamplingConfig(temperature=1.0, top_k=2)
    outs = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
            for i in range(64)}
    assert outs <= {2, 3}


def test_top_p_masks():
    # one dominant token: p=0.9 keeps only it
    logits = jnp.array([[10.0, 0.0, 0.0, 0.0]])
    cfg = SamplingConfig(temperature=1.0, top_p=0.9)
    outs = {int(sample(logits, jax.random.PRNGKey(i), cfg)[0])
            for i in range(32)}
    assert outs == {0}


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_sample_in_vocab(seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (3, 17))
    for cfg in (SamplingConfig(), SamplingConfig(temperature=0.7),
                SamplingConfig(temperature=1.0, top_k=5),
                SamplingConfig(temperature=1.0, top_p=0.8)):
        out = sample(logits, key, cfg)
        assert out.shape == (3,)
        assert ((np.asarray(out) >= 0) & (np.asarray(out) < 17)).all()


def test_temperature_sharpens():
    logits = jnp.array([[0.0, 1.0]])
    hot = sum(int(sample(logits, jax.random.PRNGKey(i),
                         SamplingConfig(temperature=5.0))[0])
              for i in range(200))
    cold = sum(int(sample(logits, jax.random.PRNGKey(i),
                          SamplingConfig(temperature=0.1))[0])
               for i in range(200))
    assert cold >= hot  # low temperature picks argmax more often
