"""Prefix caching in the unified KV pool (DESIGN.md §13): shared-
prefix decode bit-identical to the unshared run (fused and serial),
partial-hit prefill resuming at the right chunk, copy-on-write
divergence, and index invalidation on block loss / crash recovery."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import BLOCK_TOKENS, replace
from repro.serving.driver import LogicalClock, build_unit_from_specs
from repro.serving.engine import Request
from repro.serving.kvcache import UnifiedKVPool

PREF = None  # filled lazily by _prefix()


def _prefix(n_blocks=2):
    rng = np.random.default_rng(9)
    return list(rng.integers(1, 500, n_blocks * BLOCK_TOKENS))


def _unit(cache: bool, fused: bool = True, clock=None, pool_blocks=6_000):
    u = build_unit_from_specs(
        [("a", "qwen2-7b", 2.0), ("b", "qwen2-7b", 1.0)],
        pool_blocks=pool_blocks, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=fused, prefix_cache=cache)
    clock = clock or LogicalClock()
    u.clock = clock
    for e in u.engines.values():
        e.clock = clock
    return u, clock


def _drain(u, max_ticks=800):
    for _ in range(max_ticks):
        if not u.pending():
            return
        u.tick()
        u.clock.advance(0.005)
    raise AssertionError("unit did not drain")


def _sharer_reqs(pref, n=3, tail=8, out=6):
    rng = np.random.default_rng(13)
    return [Request(1 + i, "a",
                    pref + list(rng.integers(1, 500, tail)), out,
                    arrival=0.0)
            for i in range(n)]


def _run_schedule(cache: bool, fused: bool):
    """Donor first (populates the index), then three sharers — the
    exact same submissions against a cached and an uncached unit."""
    pref = _prefix()
    u, _ = _unit(cache, fused=fused)
    donor = Request(0, "a", pref + [7, 7, 7, 7], 6, arrival=0.0)
    u.submit(donor)
    _drain(u)
    for r in _sharer_reqs(pref):
        u.submit(r)
    _drain(u)
    out = {r.req_id: list(r.output) for r in u.stats.finished}
    return u, out


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "serial"])
def test_shared_prefix_decode_bit_identical(fused):
    """Decoding on adopted (shared, read-only) prefix blocks produces
    exactly the tokens the unshared run produces — the cached KV pages
    are the pages prefill would have written."""
    u_ref, ref = _run_schedule(cache=False, fused=fused)
    u_hit, hit = _run_schedule(cache=True, fused=fused)
    assert set(ref) == set(hit) == {0, 1, 2, 3}
    assert ref == hit, "shared-prefix outputs must be bit-identical"
    stats = u_hit.prefix_stats()["a"]
    assert stats["hits"] == 3, "all three sharers must adopt the prefix"
    assert stats["hit_tokens"] == 3 * 2 * BLOCK_TOKENS
    assert u_ref.prefix_stats() == {}, "cache off → no counters"
    # the unit drained: only the index holds blocks now
    pool = u_hit.pool
    assert pool.allocator.used == sum(
        v.prefix_index.held_blocks for v in pool.views.values()
        if v.prefix_index is not None)


def test_partial_hit_resumes_at_right_chunk():
    """A prompt whose first two blocks are cached starts prefill at
    token 32: the chunk job's offset says so, the sequence is born
    with the adopted tokens counted, and stamping still happens at
    prompt completion."""
    pref = _prefix()                      # 32 tokens = 2 full blocks
    u, _ = _unit(cache=True)
    donor = Request(0, "a", pref + [7, 7, 7, 7], 6, arrival=0.0)
    u.submit(donor)
    _drain(u)
    eng = u.engines["a"]
    idx = eng.view.prefix_index
    assert len(idx) == 2 and idx.inserted == 2, \
        "the donor's two full prompt blocks must be indexed"

    r = _sharer_reqs(pref, n=1)[0]        # 40-token prompt, lcp 32
    eng.admit_chunked([r])
    sid = r._seq_id
    sc = eng.view.seqs[sid]
    assert sc.shared == 2 and sc.n_tokens == 40, \
        "adopted blocks + reserved remainder, read-only prefix marked"
    assert list(eng._prefilling.values()) == [32], \
        "prefill must resume at the first uncached token"
    job = eng.export_prefill_job()
    assert list(job.offs) == [32] and list(job.clens) == [8]
    assert idx.hits == 1 and idx.hit_tokens == 32
    assert r.prefill_done < 0 and r.first_token < 0, \
        "partial hits must not pre-stamp completion times"
    _drain(u)
    assert r.first_token >= 0 and len(r.output) == 6


def test_adoption_clamped_below_full_prompt():
    """A prompt that IS a cached chain (length an exact block
    multiple) adopts one block less — prefill must still compute the
    last token's logits for the first generated token."""
    pref = _prefix()
    u, _ = _unit(cache=True)
    u.submit(Request(0, "a", pref, 4, arrival=0.0))
    _drain(u)
    eng = u.engines["a"]
    r = Request(1, "a", list(pref), 4, arrival=0.0)
    eng.admit_chunked([r])
    assert list(eng._prefilling.values()) == [BLOCK_TOKENS], \
        "adopt only ⌊(len−1)/BT⌋ blocks: the last block is recomputed"
    _drain(u)
    ref_u, _ = _unit(cache=False)
    ref_u.submit(Request(0, "a", pref, 4, arrival=0.0))
    ref_u.submit(Request(1, "a", list(pref), 4, arrival=0.0))
    _drain(ref_u)
    assert {q.req_id: list(q.output) for q in u.stats.finished} \
        == {q.req_id: list(q.output) for q in ref_u.stats.finished}


# ---------------------------------------------------------------------------
# copy-on-write at the view + cache_ops level
# ---------------------------------------------------------------------------
def _crafted_view():
    # tiny head_dim keeps the crafted arena small; the cfg must match
    # it now that register_model actually validates head_dim (PR 10)
    pool = UnifiedKVPool(256, 8, dtype=jnp.float32)
    cfg = replace(configs.get_reduced("qwen2-7b"), head_dim=8)
    view = pool.register_model(cfg, quota=10**6)
    assert view.append_tokens(0, BLOCK_TOKENS)    # donor: one full block
    base = view.seqs[0].bases[0]
    gs = view.group_size
    pool.k = pool.k.at[base:base + gs].set(1.0)
    pool.v = pool.v.at[base:base + gs].set(2.0)
    return pool, view, base, gs


def test_cow_divergence_independent_continuations():
    """A write landing inside a shared tail block triggers COW: the
    sharer gets a private, bit-identical copy of the donor's pages and
    subsequent writes never leak across."""
    pool, view, base, gs = _crafted_view()
    assert view.share_prefix(1, [base], 8)        # adopt half the block
    assert pool.allocator.refcount(base) == 2
    assert view.used == 2 * gs, "full charge per sharer (DESIGN.md §13)"
    assert view.append_tokens(1, 1)               # write → COW
    sc = view.seqs[1]
    new = sc.bases[0]
    assert new != base and sc.shared == 0
    assert pool.allocator.refcount(base) == 1
    assert pool.allocator.refcount(new) == 1
    assert np.array_equal(np.asarray(pool.k[new:new + gs]),
                          np.asarray(pool.k[base:base + gs]))
    assert np.array_equal(np.asarray(pool.v[new:new + gs]),
                          np.asarray(pool.v[base:base + gs]))
    # diverge the private copy — the donor's pages stay untouched
    pool.k = pool.k.at[new].add(5.0)
    assert (np.asarray(pool.k[base:base + gs]) == 1.0).all()
    assert not np.array_equal(np.asarray(pool.k[new:new + gs]),
                              np.asarray(pool.k[base:base + gs]))
    assert view.used == 2 * gs, "COW costs physical blocks, not quota"
    view.free_seq(0)
    view.free_seq(1)
    assert pool.allocator.used == 0


def test_cow_unshare_in_place_when_sole_holder():
    """When the donor is gone before the sharer writes, the refcount
    is 1 and COW degenerates to an in-place unshare — no copy, no new
    allocation."""
    pool, view, base, gs = _crafted_view()
    assert view.share_prefix(1, [base], 8)
    view.free_seq(0)                              # donor leaves first
    assert pool.allocator.refcount(base) == 1
    free_before = pool.allocator.free_blocks
    assert view.append_tokens(1, 1)
    sc = view.seqs[1]
    assert sc.bases[0] == base and sc.shared == 0, "unshare in place"
    assert pool.allocator.free_blocks == free_before
    view.free_seq(1)
    assert pool.allocator.used == 0


def test_share_prefix_full_quota_charge_enforced():
    pool, view, base, gs = _crafted_view()
    view.quota = gs                               # donor already uses it
    assert not view.share_prefix(1, [base], 8), \
        "a sharer over quota must be refused (full-charge policy)"
    assert pool.allocator.refcount(base) == 1 and 1 not in view.seqs


# ---------------------------------------------------------------------------
# index lifecycle: block loss, crash recovery, eviction under pressure
# ---------------------------------------------------------------------------
def _no_dangling(pool):
    for v in pool.views.values():
        if v.prefix_index is None:
            continue
        for _, (b, _) in v.prefix_index.entries():
            assert b + v.group_size <= pool.n_head_blocks, \
                "index entry points past the shrunk arena"
            assert pool.allocator.refcount(b) >= 1, \
                "index entry holds no ref — dangling base"


def test_block_loss_invalidates_doomed_index_entries():
    """A tail loss with a live sharer mid-flight: the sharer is
    evicted (every sharer of a doomed block is a victim), doomed index
    entries are dropped, the shrink removes exactly the lost blocks
    and no dangling base survives."""
    pref = _prefix()
    u, clock = _unit(cache=True)
    pool = u.pool
    # pin the arena front so the cached blocks land high: the doomed
    # tail then contains them while capacity survives the loss
    hog = pool.allocator.alloc(3_000)
    assert hog == 0
    u.submit(Request(0, "a", pref + [7, 7, 7, 7], 6, arrival=0.0))
    _drain(u)
    sharer = _sharer_reqs(pref, n=1)[0]
    u.submit(sharer)
    for _ in range(3):                     # adopt + get into flight
        u.tick()
        clock.advance(0.005)
    idx = u.engines["a"].view.prefix_index
    assert len(idx) == 2 and idx.hits == 1
    shared_bases = {b for _, (b, _) in idx.entries()}
    n_before = pool.n_head_blocks
    n_lose = n_before - min(min(shared_bases),
                            min(b for v in pool.views.values()
                                for sc in v.seqs.values()
                                for b in sc.bases))
    rec = u._lose_blocks(n_lose)
    assert rec["blocks"] == n_lose, \
        "victim eviction + index drop must free the exact doomed tail"
    assert pool.n_head_blocks == n_before - n_lose
    assert rec["requeued"] >= 1, "the live sharer is a victim"
    assert len(idx) == 0 and idx.evicted >= 2
    _no_dangling(pool)
    assert pool.allocator.used \
        == sum(v.used for v in pool.views.values()) + 3_000
    pool.allocator.free(hog, 3_000)        # release the pin, then drain
    _drain(u)
    assert {r.req_id for r in u.stats.finished} == {0, sharer.req_id}, \
        "zero drops after the loss"


def test_crash_recovery_clears_index_without_leaks():
    pref = _prefix()
    u, _ = _unit(cache=True)
    u.submit(Request(0, "a", pref + [7, 7, 7, 7], 6, arrival=0.0))
    _drain(u)
    pool = u.pool
    assert len(u.engines["a"].view.prefix_index) == 2
    assert pool.allocator.used > 0         # index inventory only
    u.recover_engine("a", reason="crash")
    idx = u.engines["a"].view.prefix_index
    assert idx is not None and len(idx) == 0, \
        "recovery must re-arm an EMPTY index (pool-level flag)"
    assert pool.allocator.used == 0, "the dead view's index refs died too"
    _no_dangling(pool)
    for r in _sharer_reqs(pref, n=2):      # cold cache still serves
        u.submit(r)
    _drain(u)
    assert len(u.stats.finished) == 3


def test_index_evicted_under_allocation_pressure():
    """Cached inventory is disposable: when the arena cannot fit a new
    sequence, LRU index entries are evicted instead of refusing
    admission (``available_blocks`` counts them; ``reclaim`` frees
    them)."""
    pool = UnifiedKVPool(8 * 4, 8, dtype=jnp.float32, prefix_cache=True)
    cfg = replace(configs.get_reduced("qwen2-7b"), head_dim=8)
    view = pool.register_model(cfg, quota=10**6)
    gs = view.group_size                   # 4 → arena holds 8 groups
    rng = np.random.default_rng(3)
    for sid in range(8):                   # fill the arena with cache
        prompt = list(rng.integers(1, 500, BLOCK_TOKENS))
        assert view.append_tokens(sid, BLOCK_TOKENS)
        view.prefix_index.insert(prompt, view.seqs[sid].bases)
        view.free_seq(sid)
    assert pool.allocator.free_blocks == 0
    assert pool.available_blocks() == 8 * gs, "inventory is evictable"
    assert view.can_append(100, BLOCK_TOKENS)
    assert view.append_tokens(100, 2 * BLOCK_TOKENS), \
        "allocation pressure must evict LRU entries, not fail"
    assert len(view.prefix_index) == 6 and view.prefix_index.evicted == 2
    _no_dangling(pool)
    view.free_seq(100)
    view.prefix_index.clear()
    assert pool.allocator.used == 0
