"""Discrete-event simulator: conservation, baselines ordering, SLO
monotonicity — the substrate of the paper's end-to-end claims."""
import pytest

from repro.core.placement import place, place_spatial
from repro.core.simulator import UnitSim, simulate
from repro.core.workload import llama_config, synthesize
from repro.core.estimator import LLMSpec


def _models(n=4, alpha=1.3, max_rate=6.0):
    names = ["llama-7b", "llama-7b", "llama-13b", "llama-30b"][:n]
    cfgs = [llama_config(nm, f"-{i}") for i, nm in enumerate(names)]
    rates = [max_rate * (i + 1) ** -alpha for i in range(n)]
    return list(zip(cfgs, rates))


def _workload(models, horizon=60.0, seed=0):
    names = [cfg.name for cfg, _ in models]
    wl = synthesize(names, alpha=1.3, max_rate=max(r for _, r in models),
                    horizon=horizon, seed=seed)
    wl.rates = {cfg.name: r for cfg, r in models}
    return wl


@pytest.fixture(scope="module")
def setting():
    models = _models()
    wl = _workload(models)
    mux_pl = place(models, n_devices=8, group_limit=32)
    sp_pl = place_spatial(models, n_devices=8)
    return models, wl, mux_pl, sp_pl


def test_conservation(setting):
    _, wl, mux_pl, _ = setting
    rep = simulate(mux_pl, wl, mode="spatial-temporal", policy="adbs")
    assert rep.finished <= rep.submitted
    assert rep.finished > 0
    assert rep.throughput > 0


def test_slo_attainment_monotone(setting):
    _, wl, mux_pl, _ = setting
    rep = simulate(mux_pl, wl, mode="spatial-temporal", policy="adbs",
                   slo_scales=(2, 4, 8, 16, 64))
    vals = [rep.slo_attainment[s] for s in (2, 4, 8, 16, 64)]
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))
    assert all(0 <= v <= 1 for v in vals)


def test_muxserve_beats_temporal(setting):
    """Headline claim: spatial-temporal ≥ temporal multiplexing."""
    _, wl, mux_pl, _ = setting
    mux = simulate(mux_pl, wl, mode="spatial-temporal", policy="adbs")
    tmp = simulate(mux_pl, wl, mode="temporal", policy="fcfs")
    assert mux.throughput >= tmp.throughput * 0.98, \
        (mux.throughput, tmp.throughput)


def test_muxserve_beats_spatial_under_skew():
    models = _models(max_rate=14.0)
    wl = _workload(models, horizon=40.0)
    mux_pl = place(models, n_devices=8, group_limit=32)
    sp_pl = place_spatial(models, n_devices=8)
    mux = simulate(mux_pl, wl, mode="spatial-temporal", policy="adbs")
    sp = simulate(sp_pl, wl, mode="spatial", policy="adbs")
    assert mux.throughput >= sp.throughput * 0.95, \
        (mux.throughput, sp.throughput)


def test_adbs_beats_fcfs_within_unit():
    """Fig. 9: ADBS > FCFS on colocated LLMs."""
    models = _models(max_rate=10.0)
    wl = _workload(models, horizon=40.0, seed=3)
    pl = place(models, n_devices=8, group_limit=32)
    adbs = simulate(pl, wl, mode="spatial-temporal", policy="adbs")
    fcfs = simulate(pl, wl, mode="spatial-temporal", policy="fcfs")
    assert adbs.throughput >= fcfs.throughput * 0.98, \
        (adbs.throughput, fcfs.throughput)


def test_quota_adaptation_tracks_rates():
    """ADBS quota shares should end up correlated with arrival rates
    (Fig. 9: block usage aligns with rate distribution)."""
    models = _models(max_rate=12.0)
    wl = _workload(models, horizon=40.0, seed=5)
    pl = place(models, n_devices=8, group_limit=32)
    rep = simulate(pl, wl, mode="spatial-temporal", policy="adbs")
    # hottest model should not hold the smallest quota share in its unit
    rates = {cfg.name: r for cfg, r in models}
    hot = max(rates, key=rates.get)
    if hot in rep.kv_util_by_llm and len(rep.kv_util_by_llm) > 1:
        assert rep.kv_util_by_llm[hot] >= min(rep.kv_util_by_llm.values())


def test_unit_sim_drains():
    spec = LLMSpec(llama_config("llama-7b"), 2.0)
    u = UnitSim([spec], 2, mode="spatial-temporal", policy="adbs")
    wl = _workload([(spec.cfg, 2.0)], horizon=20.0)
    u.load(wl.requests)
    u.run(horizon=20.0)
    done = u.results()
    assert len(done) == len(wl.requests), "single-LLM unit must drain"
    for r in done:
        assert r.finish >= r.spec.arrival
        assert r.prefill_end >= r.spec.arrival
        assert r.tokens_done == r.spec.output_len


def test_kv_accounting_returns_to_zero():
    spec = LLMSpec(llama_config("llama-7b"), 2.0)
    u = UnitSim([spec], 2, mode="spatial-temporal", policy="adbs")
    wl = _workload([(spec.cfg, 2.0)], horizon=10.0)
    u.load(wl.requests)
    u.run(horizon=10.0)
    assert abs(u.kv_used) < 1e-6
    for st in u.llms.values():
        assert abs(st.kv_bytes) < 1e-6
