"""Live reconfiguration subsystem (serving/reconfig.py): drift
monitor hysteresis, zero-downtime engine/KV migration (bit-identical
post-migration logits, fused and serial paths), fused-group
dissolve/rebuild pool accounting, and the end-to-end controller on a
regime-shift trace (DESIGN.md §10)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import replace
from repro.core.estimator import LLMSpec
from repro.core.placement import Mesh, Placement, place_onto_meshes
from repro.core.workload import piecewise_poisson_trace
from repro.serving.driver import (LogicalClock, TickCostModel,
                                  build_unit_from_specs, serve_workload,
                                  units_from_placement)
from repro.serving.engine import Request, _next_pow2, _pad_rows
from repro.serving.kvcache import migrate_view
from repro.serving.reconfig import (MigrationCostModel, ReconfigController,
                                    WorkloadMonitor, diff_placements)

COST = TickCostModel()


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------
def test_monitor_ewma_and_hysteresis():
    mon = WorkloadMonitor({"a": 4.0, "b": 1.0}, interval=1.0, alpha=0.5,
                          threshold=2.0, sustain=2, eps=1.0)
    # window 1: a keeps its rate, no drift
    for _ in range(4):
        mon.observe("a", tokens=10)
    mon.observe("b")
    assert mon.advance(1.0) == 1
    assert mon.rate_ewma["a"] == pytest.approx(4.0)
    assert not mon.triggered()
    # windows 2..3: a spikes to 16/s — one window must NOT trigger
    # (hysteresis), the second consecutive one must
    for _ in range(16):
        mon.observe("a")
    assert mon.advance(2.0) == 1
    assert not mon.triggered(), "one window above threshold must not arm"
    for _ in range(16):
        mon.observe("a")
    mon.advance(3.0)
    assert mon.triggered()
    assert mon.max_drift() > 2.0
    # rebase to the observed rates disarms
    mon.rebase(dict(mon.rate_ewma))
    assert not mon.triggered()
    assert mon.token_ewma["a"] > 0


def test_monitor_eps_floor_masks_sparse_noise():
    """A 0.5 req/s LLM sees mostly empty windows while its busy
    sibling keeps arriving; the eps floor keeps that Poisson sparsity
    from arming the trigger even as the cold EWMA decays."""
    mon = WorkloadMonitor({"cold": 0.5, "busy": 4.0}, interval=0.5,
                          threshold=2.0, sustain=2, eps=1.0)
    for w in range(1, 11):
        for _ in range(2):                 # busy keeps its planned rate
            mon.observe("busy")
        mon.advance(0.5 * w)
    assert mon.rate_ewma["cold"] < 0.01
    assert not mon.triggered()


def test_monitor_idle_windows_frozen():
    """Totally-idle windows (trace gap / end-of-trace drain) freeze
    the EWMAs and the trigger — draining decodes must not fire a
    pointless migration."""
    mon = WorkloadMonitor({"a": 4.0, "b": 1.0}, interval=0.5,
                          threshold=2.0, sustain=2, eps=1.0)
    assert mon.advance(10.0) == 20         # long idle gap
    assert mon.rate_ewma == {"a": 4.0, "b": 1.0}, "EWMAs frozen"
    assert not mon.triggered()
    mon.observe("a")                       # traffic resumes
    mon.advance(10.5)
    assert mon.rate_ewma["a"] != 4.0


def test_monitor_windows_close_against_callers_clock():
    mon = WorkloadMonitor({"a": 1.0}, interval=0.25)
    assert mon.advance(0.2) == 0
    assert mon.advance(1.0) == 4
    assert mon.windows_closed == 4


# ---------------------------------------------------------------------------
# KV migration: bit-identical continuation
# ---------------------------------------------------------------------------
def _twin_units(fused: bool, clock=None):
    uA = build_unit_from_specs(
        [("m0", "qwen2-7b", 2.0), ("m1", "qwen2-7b", 1.0)],
        pool_blocks=6_000, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=fused)
    uB = build_unit_from_specs(
        [("m2", "qwen2-7b", 1.0)], pool_blocks=6_000, max_slots=4,
        chunk_tokens=16, seed=7, policy="adbs", fused=fused)
    clock = clock or LogicalClock()
    for u in (uA, uB):
        u.clock = clock
        for e in u.engines.values():
            e.clock = clock
    return uA, uB


def _requests():
    rng = np.random.default_rng(3)
    return ([Request(i, "m1", list(rng.integers(1, 500, 24)), 8)
             for i in range(3)]
            + [Request(10 + i, "m0", list(rng.integers(1, 500, 20)), 6)
               for i in range(2)])


def _decode_logits(eng):
    """Run the engine's decode step WITHOUT committing (pool arrays are
    copied because jitted steps donate them) — the probe for
    bit-identical post-migration logits."""
    job = eng.export_decode_job()
    assert job is not None
    B = len(job)
    lens = eng.view.seq_lens(job.seq_ids)
    table = eng.view.block_table(job.seq_ids, eng.max_blocks)
    last_tok = job.last_tok
    Bp = _next_pow2(B)
    if Bp != B:
        last_tok, lens, table = _pad_rows(
            Bp, (job.last_tok, 0), (lens, 1), (table, -1))
    _, _, logits, _, _ = eng._decode_fn(
        eng.params, eng.model_index, jnp.asarray(last_tok),
        jnp.asarray(lens), eng.pool.k + 0, eng.pool.v + 0,
        jnp.asarray(table), None, None)
    return np.asarray(logits[:B])


def _migrate_m1(uA, uB):
    eng, queued = uA.remove_engine("m1")
    evicted = eng.evict_prefilling()
    view, blocks = migrate_view(eng.view, uB.pool, quota=eng.view.used)
    eng.rebind_view(view)
    uB.add_engine("m1", eng, list(evicted) + list(queued))
    return blocks


@pytest.mark.parametrize("fused", [True, False],
                         ids=["fused", "serial"])
def test_migrated_decode_bit_identical(fused):
    """A decode continued after KV migration produces bit-identical
    logits (and therefore tokens) to an unmigrated twin run — the
    page copy is exact and block tables re-resolve from the new pool.
    """
    # twin 1: never migrated
    uA_ref, uB_ref = _twin_units(fused)
    for r in _requests():
        uA_ref.submit(r)
    for _ in range(6):
        uA_ref.tick()
    ref_logits = _decode_logits(uA_ref.engines["m1"])

    # twin 2: identical history, then m1 migrates mid-decode
    uA, uB = _twin_units(fused)
    reqs = _requests()
    for r in reqs:
        uA.submit(r)
    for _ in range(6):
        uA.tick()
    blocks = _migrate_m1(uA, uB)
    assert blocks > 0, "mid-decode migration must carry live KV pages"
    mig_logits = _decode_logits(uB.engines["m1"])
    assert np.array_equal(ref_logits, mig_logits), \
        "post-migration logits must be bit-identical"

    # ... and the completed outputs match the twin exactly, with no
    # request dropped (drain-or-carry)
    for _ in range(600):
        if not (uA.pending() + uB.pending() + uA_ref.pending()
                + uB_ref.pending()):
            break
        for u in (uA, uB, uA_ref, uB_ref):
            if u.pending():
                u.tick()
    ref_out = {r.req_id: list(r.output) for r in uA_ref.stats.finished}
    mig_out = {r.req_id: list(r.output)
               for u in (uA, uB) for r in u.stats.finished}
    assert set(ref_out) == set(mig_out) == {r.req_id for r in reqs}
    assert ref_out == mig_out


def test_migrate_view_copies_pages_and_frees_source():
    uA, uB = _twin_units(fused=False)
    eng = uA.engines["m1"]
    for r in _requests():
        uA.submit(r)
    for _ in range(6):
        uA.tick()
    src_pool = eng.pool
    seqs_before = {sid: (list(sc.bases), sc.n_tokens)
                   for sid, sc in eng.view.seqs.items()}
    assert seqs_before
    src_used = eng.view.used
    gs = eng.view.group_size
    # capture source pages per sequence (contiguous head-block groups)
    src_pages = {
        sid: np.concatenate([np.asarray(src_pool.k[b:b + gs])
                             for b in bases])
        for sid, (bases, _) in seqs_before.items()}

    view, blocks = migrate_view(eng.view, uB.pool, quota=src_used)
    eng.rebind_view(view)
    assert blocks == sum(len(b) for b, _ in seqs_before.values()) * gs
    # per-sequence bookkeeping carried over; pages bit-identical
    for sid, (_bases, n_tokens) in seqs_before.items():
        assert view.seqs[sid].n_tokens == n_tokens
        dst = np.concatenate([np.asarray(uB.pool.k[b:b + gs])
                              for b in view.seqs[sid].bases])
        assert np.array_equal(src_pages[sid], dst)
    assert view.used == src_used
    # source fully released and unregistered
    assert "m1" not in src_pool.views
    assert src_pool.used_by.get("m1") is None


def test_prefilling_requests_requeue_not_carry():
    """Drain-or-carry: a request still in its prompt chunks at
    migration time is evicted, requeued at the destination and
    restarted exactly (greedy decoding)."""
    uA_ref, _ = _twin_units(fused=False)
    reqs_ref = _requests()
    for r in reqs_ref:
        uA_ref.submit(r)
    uA_ref.tick()                       # chunks in flight
    uA, uB = _twin_units(fused=False)
    reqs = _requests()
    for r in reqs:
        uA.submit(r)
    eng = uA.engines["m1"]
    for _ in range(4):                  # round-robin reaches m1 by now
        uA.tick()
        if eng.has_prefill_work():
            break
    assert eng.has_prefill_work(), "ticks must leave m1 chunks in flight"
    n_prefilling = len(eng._prefilling)
    eng2, queued = uA.remove_engine("m1")
    evicted = eng2.evict_prefilling()
    assert len(evicted) == n_prefilling and evicted
    for r in evicted:
        assert r.prefill_done < 0 and r.first_token < 0 and not r.output
    view, blocks = migrate_view(eng2.view, uB.pool, quota=eng2.view.used)
    eng2.rebind_view(view)
    uB.add_engine("m1", eng2, list(evicted) + list(queued))
    for _ in range(600):
        if not (uA.pending() + uB.pending() + uA_ref.pending()):
            break
        for u in (uA, uB, uA_ref):
            if u.pending():
                u.tick()
    ref_out = {r.req_id: list(r.output) for r in uA_ref.stats.finished}
    mig_out = {r.req_id: list(r.output)
               for u in (uA, uB) for r in u.stats.finished}
    assert set(mig_out) == {r.req_id for r in reqs}, "zero drops"
    assert ref_out == mig_out, "restarted prefills are exact under greedy"


def test_move_skipped_when_destination_full():
    """A move whose destination pool cannot hold the live KV is
    skipped whole — the engine never detaches, nothing is dropped,
    and the plan records the spec back at its source mesh."""
    from repro.serving.reconfig import MigrationExecutor

    uA, uB = _twin_units(fused=False)
    for r in _requests():
        uA.submit(r)
    for _ in range(6):
        uA.tick()
    # exhaust the destination pool so the pre-check fails
    hog = uB.pool.allocator.alloc(uB.pool.allocator.free_blocks)
    assert uB.pool.allocator.free_blocks == 0
    uA.mesh_id, uB.mesh_id = 0, 1
    ex = MigrationExecutor({0: uA, 1: uB})
    pl = _shift_placement()
    stats = ex.execute([("m1", 0, 1)], pl)
    assert stats["executed"] == [] and stats["skipped"] == [("m1", 0, 1)]
    assert "m1" in uA.engines and "m1" not in uB.engines
    uB.pool.allocator.free(hog, uB.pool.n_head_blocks)
    # drain: every request still finishes on the source unit
    for _ in range(600):
        if not uA.pending():
            break
        uA.tick()
    assert len(uA.stats.finished) == len(_requests())


# ---------------------------------------------------------------------------
# fused-group dissolve/rebuild pool accounting
# ---------------------------------------------------------------------------
def test_group_dissolve_returns_pool_grant():
    u = build_unit_from_specs(
        [("g0", "qwen2-7b", 1.0), ("g1", "qwen2-7b", 1.0)],
        pool_blocks=6_000, max_slots=2, chunk_tokens=16, seed=0,
        policy="adbs", fused=True)
    assert len(u.fused_groups) == 1
    grp = u.fused_groups[0]
    granted = grp.granted_blocks
    assert granted > 0
    assert u.pool.n_head_blocks == 6_000 + granted
    # removing a member dissolves the group: idle pool → the shrink is
    # the exact inverse of the grant
    eng, _ = u.remove_engine("g1")
    assert not u.fused_groups
    assert u.pool.n_head_blocks == 6_000
    assert u.reclaimed_weight_bytes == 0
    # each engine owns a private [1, ...] stack again
    assert eng.params["tok"]["embed"].shape[0] == 1
    assert eng.model_index == 0
    # re-adding rebuilds the group and re-grows the grant
    u.add_engine("g1", eng)
    assert len(u.fused_groups) == 1
    assert u.pool.n_head_blocks == 6_000 + u.fused_groups[0].granted_blocks


# ---------------------------------------------------------------------------
# re-planner + controller end-to-end
# ---------------------------------------------------------------------------
def _shift_placement():
    cfg = configs.get("qwen2-7b")

    def spec(name, rate):
        return LLMSpec(replace(cfg, name=name), rate, mean_prompt=16,
                       mean_output=6, tp=1, sm_frac=1.0, arch="qwen2-7b")

    return Placement(
        meshes=[Mesh(0, 4, [spec("llm0", 12.0), spec("llm1", 2.0)]),
                Mesh(1, 1, [spec("llm2", 0.5)])],
        total_tpt=14.5)


def test_place_onto_meshes_tracks_rates():
    """The online re-planner assigns the hot LLM to the big mesh —
    for pre-flip rates that reproduces the startup layout, for
    post-flip rates it demands a move."""
    pl = _shift_placement()
    models_pre = [(s.cfg, s.rate) for m in pl.meshes for s in m.specs]
    mesh_sizes = [(m.mesh_id, m.n_devices) for m in pl.meshes]
    pre = place_onto_meshes(models_pre, mesh_sizes, mean_prompt=16,
                            mean_output=6)
    assert {s.name: m.mesh_id for m in pre.meshes
            for s in m.specs}["llm0"] == 0
    post_rates = {"llm0": 0.5, "llm1": 2.0, "llm2": 12.0}
    models_post = [(s.cfg, post_rates[s.name])
                   for m in pl.meshes for s in m.specs]
    post = place_onto_meshes(models_post, mesh_sizes, mean_prompt=16,
                             mean_output=6)
    assert {s.name: m.mesh_id for m in post.meshes
            for s in m.specs}["llm2"] == 0
    moves = diff_placements(pre, post)
    assert any(n == "llm2" and dst == 0 for n, _, dst in moves)


def _serve_shift(reconfig: bool, horizon=2.4):
    pl = _shift_placement()
    wl = piecewise_poisson_trace(
        [(0.0, {"llm0": 12.0, "llm1": 2.0, "llm2": 0.5}),
         (horizon / 2, {"llm0": 0.5, "llm1": 2.0, "llm2": 12.0})],
        horizon, seed=0, mean_prompt=16, mean_output=6, max_len=128)
    units = units_from_placement(pl, pool_blocks=12_000, max_slots=4,
                                 chunk_tokens=16, seed=0, policy="adbs",
                                 fused=True)
    ctrl = None
    if reconfig:
        ctrl = ReconfigController(pl, units, interval=0.2,
                                  drift_threshold=2.0, sustain=2,
                                  migration_cost=MigrationCostModel())
    rep = serve_workload(units, wl, seed=1, slo_scales=(2.0, 4.0, 8.0),
                         cost=COST, reconfig=ctrl)
    return wl, rep


def test_controller_end_to_end_zero_drops_and_events():
    wl, rep = _serve_shift(reconfig=True)
    assert rep.aggregate.finished == rep.aggregate.submitted \
        == len(wl.requests), "migration must not drop requests"
    assert rep.reconfig is not None and rep.reconfig.events >= 1
    assert rep.reconfig.moves >= 1, "the flip must move an engine"
    assert rep.reconfig.stall_ticks > 0
    assert rep.reconfig.dt_charged > 0
    # drift section: estimates next to the original plan
    assert set(rep.planned_rates) == {"llm0", "llm1", "llm2"}
    assert rep.planned_rates["llm0"] == 12.0
    assert rep.rate_estimates["llm2"] > rep.planned_rates["llm2"]
    ev = rep.reconfig.log[0]
    assert set(ev) >= {"t", "drift", "moves", "migrated_blocks",
                       "requeued", "quota_moved", "dt_charged",
                       "stall_ticks"}


def test_controller_deterministic_reproducible():
    """Reconfiguration rides the logical clock: two fresh runs of the
    same shift trace are bit-identical, events included."""
    _, a = _serve_shift(reconfig=True)
    _, b = _serve_shift(reconfig=True)
    assert a.horizon == b.horizon and a.ticks == b.ticks
    assert a.aggregate.attainment == b.aggregate.attainment
    assert a.aggregate.e2e == b.aggregate.e2e
    assert a.reconfig.to_json() == b.reconfig.to_json()
    assert a.rate_estimates == b.rate_estimates


def test_share_only_replan_executes():
    """A re-plan that changes ONLY sm_frac (same assignment, same
    rates) diffs to an empty move schedule — it must still execute:
    the executor applies the new shares to the destination units and
    the event reports a nonzero Σ|Δsm_frac| (before the fix the
    'implied' rebalance silently never happened)."""
    from dataclasses import replace as dc_replace

    from repro.serving.reconfig import MigrationExecutor, shares_of

    pl = _shift_placement()
    for m in pl.meshes:                    # plan with enforced shares
        for s in m.specs:
            s.sm_frac = 0.5
    units = units_from_placement(pl, pool_blocks=12_000, max_slots=2,
                                 chunk_tokens=16, seed=0, policy="adbs",
                                 fused=True)
    ex = MigrationExecutor({u.mesh_id: u for u in units})
    new_pl = Placement([Mesh(m.mesh_id, m.n_devices,
                             [dc_replace(s, sm_frac=0.2 if s.name == "llm0"
                                         else s.sm_frac)
                              for s in m.specs])
                        for m in pl.meshes], pl.total_tpt)
    assert diff_placements(pl, new_pl) == []
    stats = ex.execute([], new_pl)
    assert stats["share_moved"] == pytest.approx(0.3)
    assert units[0].sm_frac["llm0"] == pytest.approx(0.2)
    assert shares_of(new_pl)["llm0"] == pytest.approx(0.2)
    # a second pass is idempotent: nothing left to move
    assert ex.execute([], new_pl)["share_moved"] == 0.0


def test_static_report_still_exposes_estimates():
    """Drift is visible in every report, reconfig enabled or not."""
    wl, rep = _serve_shift(reconfig=False)
    assert rep.reconfig is None
    assert rep.planned_rates and rep.rate_estimates
    assert rep.rate_estimates["llm2"] > 2.0, \
        "the post-flip surge must show in the EWMA estimates"
    assert "rates est(plan)" in rep.summary()


# ---------------------------------------------------------------------------
# migration × prefix sharing (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _twin_cached_units(fused: bool):
    uA = build_unit_from_specs(
        [("m0", "qwen2-7b", 2.0), ("m1", "qwen2-7b", 1.0)],
        pool_blocks=6_000, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=fused, prefix_cache=True)
    uB = build_unit_from_specs(
        [("m2", "qwen2-7b", 1.0)], pool_blocks=6_000, max_slots=4,
        chunk_tokens=16, seed=7, policy="adbs", fused=fused,
        prefix_cache=True)
    clock = LogicalClock()
    for u in (uA, uB):
        u.clock = clock
        for e in u.engines.values():
            e.clock = clock
    return uA, uB


def _shared_history(uA):
    """Donor populates m1's prefix index, then two sharers adopt the
    cached blocks and sit mid-decode."""
    rng = np.random.default_rng(21)
    pref = list(rng.integers(1, 500, 32))            # 2 full blocks
    uA.submit(Request(0, "m1", pref + [3, 3, 3, 3], 4))
    for _ in range(200):
        if not uA.pending():
            break
        uA.tick()
    sharers = [Request(1 + i, "m1",
                       pref + list(rng.integers(1, 500, 8)), 8)
               for i in range(2)]
    for r in sharers:
        uA.submit(r)
    for _ in range(6):
        uA.tick()
    return sharers


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "serial"])
def test_migrated_shared_prefix_bit_identical(fused):
    """Migrating a view with shared prefix blocks rebuilds the
    refcounts and the prefix index on the destination (distinct groups
    copied once, cache-only entries dropped) and the carried decode
    stays bit-identical."""
    uA_ref, _ = _twin_cached_units(fused)
    _shared_history(uA_ref)
    ref_logits = _decode_logits(uA_ref.engines["m1"])

    uA, uB = _twin_cached_units(fused)
    sharers = _shared_history(uA)
    src_view = uA.engines["m1"].view
    src_alloc = uA.pool.allocator
    shared_bases = list(src_view.seqs[sharers[0]._seq_id].bases[:2])
    assert src_view.seqs[sharers[0]._seq_id].shared == 2
    assert src_view.seqs[sharers[1]._seq_id].bases[:2] == shared_bases, \
        "both sharers must reference the same cached groups"
    # 2 sharers + the index entry each hold a ref on the shared groups
    assert all(src_alloc.refcount(b) == 3 for b in shared_bases)
    n_entries = len(src_view.prefix_index)
    assert n_entries == 2

    blocks = _migrate_m1(uA, uB)
    dst_view = uB.pool.views["m1"]
    gs = dst_view.group_size
    uniq = {b for sc in dst_view.seqs.values() for b in sc.bases}
    assert blocks == len(uniq) * gs, \
        "shared groups must be copied once, not once per sharer"
    # sharing metadata carried: same shared counts, common new bases
    new_shared = dst_view.seqs[sharers[0]._seq_id].bases[:2]
    assert dst_view.seqs[sharers[1]._seq_id].bases[:2] == new_shared
    assert dst_view.seqs[sharers[0]._seq_id].shared == 2
    # index rebuilt against the remapped bases (entries whose groups a
    # live sequence carries; here: both)
    assert len(dst_view.prefix_index) == n_entries
    assert {b for _, (b, _) in dst_view.prefix_index.entries()} \
        == set(new_shared)
    assert all(uB.pool.allocator.refcount(b) == 3 for b in new_shared)
    assert dst_view.used == sum(len(sc.bases) * gs
                                for sc in dst_view.seqs.values())

    mig_logits = _decode_logits(uB.engines["m1"])
    assert np.array_equal(ref_logits, mig_logits), \
        "post-migration shared-prefix logits must be bit-identical"
    for _ in range(600):
        if not (uA.pending() + uB.pending() + uA_ref.pending()):
            break
        for u in (uA, uB, uA_ref):
            if u.pending():
                u.tick()
    ref_out = {r.req_id: list(r.output) for r in uA_ref.stats.finished}
    mig_out = {r.req_id: list(r.output)
               for u in (uA, uB) for r in u.stats.finished}
    assert ref_out == mig_out and set(mig_out) == {0, 1, 2}


def test_migrate_drops_cache_only_entries():
    """Index entries no live sequence shares are deliberately NOT
    migrated (copying cold cache would inflate the migration); the
    source's refs are released with the view."""
    uA, uB = _twin_cached_units(fused=False)
    rng = np.random.default_rng(22)
    pref = list(rng.integers(1, 500, 32))
    uA.submit(Request(0, "m1", pref + [3, 3, 3, 3], 4))
    for _ in range(200):
        if not uA.pending():
            break
        uA.tick()
    src_view = uA.engines["m1"].view
    assert len(src_view.prefix_index) == 2 and not src_view.seqs
    blocks = _migrate_m1(uA, uB)
    assert blocks == 0, "cache-only inventory must not be copied"
    dst_view = uB.pool.views["m1"]
    assert len(dst_view.prefix_index) == 0
    assert uB.pool.allocator.used \
        == sum(v.used for v in uB.pool.views.values())
