"""End-to-end system behaviour: the paper's pipeline from workload →
placement → multiplexed serving, at CPU scale with real engines, plus
simulator-vs-estimator coherence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.placement import place, place_spatial
from repro.core.simulator import simulate
from repro.core.workload import llama_config, synthesize
from repro.models.transformer import init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler


def test_end_to_end_pipeline_simulated():
    """Workload → Alg.1 placement → ADBS simulation: MuxServe's
    aggregate throughput ≥ both baselines on a skewed workload (the
    paper's headline ordering, Fig. 5)."""
    cfgs = [llama_config("llama-7b", f"-{i}") for i in range(4)]
    rates = [16.0, 2.0, 0.8, 0.4]
    models = list(zip(cfgs, rates))
    wl = synthesize([c.name for c in cfgs], alpha=1.7, max_rate=16.0,
                    horizon=45.0, seed=11)
    wl.rates = dict(zip([c.name for c in cfgs], rates))

    mux_pl = place(models, n_devices=8, group_limit=32)
    sp_pl = place_spatial(models, n_devices=8)
    mux = simulate(mux_pl, wl, mode="spatial-temporal", policy="adbs")
    spatial = simulate(sp_pl, wl, mode="spatial", policy="adbs")
    temporal = simulate(mux_pl, wl, mode="temporal", policy="fcfs")

    assert mux.throughput >= 0.95 * spatial.throughput
    assert mux.throughput >= 0.95 * temporal.throughput
    assert mux.finished > 0


def test_end_to_end_real_engines_multiplexed():
    """Three reduced LLMs of different families colocated on one pool,
    scheduled by ADBS with interleaved arrivals — everything finishes,
    cache accounting returns to zero, per-model outputs are
    deterministic replays of solo serving."""
    archs = ["qwen2-7b", "mamba2-2.7b", "musicgen-medium"]
    cfgs = {a: configs.get_reduced(a) for a in archs}
    pool = UnifiedKVPool(300_000, 64, dtype=jnp.float32)
    engines = {}
    params = {}
    for i, a in enumerate(archs):
        cfg = cfgs[a]
        params[a] = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, 100_000)
        engines[cfg.name] = Engine(cfg, params[a], view, max_slots=2)
    mux = MuxScheduler(engines, pool, policy="adbs", adapt_every=4)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(9):
        a = archs[i % 3]
        cfg = cfgs[a]
        reqs.append(Request(i, cfg.name,
                            list(rng.integers(1, cfg.vocab_size, 6 + i % 5)),
                            max_new_tokens=3))
    for r in reqs:
        mux.submit(r)
    stats = mux.run(max_ticks=400)
    assert len(stats.finished) == 9
    assert pool.allocator.used == 0
    for a in archs:
        n = sum(1 for r in stats.finished if r.model == cfgs[a].name)
        assert n == 3, f"{a}: {n}/3 finished"

    # replay one request solo → identical output tokens
    target = reqs[0]
    cfg = cfgs[archs[0]]
    pool2 = UnifiedKVPool(100_000, 64, dtype=jnp.float32)
    v2 = pool2.register_model(cfg, 100_000)
    solo = Engine(cfg, params[archs[0]], v2, max_slots=1)
    q = Request(99, cfg.name, target.prompt, 3)
    solo.prefill([q])
    while not q.done:
        solo.decode()
    muxed = next(r for r in stats.finished if r.req_id == 0)
    assert muxed.output == q.output, "multiplexing must not change tokens"


def test_quota_pressure_backpressures_not_crashes():
    """Tiny pool: requests queue instead of failing; everything still
    completes eventually."""
    cfg = configs.get_reduced("qwen2-7b")
    group = cfg.n_layers * cfg.n_kv_heads  # head-blocks per token-block
    pool = UnifiedKVPool(group * 6, cfg.hd, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    view = pool.register_model(cfg, group * 6)
    eng = Engine(cfg, params, view, max_slots=2)
    mux = MuxScheduler({cfg.name: eng}, pool, policy="adbs")
    rng = np.random.default_rng(1)
    for i in range(4):
        mux.submit(Request(i, cfg.name,
                           list(rng.integers(1, cfg.vocab_size, 8)), 2))
    stats = mux.run(max_ticks=500)
    assert len(stats.finished) == 4
    assert pool.allocator.used == 0
