"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per the kernel contract; tolerances are loose for
bf16 (accumulation is f32 in both kernel and oracle).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_prefill import (flash_prefill,
                                         fused_paged_flash_prefill)
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 512, 4, 1, 128),     # MQA, head_dim 128
])
def test_flash_prefill_matches_ref(b, s, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = flash_prefill(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("c,h,kv,hd", [
    (8, 4, 2, 64),           # GQA 2:1
    (4, 4, 4, 64),           # MHA
])
def test_fused_paged_flash_prefill_matches_oracle(c, h, kv, hd):
    """Pallas fused_paged_flash_prefill (interpret mode) == XLA oracle
    on a cross-model chunk batch with pre-resolved phys ids — the
    prefill-phase mirror of the fused decode kernel test."""
    from repro.serving import cache_ops
    bt = 16
    pool_k = jax.random.normal(jax.random.PRNGKey(0), (256, bt, hd),
                               jnp.float32)
    pool_v = jax.random.normal(jax.random.PRNGKey(1), (256, bt, hd),
                               jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(2), (4, c, h, hd), jnp.float32)
    # rows from two "models": different layer offsets in the same arena
    t0 = np.array([[0, 8, -1, -1], [16, 24, 32, -1]], np.int32)
    t1 = np.array([[40, 48, -1, -1], [56, 64, 72, 80]], np.int32)
    phys = jnp.concatenate([
        cache_ops.resolve_physical_blocks(jnp.asarray(t0), 0, kv),
        cache_ops.resolve_physical_blocks(jnp.asarray(t1), 1, kv)])
    # mixed chunk offsets: row 0 is a fresh prompt, the rest mid-prompt
    offs = jnp.asarray(np.array([0, 17, 5, 33], np.int32))
    oracle = cache_ops.fused_paged_chunk_attention(
        q, pool_k, pool_v, phys, offs)
    out = fused_paged_flash_prefill(q, pool_k, pool_v, phys, offs,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_prefill_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, hd = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = flash_prefill(q, k, v, block_q=64, block_k=64, window=window,
                        interpret=True)
    expect = ref.flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_causality():
    """Changing future tokens must not change earlier outputs."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b, s, h, hd = 1, 128, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out1 = flash_prefill(q, k, v, block_q=64, block_k=64, interpret=True)
    k2 = k.at[:, 64:].set(jax.random.normal(ks[3], (b, s - 64, h, hd)))
    v2 = v.at[:, 64:].set(jax.random.normal(ks[3], (b, s - 64, h, hd)))
    out2 = flash_prefill(q, k2, v2, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :64]),
                               np.asarray(out2[:, :64]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------
def _make_pool(key, n_blocks, bt, hd, dtype):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (n_blocks, bt, hd), dtype),
            jax.random.normal(k2, (n_blocks, bt, hd), dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,bt,nb", [
    (2, 8, 2, 64, 16, 4),
    (3, 4, 4, 128, 16, 3),
    (1, 16, 2, 64, 32, 2),
])
def test_paged_decode_matches_ref(b, h, kv, hd, bt, nb, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    n_layers = 2
    layer = 1
    group_size = n_layers * kv
    pool_k, pool_v = _make_pool(ks[0], 4 + b * nb * group_size, bt, hd,
                                dtype)
    q = jax.random.normal(ks[1], (b, h, hd), dtype)
    # contiguous group bases per (seq, token-block)
    table = np.full((b, nb), -1, np.int32)
    base = 4
    for i in range(b):
        for j in range(nb):
            table[i, j] = base
            base += group_size
    rng = np.random.default_rng(0)
    lens = rng.integers(1, nb * bt + 1, b).astype(np.int32)
    table_j = jnp.asarray(table)
    lens_j = jnp.asarray(lens)
    out = paged_decode_attention(q, pool_k, pool_v, table_j, lens_j, layer,
                                 n_kv=kv, interpret=True)
    expect = ref.paged_decode_ref(q, pool_k, pool_v, table_j, lens_j,
                                  layer, n_kv=kv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_paged_decode_respects_lens():
    """KV beyond seq_len must not affect the output."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    b, h, kv, hd, bt, nb = 1, 4, 2, 64, 16, 4
    group = kv  # single layer
    pool_k, pool_v = _make_pool(ks[0], b * nb * group, bt, hd, jnp.float32)
    q = jax.random.normal(ks[1], (b, h, hd), jnp.float32)
    table = jnp.arange(nb, dtype=jnp.int32)[None, :] * group
    lens = jnp.array([bt + 3], jnp.int32)
    out1 = paged_decode_attention(q, pool_k, pool_v, table, lens, 0,
                                  n_kv=kv, interpret=True)
    # scribble over blocks past the length
    pool_k2 = pool_k.at[2 * group:].set(99.0)
    pool_v2 = pool_v.at[2 * group:].set(-99.0)
    out2 = paged_decode_attention(q, pool_k2, pool_v2, table, lens, 0,
                                  n_kv=kv, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 128, 4, 64, 1, 32, 32),
    (2, 64, 2, 32, 2, 16, 16),
    (1, 256, 8, 64, 1, 64, 64),
])
def test_ssd_scan_matches_ref(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    B = jax.random.normal(ks[2], (b, s, g, n), dtype)
    C = jax.random.normal(ks[3], (b, s, g, n), dtype)
    d_skip = jnp.ones((h,), jnp.float32)
    if g > 1:
        pytest.skip("Pallas ssd_scan handles groups by pre-repeat; "
                    "oracle covers g>1 via ssd_chunked directly")
    y, fs = ssd_scan(x, dt.astype(dtype), a_log, B, C, d_skip,
                     chunk=chunk, interpret=True)
    y_ref, fs_ref = ssd_chunked(x, dt, a_log, B, C, d_skip, chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunk_invariance():
    """The chunked oracle must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, s, h, p, n = 1, 128, 2, 16, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    B = jax.random.normal(ks[2], (b, s, 1, n))
    C = jax.random.normal(ks[3], (b, s, 1, n))
    d = jnp.ones((h,))
    y1, f1 = ssd_chunked(x, dt, a_log, B, C, d, 16)
    y2, f2 = ssd_chunked(x, dt, a_log, B, C, d, 128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """SSD chunked == step-by-step SSM recurrence (ground truth)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, h, p, n = 1, 32, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.2
    a_log = jnp.log(jnp.linspace(1.0, 2.0, h))
    B = jax.random.normal(ks[2], (b, s, 1, n))
    C = jax.random.normal(ks[3], (b, s, 1, n))
    d = jnp.zeros((h,))
    y, fs = ssd_chunked(x, dt, a_log, B, C, d, 8)

    a = -np.exp(np.asarray(a_log))
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    xn, dtn = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    Bn, Cn = np.asarray(B, np.float64), np.asarray(C, np.float64)
    for t in range(s):
        for hh in range(h):
            dA = np.exp(dtn[:, t, hh] * a[hh])
            state[:, hh] = state[:, hh] * dA[:, None, None] + \
                dtn[:, t, hh, None, None] * np.einsum(
                    "bp,bn->bpn", xn[:, t, hh], Bn[:, t, 0])
            ys[:, t, hh] = np.einsum("bpn,bn->bp", state[:, hh], Cn[:, t, 0])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), state, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# int8 paged decode attention (W8/KV8 serving kernel)
# ---------------------------------------------------------------------------
def _quantize_pool(x):
    amax = jnp.abs(x).max(-1)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s


@pytest.mark.parametrize("b,h,kv,hd,bt,nb", [
    (2, 8, 2, 64, 16, 4),
    (1, 4, 4, 128, 16, 3),
])
def test_paged_decode_int8_matches_dequant_ref(b, h, kv, hd, bt, nb):
    from repro.kernels.paged_attention_int8 import \
        paged_decode_attention_int8
    from repro.serving.cache_ops import paged_decode_attention as ref_attn
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    n_layers, layer = 2, 1
    group = n_layers * kv
    N = 4 + b * nb * group
    kf = jax.random.normal(ks[0], (N, bt, hd)) * 2
    vf = jax.random.normal(ks[1], (N, bt, hd)) * 2
    k8, sk = _quantize_pool(kf)
    v8, sv = _quantize_pool(vf)
    kd = k8.astype(jnp.float32) * sk[..., None]
    vd = v8.astype(jnp.float32) * sv[..., None]
    q = jax.random.normal(ks[2], (b, h, hd))
    table = np.full((b, nb), -1, np.int32)
    base = 4
    for i in range(b):
        for j in range(nb):
            table[i, j] = base
            base += group
    rng = np.random.default_rng(1)
    lens = jnp.asarray(rng.integers(1, nb * bt + 1, b).astype(np.int32))
    table = jnp.asarray(table)
    out = paged_decode_attention_int8(q, k8, v8, sk, sv, table, lens,
                                      layer, n_kv=kv, interpret=True)
    expect = ref_attn(q, kd, vd, table, lens, layer, kv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_paged_decode_int8_near_bf16_truth():
    """End-to-end quantization error of the int8 kernel vs exact f32
    attention over the same (pre-quantization) KV."""
    from repro.kernels.paged_attention_int8 import \
        paged_decode_attention_int8
    from repro.serving.cache_ops import paged_decode_attention as ref_attn
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, kv, hd, bt, nb = 1, 4, 2, 64, 16, 3
    group = kv
    N = b * nb * group
    kf = jax.random.normal(ks[0], (N, bt, hd))
    vf = jax.random.normal(ks[1], (N, bt, hd))
    k8, sk = _quantize_pool(kf)
    v8, sv = _quantize_pool(vf)
    q = jax.random.normal(ks[2], (b, h, hd))
    table = jnp.arange(nb, dtype=jnp.int32)[None, :] * group
    lens = jnp.array([nb * bt], jnp.int32)
    out = paged_decode_attention_int8(q, k8, v8, sk, sv, table, lens, 0,
                                      n_kv=kv, interpret=True)
    exact = ref_attn(q, kf, vf, table, lens, 0, kv)
    rel = float(jnp.abs(out - exact).max() / jnp.abs(exact).max())
    assert rel < 0.05, rel
