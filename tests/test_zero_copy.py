"""Zero-copy stacked weights + fused chunked-prefill sweep + shape-
stable batching (DESIGN.md §2/§5).

A fused group must hold exactly ONE weight tree (members index the
stacked buffer — no private copies), the reclaimed HBM must grow the
unified pool, the fused prefill sweep must be greedy-parity with the
serial chunk path, and the bucketed hot paths must stop compiling new
programs once their shape buckets are warm.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import replace
from repro.models.transformer import init_params
from repro.serving.engine import (TRACE_COUNTS, Engine, Request, tree_bytes,
                                  unique_tree_bytes)
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler


def _colocated(archs, fused, max_slots=2, quota=30_000, n_blocks=100_000,
               chunk_tokens=None):
    """Build a unit of colocated reduced engines (repeated archs get
    distinct weights + names) and a MuxScheduler over them."""
    pool = UnifiedKVPool(n_blocks, 64, dtype=jnp.float32)
    engines = {}
    for i, a in enumerate(archs):
        cfg = replace(configs.get_reduced(a), name=f"m{i}")
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, quota)
        engines[cfg.name] = Engine(cfg, params, view, max_slots=max_slots,
                                   chunk_tokens=chunk_tokens)
    return MuxScheduler(engines, pool, policy="adbs", fused=fused), pool


def _submit(mux, n_reqs, max_new=4, seed=7, plen=None):
    rng = np.random.default_rng(seed)
    names = list(mux.engines)
    reqs = []
    for i in range(n_reqs):
        name = names[i % len(names)]
        vocab = mux.engines[name].cfg.vocab_size
        n = plen(i) if plen else 6 + i % 5
        r = Request(i, name, list(rng.integers(1, vocab, n)), max_new)
        reqs.append(r)
        mux.submit(r)
    return reqs


# ---------------------------------------------------------------------------
# zero-copy weight de-duplication
# ---------------------------------------------------------------------------
def test_fused_group_holds_single_weight_tree():
    """No engine in a fused group holds a private full weight tree: all
    members point at the group's stacked tree, so the group's live
    weight bytes are ~1× (the stacked tree), not 2×."""
    mux, _ = _colocated(["qwen2-7b"] * 3, fused=True)
    assert len(mux.fused_groups) == 1
    grp = mux.fused_groups[0]
    for eng in grp.engines:
        assert eng.params is grp.params, \
            "fused-group engine must index the shared stacked tree"
    live = unique_tree_bytes([e.params for e in grp.engines])
    assert live == tree_bytes(grp.params)
    # the serial scheduler's engines own one tree each — the fused
    # group's live bytes must equal that total (1×), not double it
    mux_s, _ = _colocated(["qwen2-7b"] * 3, fused=False)
    serial_live = sum(unique_tree_bytes([e.params])
                      for e in mux_s.engines.values())
    assert live == serial_live
    assert grp.reclaimed_bytes == serial_live
    assert mux.reclaimed_weight_bytes == grp.reclaimed_bytes


def test_reclaimed_bytes_grow_pool():
    """The weight copy reclaimed by de-duplication is granted to the
    unified pool as extra head-blocks, split across the group's views
    as quota (the paper's memory-multiplexing dividend)."""
    n_blocks, quota = 50_000, 10_000
    mux_s, pool_s = _colocated(["qwen2-7b"] * 2, fused=False,
                               n_blocks=n_blocks, quota=quota)
    mux_f, pool_f = _colocated(["qwen2-7b"] * 2, fused=True,
                               n_blocks=n_blocks, quota=quota)
    grp = mux_f.fused_groups[0]
    extra = grp.reclaimed_bytes // pool_f.head_block_bytes
    assert extra > 0
    assert pool_f.n_head_blocks == n_blocks + extra
    assert pool_f.allocator.n_blocks == n_blocks + extra
    assert pool_f.allocator.free_blocks \
        == pool_s.allocator.free_blocks + extra
    assert pool_f.k.shape[0] == n_blocks + extra
    share = extra // len(grp.engines)
    for eng in mux_f.engines.values():
        assert eng.view.quota == quota + share
    # the grown range is allocatable
    base = pool_f.allocator.alloc(pool_f.allocator.free_blocks)
    assert base is not None
    pool_f.allocator.free(base, pool_f.allocator.used)


def test_serial_fallback_runs_off_stacked_tree():
    """A lone-active group member decodes AND prefills off the shared
    stacked tree (via its model index) with outputs identical to a
    standalone engine holding the same weights privately."""
    mux, _ = _colocated(["qwen2-7b"] * 2, fused=True)
    rng = np.random.default_rng(11)
    cfg = mux.engines["m1"].cfg
    prompt = list(rng.integers(1, cfg.vocab_size, 9))
    r = Request(0, "m1", list(prompt), 6)
    mux.submit(r)
    mux.run(max_ticks=100)
    assert r.done

    # standalone reference: same seed ⇒ same weights, private tree
    cfg1 = replace(configs.get_reduced("qwen2-7b"), name="m1")
    params = init_params(jax.random.PRNGKey(1), cfg1, jnp.float32)
    pool2 = UnifiedKVPool(50_000, 64, dtype=jnp.float32)
    solo = Engine(cfg1, params, pool2.register_model(cfg1, 20_000),
                  max_slots=2)
    q = Request(9, "m1", list(prompt), 6)
    solo.prefill([q])
    while not q.done:
        solo.decode()
    assert r.output == q.output


# ---------------------------------------------------------------------------
# fused chunked-prefill sweep
# ---------------------------------------------------------------------------
def test_fused_prefill_parity_with_serial():
    """Fused prefill sweep == serial chunked prefill: greedy outputs
    bit-identical for colocated same-arch engines with distinct
    weights, prompts long enough to span several chunks, and decode
    interleaved between chunks."""
    archs = ["qwen2-7b"] * 3
    mux_s, pool_s = _colocated(archs, fused=False, chunk_tokens=8)
    mux_f, pool_f = _colocated(archs, fused=True, chunk_tokens=8)
    assert len(mux_f.fused_groups) == 1
    assert mux_f.fused_groups[0].chunk_tokens == 8
    # chunked group members leave the serial prefill rotation entirely
    assert mux_f._prefill_serial_names == []

    plen = lambda i: (11, 23, 34)[i % 3]  # noqa: E731 — spans 2-5 chunks
    _submit(mux_s, 6, max_new=20, plen=plen)
    reqs_f = _submit(mux_f, 6, max_new=20, plen=plen)
    mux_s.run(max_ticks=400)
    mux_f.run(max_ticks=400)

    assert len(mux_s.stats.finished) == len(mux_f.stats.finished) == 6
    outs_s = {r.req_id: r.output for r in mux_s.stats.finished}
    for r in reqs_f:
        assert r.output == outs_s[r.req_id], r.req_id
    assert mux_s.stats.prefill_tokens == mux_f.stats.prefill_tokens
    assert pool_s.allocator.used == 0 and pool_f.allocator.used == 0


def test_fused_prefill_mixed_chunk_and_whole_prompt():
    """Engines with different chunk windows must not share a group
    (the sweep needs one common chunk shape), and whole-prompt fused
    groups keep prefilling serially while decoding fused."""
    pool = UnifiedKVPool(100_000, 64, dtype=jnp.float32)
    engines = {}
    for i, chunk in enumerate((8, 8, None)):
        cfg = replace(configs.get_reduced("qwen2-7b"), name=f"m{i}")
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        engines[cfg.name] = Engine(cfg, params,
                                   pool.register_model(cfg, 30_000),
                                   max_slots=2, chunk_tokens=chunk)
    mux = MuxScheduler(engines, pool, policy="adbs", fused=True)
    # chunk window is part of the fusion signature: m0+m1 group, m2
    # (whole-prompt) stays serial for both phases
    assert len(mux.fused_groups) == 1
    assert set(mux.fused_groups[0].names) == {"m0", "m1"}
    assert mux._serial_names == ["m2"]
    assert mux._prefill_serial_names == ["m2"]
    reqs = _submit(mux, 6, max_new=6)
    mux.run(max_ticks=300)
    assert all(r.done for r in reqs)
    assert pool.allocator.used == 0


# ---------------------------------------------------------------------------
# shape-stable batching
# ---------------------------------------------------------------------------
def _drain_wave(eng, prompts, max_new):
    reqs = [Request(i, eng.cfg.name, list(p), max_new)
            for i, p in enumerate(prompts)]
    pending = list(reqs)
    for _ in range(200):
        if pending or eng.has_prefill_work():
            eng.prefill(pending[:len(eng.free_slots())])
            pending = [r for r in pending if not hasattr(r, "_seq_id")]
        eng.decode()
        if all(r.done for r in reqs):
            return reqs
    raise AssertionError("wave did not drain")


def test_bucketing_bounds_compile_count():
    """Once the (pow2-B, block-multiple-S) buckets of a workload are
    warm, serving a second workload with the same bucket profile must
    compile NOTHING new — the trace counter proves shape stability."""
    cfg = replace(configs.get_reduced("qwen2-7b"), name="tc0")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    pool = UnifiedKVPool(100_000, 64, dtype=jnp.float32)
    eng = Engine(cfg, params, pool.register_model(cfg, 50_000), max_slots=4)

    def wave(engine, lens, max_new, seed):
        rr = np.random.default_rng(seed)
        return _drain_wave(
            engine, [list(rr.integers(1, cfg.vocab_size, n)) for n in lens],
            max_new)

    # warm the buckets: prefill B=3→pow2 4, S=48; decode B=3→pow2 4
    wave(eng, [9, 17, 37], max_new=5, seed=1)
    warm = sum(TRACE_COUNTS.values())
    # same bucket profile, different raw shapes (lens land in the same
    # 16-token S buckets and the same pow2 row buckets)
    wave(eng, [13, 30, 42], max_new=5, seed=2)
    assert sum(TRACE_COUNTS.values()) == warm, \
        "warm shape buckets must not re-trace"

    # a same-geometry engine shares the jit cache: serving a second
    # instance of the architecture over the warm buckets compiles
    # nothing either
    cfg2 = replace(configs.get_reduced("qwen2-7b"), name="tc1")
    params2 = init_params(jax.random.PRNGKey(1), cfg2, jnp.float32)
    eng2 = Engine(cfg2, params2, pool.register_model(cfg2, 30_000),
                  max_slots=4)
    wave(eng2, [11, 21, 41], max_new=5, seed=3)
    assert sum(TRACE_COUNTS.values()) == warm, \
        "same-geometry engines must share compiled programs"


def test_chunked_bucketing_bounds_compile_count():
    """The chunked-prefill path is shape-stable too: fused sweep rows
    pad to the group's fixed row count, serial chunks to pow2 rows."""
    mux, _ = _colocated(["qwen2-7b"] * 2, fused=True, chunk_tokens=8,
                        max_slots=2)
    _submit(mux, 4, max_new=8, seed=3, plen=lambda i: 10 + 9 * (i % 2))
    mux.run(max_ticks=300)
    warm = sum(TRACE_COUNTS.values())
    _submit(mux, 4, max_new=8, seed=4, plen=lambda i: 12 + 7 * (i % 2))
    mux.run(max_ticks=300)
    assert sum(TRACE_COUNTS.values()) == warm, \
        "steady-state fused serving must not re-trace"
