"""Chunked prefill (beyond-paper, Sarathi-style): correctness + the
interleaving property it exists for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler


def _serve(cfg, params, prompts, chunk, max_new=4):
    pool = UnifiedKVPool(100_000, cfg.hd, dtype=jnp.float32)
    view = pool.register_model(cfg, 100_000)
    eng = Engine(cfg, params, view, max_slots=len(prompts),
                 chunk_tokens=chunk)
    reqs = [Request(i, cfg.name, p, max_new)
            for i, p in enumerate(prompts)]
    eng.prefill(reqs)
    for _ in range(60):
        if eng.has_prefill_work():
            eng.prefill([])
        eng.decode()
        if all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


@pytest.mark.parametrize("arch", ["qwen2-7b", "granite-moe-3b-a800m",
                                  "mamba2-2.7b"])
@pytest.mark.parametrize("chunk", [4, 16])
def test_chunked_matches_unchunked(arch, chunk):
    cfg = configs.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, n)) for n in (23, 9)]
    ref = _serve(cfg, params, prompts, None)
    out = _serve(cfg, params, prompts, chunk)
    assert out == ref


def test_chunked_prefill_interleaves_decode():
    """The point of chunking: while LLM A's long prompt prefills chunk
    by chunk, LLM B's decode makes progress between chunks (with
    unchunked prefill, B's first decode waits for the whole prompt)."""
    cfg_a = configs.get_reduced("qwen2-7b")
    cfg_b = configs.get_reduced("musicgen-medium")
    pa = init_params(jax.random.PRNGKey(0), cfg_a, jnp.float32)
    pb = init_params(jax.random.PRNGKey(1), cfg_b, jnp.float32)
    pool = UnifiedKVPool(200_000, 64, dtype=jnp.float32)
    va = pool.register_model(cfg_a, 100_000)
    vb = pool.register_model(cfg_b, 100_000)
    eng_a = Engine(cfg_a, pa, va, max_slots=1, chunk_tokens=8)
    eng_b = Engine(cfg_b, pb, vb, max_slots=1)
    mux = MuxScheduler({cfg_a.name: eng_a, cfg_b.name: eng_b}, pool,
                       policy="adbs")
    rng = np.random.default_rng(2)
    long_req = Request(0, cfg_a.name,
                       list(rng.integers(1, cfg_a.vocab_size, 64)), 2)
    short_req = Request(1, cfg_b.name,
                        list(rng.integers(1, cfg_b.vocab_size, 6)), 4)
    mux.submit(long_req)
    mux.submit(short_req)
    # drive ticks manually; B must produce tokens while A still prefills
    b_tokens_during_a_prefill = 0
    for _ in range(40):
        mux.tick()
        if eng_a.has_prefill_work() and short_req.output:
            b_tokens_during_a_prefill = len(short_req.output)
        if long_req.done and short_req.done:
            break
    assert long_req.done and short_req.done
    assert b_tokens_during_a_prefill > 0, \
        "decode of the colocated LLM must progress between prefill chunks"
    assert pool.allocator.used == 0
