"""SLO-attainment serving driver (serving/driver.py): deterministic
clock, attainment conventions (DESIGN.md §9), and the placement →
runtime bridge."""
import json
import math

import pytest

from repro import configs
from repro.core.estimator import LLMSpec
from repro.core.placement import (Mesh, Placement, placement_from_json,
                                  placement_to_json)
from repro.core.workload import synthesize
from repro.serving.driver import (LogicalClock, TickCostModel,
                                  build_unit_from_specs, serve_workload,
                                  units_from_placement)
from repro.serving.mux import FusedGroup

SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0)
COST = TickCostModel()
NAMES = ["llm0", "llm1", "llm2"]


def _skewed_workload(max_rate=20.0, horizon=2.0):
    """3-LLM popularity-skewed trace (α=2.1 → top LLM dominates)."""
    return synthesize(NAMES, alpha=2.1, max_rate=max_rate, horizon=horizon,
                      seed=0, mean_prompt=16, mean_output=6, max_len=128)


def _serve(wl, policy: str):
    unit = build_unit_from_specs(
        [(n, "qwen2-7b", wl.rates[n]) for n in NAMES],
        pool_blocks=20_000, max_slots=4, chunk_tokens=16, seed=0,
        policy=policy, fused=True)
    return serve_workload([unit], wl, seed=1, slo_scales=SCALES, cost=COST)


@pytest.fixture(scope="module")
def skewed_reports():
    wl = _skewed_workload()
    return wl, {p: _serve(wl, p) for p in ("adbs", "fcfs")}


def test_attainment_monotone_in_slo_scale(skewed_reports):
    """A larger SLO scale admits a superset of requests — attainment
    must be non-decreasing in slo_scale, per LLM and aggregate."""
    _, reports = skewed_reports
    for policy, rep in reports.items():
        for r in [rep.aggregate, *rep.per_llm.values()]:
            vals = [r.attainment[s] for s in SCALES]
            assert vals == sorted(vals), (policy, r.name, vals)
            assert all(0.0 <= v <= 1.0 for v in vals)


def test_adbs_attains_geq_fcfs_on_skewed_trace(skewed_reports):
    """The paper's ADBS claim in runtime form: on a popularity-skewed
    colocated trace, ADBS (prefill-priority round-robin + quota
    adaptation) attains at least as many requests as temporal FCFS at
    every scale, strictly more at some scale."""
    _, reports = skewed_reports
    adbs = reports["adbs"].aggregate.attainment
    fcfs = reports["fcfs"].aggregate.attainment
    assert all(adbs[s] >= fcfs[s] for s in SCALES), (adbs, fcfs)
    assert any(adbs[s] > fcfs[s] for s in SCALES), (adbs, fcfs)


def test_all_finished_with_sane_timelines(skewed_reports):
    """Both policies drain the trace; every request timeline is
    ordered: arrival ≤ first_token ≤ finish (one clock domain)."""
    wl, reports = skewed_reports
    for policy, rep in reports.items():
        agg = rep.aggregate
        assert agg.finished == agg.submitted == len(wl.requests), policy
        assert agg.ttft.p50 >= 0 and agg.tpot.p50 >= 0
        assert agg.e2e.p99 >= agg.ttft.p99 - 1e-12


def test_deterministic_clock_reproducible():
    """Same trace + fresh unit ⇒ bit-identical report: scheduling
    depends only on lengths/arrivals, and logical time only on token
    counts — nothing in the loop reads wall time."""
    wl = _skewed_workload(max_rate=10.0, horizon=1.0)
    a = _serve(wl, "adbs")
    b = _serve(wl, "adbs")
    assert a.horizon == b.horizon and a.ticks == b.ticks
    assert a.aggregate.attainment == b.aggregate.attainment
    assert a.aggregate.e2e == b.aggregate.e2e
    assert a.aggregate.ttft == b.aggregate.ttft


def test_solo_request_meets_its_own_reference():
    """Self-consistency of the SLO convention: a request served on an
    idle unit finishes within ~its analytic solo reference, so
    attainment at small scales is 1.0 when there is no contention."""
    wl = synthesize(["solo"], alpha=1.0, max_rate=0.5, horizon=6.0,
                    seed=0, mean_prompt=16, mean_output=6, max_len=64)
    assert 1 <= len(wl.requests) <= 6
    unit = build_unit_from_specs([("solo", "qwen2-7b", 0.5)],
                                 pool_blocks=20_000, max_slots=4,
                                 chunk_tokens=16, seed=0, policy="adbs")
    rep = serve_workload([unit], wl, seed=1, slo_scales=(1.5,), cost=COST)
    assert rep.aggregate.finished == len(wl.requests)
    assert rep.aggregate.attainment[1.5] == 1.0
    for r in rep.per_llm["solo"].attainment.values():
        assert r == 1.0


def test_logical_clock_and_cost_model():
    c = LogicalClock()
    assert c() == 0.0
    c.advance(1.5)
    c.advance(0.25)
    assert c() == 1.75
    # reference = per-tick base cost × tick count + per-token costs;
    # the first output token is committed by the prefill tick, so only
    # output_len − 1 tokens are billed at decode cost (mirrors how the
    # serving loop meters MuxStats tokens)
    ref = COST.solo_reference(32, 4, chunk_tokens=16)
    exp = (2 + 3) * COST.base + 32 * COST.prefill_tok + 3 * COST.decode_tok
    assert math.isclose(ref, exp)
    assert COST.dt(10, 5) == pytest.approx(
        COST.base + 10 * COST.prefill_tok + 5 * COST.decode_tok)


# ---------------------------------------------------------------------------
# placement → runtime bridge
# ---------------------------------------------------------------------------
def _plan() -> Placement:
    def spec(name, rate, tp=2, f=0.5):
        cfg = configs.get("qwen2-7b")
        from repro.config import replace
        return LLMSpec(replace(cfg, name=name), rate, mean_prompt=24,
                       mean_output=8, tp=tp, sm_frac=f)
    return Placement(
        meshes=[Mesh(0, 4, [spec("qwen2-7b#0", 3.0), spec("qwen2-7b#1", 1.0)]),
                Mesh(1, 2, [spec("qwen2-7b#2", 0.5, tp=1, f=1.0)])],
        total_tpt=4.5)


def test_placement_json_roundtrip():
    """Plan JSON preserves mesh layout and every spec field; configs
    are re-resolved by arch so the runtime can substitute variants."""
    pl = _plan()
    data = json.loads(json.dumps(placement_to_json(pl)))  # via the wire
    back = placement_from_json(data, configs.get)
    assert back.total_tpt == pl.total_tpt
    assert [m.n_devices for m in back.meshes] == [4, 2]
    for m0, m1 in zip(pl.meshes, back.meshes):
        assert m0.mesh_id == m1.mesh_id
        for s0, s1 in zip(m0.specs, m1.specs):
            assert (s0.name, s0.rate, s0.tp, s0.sm_frac) \
                == (s1.name, s1.rate, s1.tp, s1.sm_frac)
            assert s1.cfg.n_layers == configs.get("qwen2-7b").n_layers


def test_placement_builds_real_units():
    """units_from_placement: one MuxScheduler per mesh, group
    membership = the mesh's LLM set, quota split ∝ arrival rate, and
    same-architecture members fuse."""
    pl = _plan()
    units = units_from_placement(pl, pool_blocks=40_000, max_slots=2,
                                 chunk_tokens=16, fused=True)
    assert len(units) == 2
    assert sorted(units[0].engines) == ["qwen2-7b#0", "qwen2-7b#1"]
    assert sorted(units[1].engines) == ["qwen2-7b#2"]
    # quota split ∝ rate inside the first mesh (3:1), before the fused
    # zero-copy grant tops both views up equally
    grp = units[0].fused_groups
    assert len(grp) == 1 and isinstance(grp[0], FusedGroup)
    grant = units[0].reclaimed_weight_bytes \
        // units[0].pool.head_block_bytes // 2
    v0 = units[0].engines["qwen2-7b#0"].view
    v1 = units[0].engines["qwen2-7b#1"].view
    q0, q1 = v0.quota - grant, v1.quota - grant
    assert q0 / q1 == pytest.approx(3.0, rel=0.05), (q0, q1)
    # pool blocks split ∝ mesh devices (4:2) before the fused grant
    base0 = units[0].pool.n_head_blocks - 2 * grant
    assert base0 / units[1].pool.n_head_blocks \
        == pytest.approx(2.0, rel=0.05)
    # every engine runs the REDUCED variant under its unit-unique name
    red = configs.get_reduced("qwen2-7b")
    for u in units:
        for name, eng in u.engines.items():
            assert eng.cfg.name == name
            assert eng.cfg.n_layers == red.n_layers
            assert eng.cfg.d_model == red.d_model
