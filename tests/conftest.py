"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU
device; only launch/dryrun.py forces 512 placeholder devices."""
import os

import jax
import numpy as np
import pytest

# determinism + smaller compile cache churn
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# wall-clock budget per test when pytest-timeout is installed (the
# [test] extra ships it; like hypothesis, its absence degrades
# gracefully — a bare pytest run just has no hang protection).  A
# wedged serving loop then fails its test instead of hanging CI; the
# in-loop watchdog (DESIGN.md §12) is the runtime's own last resort,
# this is the test harness's.
_TEST_TIMEOUT_S = 600


def pytest_collection_modifyitems(config, items):
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if item.get_closest_marker("timeout") is None:
            item.add_marker(pytest.mark.timeout(_TEST_TIMEOUT_S))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


ALL_ARCHS = [
    "musicgen-medium", "qwen2-7b", "granite-moe-3b-a800m", "zamba2-1.2b",
    "qwen3-14b", "phi-3-vision-4.2b", "command-r-plus-104b", "mamba2-2.7b",
    "qwen3-moe-235b-a22b", "deepseek-coder-33b",
]
