"""Live serving front end (serving/frontend.py, router.py, metrics.py;
DESIGN.md §14): streaming determinism against the closed-loop driver,
cross-LLM routing strategies, client cancellation as a first-class
disposition, backpressure surfacing as stream errors, and the
Prometheus-style metrics layer."""
import asyncio
import json
import urllib.request

import numpy as np
import pytest

from repro.core.workload import synthesize
from repro.serving.driver import (ServeSession, TickCostModel,
                                  build_unit_from_specs,
                                  requests_from_workload, serve_requests)
from repro.serving.engine import Request
from repro.serving.faults import FaultPlan
from repro.serving.frontend import (ServingFrontend, StreamCancelled,
                                    StreamShed, serve_and_collect)
from repro.serving.metrics import (MetricsServer, ServingMetrics,
                                   percentile_from_histogram)
from repro.serving.router import (ExplicitTarget, LeastLoaded, RoundRobin,
                                  Router, WeightedByRate, family_of,
                                  make_strategy)

COST = TickCostModel()
NAMES = ["llm0", "llm1", "llm2"]


def _workload(max_rate=10.0, horizon=1.5):
    return synthesize(NAMES, alpha=2.1, max_rate=max_rate, horizon=horizon,
                      seed=0, mean_prompt=16, mean_output=6, max_len=128)


def _unit(wl, fused=True, **kw):
    return build_unit_from_specs(
        [(n, "qwen2-7b", wl.rates[n]) for n in NAMES],
        pool_blocks=8_000, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=fused, **kw)


def _build(wl, fused=True, **kw):
    u = _unit(wl, fused=fused, **kw)
    return u, requests_from_workload(wl, u.engines, seed=1)


def _ab_unit(**kw):
    return build_unit_from_specs(
        [("a", "qwen2-7b", 3.0), ("b", "qwen2-7b", 1.0)],
        pool_blocks=4_000, max_slots=4, chunk_tokens=16, seed=0,
        policy="adbs", fused=True, **kw)


def _reqs(n, model="a", plen=24, out=6, arrival=0.0):
    rng = np.random.default_rng(7)
    return [Request(i, model, list(rng.integers(1, 500, plen)), out,
                    arrival=arrival) for i in range(n)]


# ---------------------------------------------------------------------------
# streaming determinism: open-loop == closed-loop, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", [True, False],
                         ids=["fused", "serial"])
def test_streams_bit_identical_to_closed_loop(fused):
    """The frontend drives the SAME ServeSession stepper as the
    closed-loop driver, so under the virtual clock every streamed
    token sequence equals the driver's Request.output exactly — for
    both the fused sweep and serial per-engine ticks."""
    wl = _workload()
    u1, r1 = _build(wl, fused=fused)
    rep1 = serve_requests([u1], r1, cost=COST)
    u2, r2 = _build(wl, fused=fused)
    fe = ServingFrontend([u2], r2, cost=COST)
    rep2, outs = serve_and_collect(fe)
    by_id = {r.req_id: r for r in r1}
    for r in r2:
        assert outs[r.req_id] == by_id[r.req_id].output == r.output
    assert rep1.ticks == rep2.ticks
    assert rep1.horizon == rep2.horizon
    assert rep1.aggregate.attainment == rep2.aggregate.attainment
    assert rep1.aggregate.finished == rep2.aggregate.finished


def test_frontend_rerun_reproducible():
    """Same trace + fresh units ⇒ the frontend reproduces itself
    bit-for-bit (open-loop streaming adds no hidden nondeterminism)."""
    wl = _workload(max_rate=6.0, horizon=1.0)
    runs = []
    for _ in range(2):
        u, reqs = _build(wl)
        rep, outs = serve_and_collect(ServingFrontend([u], reqs, cost=COST))
        runs.append((rep.ticks, rep.horizon,
                     {i: tuple(o) for i, o in outs.items()}))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
def test_family_convention():
    assert family_of("llm-a@1") == "llm-a"
    assert family_of("solo") == "solo"


def _two_replica_units():
    ua = build_unit_from_specs([("m@0", "qwen2-7b", 2.0)],
                               pool_blocks=4_000, max_slots=2,
                               chunk_tokens=16, seed=0, policy="adbs")
    ub = build_unit_from_specs([("m@1", "qwen2-7b", 2.0)],
                               pool_blocks=4_000, max_slots=2,
                               chunk_tokens=16, seed=0, policy="adbs")
    return ua, ub


def test_router_strategies():
    ua, ub = _two_replica_units()
    r = Router([ua, ub], strategy=RoundRobin())
    # exact names short-circuit every strategy
    assert r.resolve("m@0") == "m@0"
    # round-robin alternates replicas deterministically
    assert [r.resolve("m") for _ in range(4)] == ["m@0", "m@1"] * 2
    with pytest.raises(KeyError):
        r.resolve("nope")
    # explicit refuses family fan-out
    r2 = Router([ua, ub], strategy=ExplicitTarget())
    with pytest.raises(KeyError):
        r2.resolve("m")
    # weighted: 3:1 planned rates → 3:1 long-run split (smooth WRR)
    r3 = Router([ua, ub], strategy=WeightedByRate({"m@0": 3.0, "m@1": 1.0}))
    picks = [r3.resolve("m") for _ in range(8)]
    assert picks.count("m@0") == 6 and picks.count("m@1") == 2
    # least-loaded follows queue depth
    r4 = Router([ua, ub], strategy=LeastLoaded())
    ua.submit(_reqs(1, model="m@0")[0])
    assert r4.resolve("m") == "m@1"
    for name in ("explicit", "round_robin", "weighted", "least_loaded"):
        assert make_strategy(name, {"m@0": 1.0}).name == name
    with pytest.raises(ValueError):
        make_strategy("bogus")


def test_router_refresh_follows_topology():
    ua, ub = _two_replica_units()
    r = Router([ua, ub], strategy=RoundRobin())
    assert sorted(r.families["m"]) == ["m@0", "m@1"]
    # a removed engine disappears from the view on refresh
    ub.remove_engine("m@1")
    r.refresh()
    assert r.families["m"] == ["m@0"]
    assert all(r.resolve("m") == "m@0" for _ in range(3))


# ---------------------------------------------------------------------------
# cancellation: the third disposition
# ---------------------------------------------------------------------------
def test_cancel_queued_and_prearrival():
    """Cancelling a queued request frees its queue slot immediately;
    cancelling before arrival means it is never submitted.  Both count
    as `cancelled`, and submitted = finished + shed + cancelled."""
    u = _ab_unit()
    reqs = _reqs(6, model="a") + _reqs(1, model="b", arrival=5.0)
    late = reqs[-1]
    session = ServeSession([u], reqs, cost=COST)
    assert session.cancel(late)          # pre-arrival: never submitted
    assert not session.cancel(late)      # idempotent
    session.step()                       # t=0 arrivals submitted
    queued = next(iter(u.queues["a"]), None)
    assert queued is not None
    assert session.cancel(queued)
    assert queued not in u.queues["a"] and queued.cancelled
    while session.step()[0] != "done":
        pass
    rep = session.report()
    agg = rep.aggregate
    assert agg.cancelled == 2
    assert agg.submitted == agg.finished + agg.shed + agg.cancelled
    assert rep.per_llm["a"].cancelled == 1
    assert rep.per_llm["b"].cancelled == 1
    assert "cancelled=2" in rep.summary()
    assert rep.to_json()["aggregate"]["cancelled"] == 2
    # cancelled ≠ shed: sheds stay zero here
    assert agg.shed == 0


def test_cancel_inflight_frees_kv_now():
    """Cancelling a RUNNING request evicts its sequence: slot, KV
    blocks and prefix refs return to the pool immediately, not at the
    request's would-have-been finish."""
    u = _ab_unit()
    (victim,), rest = _reqs(1, model="a", out=64), _reqs(3, model="b")
    session = ServeSession([u], [victim] + rest, cost=COST)
    for _ in range(200):
        session.step()
        if victim.first_token >= 0:
            break
    assert victim.first_token >= 0 and victim.finish < 0
    used_before = u.engines["a"].view.used
    assert used_before > 0
    assert session.cancel(victim)
    assert victim.cancelled and not victim.shed
    assert u.engines["a"].view.used < used_before
    while session.step()[0] != "done":
        pass
    # pool fully drains: nothing leaked by the mid-flight eviction
    assert all(v.used == 0 for v in u.pool.views.values())
    rep = session.report()
    assert rep.aggregate.cancelled == 1
    assert rep.aggregate.submitted == \
        rep.aggregate.finished + rep.aggregate.shed + rep.aggregate.cancelled


def test_cancel_terminates_stream():
    """frontend.cancel ends the request's stream with StreamCancelled
    (after ≥1 streamed token, so the cancel is genuinely mid-flight)."""
    u = _ab_unit()
    victim = _reqs(1, model="a", out=64)[0]
    fe = ServingFrontend([u], [victim], cost=COST)

    async def _main():
        stream = fe.stream(victim)
        serve_task = asyncio.ensure_future(fe.serve())

        async def consume():
            got = 0
            with pytest.raises(StreamCancelled):
                async for _tok in stream:
                    got += 1
                    if got == 2:
                        assert fe.cancel(victim)
            return got

        got = await consume()
        await serve_task
        return got

    assert asyncio.run(_main()) >= 2
    assert victim.cancelled
    assert fe.report().aggregate.cancelled == 1


# ---------------------------------------------------------------------------
# backpressure surfaces as stream errors
# ---------------------------------------------------------------------------
def test_shed_surfaces_as_stream_error():
    """Bounded-queue shedding terminates the affected streams with
    StreamShed carrying the reason — clients see backpressure, never a
    silent hang — and the metrics layer counts the stream errors."""
    u = _ab_unit(max_queue=1, shed_policy="reject")
    reqs = _reqs(6, model="a")
    metrics = ServingMetrics()
    fe = ServingFrontend([u], reqs, metrics=metrics, cost=COST)
    rep, outs = serve_and_collect(fe)
    sheds = {i: o for i, o in outs.items() if isinstance(o, StreamShed)}
    fins = {i: o for i, o in outs.items() if isinstance(o, list)}
    assert sheds and fins
    assert len(sheds) + len(fins) == len(reqs)
    assert all(o.reason == "queue_full" for o in sheds.values())
    assert rep.aggregate.shed == len(sheds)
    assert rep.aggregate.submitted == \
        rep.aggregate.finished + rep.aggregate.shed
    snap = {f["name"]: f for f in metrics.snapshot()["families"]}
    errs = sum(s["value"]
               for s in snap["mux_stream_errors_total"]["series"])
    assert errs == len(sheds)


# ---------------------------------------------------------------------------
# metrics layer
# ---------------------------------------------------------------------------
def test_metrics_registry_and_exposition():
    m = ServingMetrics()
    m.requests_submitted.inc(llm="a")
    m.requests_submitted.inc(2, llm="b")
    m.llm_qps.set(3.25, llm="a")
    for v in (0.004, 0.04, 0.4):
        m.ttft_seconds.observe(v, llm="a")
    m.reconfig_events.inc(kind="move")
    m.fault_events.inc(kind="engine_crash")
    text = m.registry.render()
    assert "# TYPE mux_requests_submitted_total counter" in text
    assert 'mux_requests_submitted_total{llm="b"} 2' in text
    assert 'mux_llm_qps{llm="a"} 3.25' in text
    assert 'mux_ttft_seconds_bucket{llm="a",le="+Inf"} 3' in text
    assert 'mux_ttft_seconds_count{llm="a"} 3' in text
    assert 'mux_reconfig_events_total{kind="move"} 1' in text
    assert 'mux_fault_events_total{kind="engine_crash"} 1' in text
    p50 = percentile_from_histogram(m.ttft_seconds, 0.5, llm="a")
    assert p50 is not None and 0.004 <= p50 <= 0.4
    with pytest.raises(ValueError):
        m.requests_submitted.inc(-1, llm="a")


def test_metrics_http_endpoint():
    m = ServingMetrics()
    m.requests_submitted.inc(llm="a")
    m.log.emit(0.0, "submit", 1, llm="a")
    srv = MetricsServer(m, port=0).start()
    try:
        with urllib.request.urlopen(f"{srv.url}/metrics") as resp:
            body = resp.read().decode()
            assert resp.status == 200
            assert 'mux_requests_submitted_total{llm="a"} 1' in body
        with urllib.request.urlopen(f"{srv.url}/metrics.json") as resp:
            snap = json.loads(resp.read())
            assert any(f["name"] == "mux_requests_submitted_total"
                       for f in snap["families"])
        with urllib.request.urlopen(f"{srv.url}/events") as resp:
            assert "data: " in resp.read().decode()
        with urllib.request.urlopen(f"{srv.url}/nope") as resp:
            pytest.fail("404 expected")
    except urllib.error.HTTPError as e:
        assert e.code == 404
    finally:
        srv.close()
    srv.close()                          # idempotent (thread already down)


def test_serving_records_metrics_and_report_embeds_snapshot():
    """One armed run records the full taxonomy: lifecycle counters and
    latency histograms agree with the report's roll-ups, a fired fault
    lands in the fault counter, request-correlated structured logs
    exist, and the final snapshot rides in ServeReport (schema v2)."""
    u = _ab_unit()
    reqs = _reqs(4, model="a") + _reqs(2, model="b")
    metrics = ServingMetrics()
    rep = serve_requests([u], reqs, cost=COST, metrics=metrics,
                         faults=FaultPlan.parse("crash:a@0.02"))
    assert rep.to_json()["schema_version"] == 2
    assert rep.metrics is not None
    fams = {f["name"]: f for f in rep.metrics["families"]}
    fin = sum(s["value"]
              for s in fams["mux_requests_finished_total"]["series"])
    assert fin == rep.aggregate.finished
    ttft_n = sum(s["count"] for s in fams["mux_ttft_seconds"]["series"])
    assert ttft_n == rep.aggregate.finished
    tok = sum(s["value"] for s in fams["mux_tokens_total"]["series"])
    assert tok > 0
    faults = {s["labels"]["kind"]: s["value"]
              for s in fams["mux_fault_events_total"]["series"]}
    assert faults.get("engine_crash", 0) >= 1
    recov = {s["labels"]["llm"]: s["value"]
             for s in fams["mux_recoveries_total"]["series"]}
    assert recov.get("a", 0) >= 1
    # request-correlated structured log: every request has a submit
    # record, finished ones also a finish record
    for r in reqs:
        events = [rec.event for rec in metrics.log.for_request(r.req_id)]
        assert "submit" in events
        if r.finish >= 0:
            assert "finish" in events
    # full exposition renders without error and carries the live qps
    assert "mux_llm_qps" in metrics.registry.render()
