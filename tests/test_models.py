"""Per-architecture smoke tests (assignment requirement): instantiate
the REDUCED variant of each family and run one forward + one train step
on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.layers import apply_rope, repeat_kv, rms_norm
from repro.models.transformer import forward, init_params
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step

from conftest import ALL_ARCHS


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.frontend_dim:
        prefix = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.float32)
    logits, aux = forward(params, cfg, toks, prefix_emb=prefix, remat=False)
    n_pre = 0 if prefix is None else cfg.n_prefix_tokens
    assert logits.shape == (B, S + n_pre, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any(), f"{arch}: NaN logits"

    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((B, 1), -100, jnp.int32)], axis=1)
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt, remat=True))
    args = [params, init_state(params), toks, labels]
    if prefix is not None:
        args.append(prefix)
    p2, o2, m = step(*args)
    assert np.isfinite(float(m["loss"])), f"{arch}: non-finite loss"
    assert int(o2.step) == 1
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


def test_rms_norm_properties():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    w = jnp.ones((64,))
    y = rms_norm(x, w)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


def test_rope_preserves_norm_and_relative():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 64))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i−j
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 1e4)
        kj = apply_rope(k, jnp.array([[j]]), 1e4)
        return float((qi * kj).sum())
    assert np.isclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_repeat_kv():
    x = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    y = repeat_kv(x, 3)
    assert y.shape == (2, 3, 6, 4)
    np.testing.assert_array_equal(np.asarray(y[:, :, 0]),
                                  np.asarray(y[:, :, 1]))
    np.testing.assert_array_equal(np.asarray(y[:, :, 3]),
                                  np.asarray(y[:, :, 5]))


def test_forward_causality():
    """Future tokens must not leak into earlier logits."""
    cfg = configs.get_reduced("deepseek-coder-33b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    l1, _ = forward(params, cfg, toks, remat=False)
    toks2 = toks.at[:, 10:].set((toks[:, 10:] + 7) % cfg.vocab_size)
    l2, _ = forward(params, cfg, toks2, remat=False)
    np.testing.assert_allclose(np.asarray(l1[:, :10]),
                               np.asarray(l2[:, :10]), atol=1e-4)


def test_ssm_causality():
    cfg = configs.get_reduced("mamba2-2.7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    l1, _ = forward(params, cfg, toks, remat=False)
    toks2 = toks.at[:, 10:].set((toks[:, 10:] + 7) % cfg.vocab_size)
    l2, _ = forward(params, cfg, toks2, remat=False)
    np.testing.assert_allclose(np.asarray(l1[:, :10]),
                               np.asarray(l2[:, :10]), atol=1e-4)


def test_moe_aux_loss_nonzero():
    cfg = configs.get_reduced("granite-moe-3b-a800m")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, aux = forward(params, cfg, toks, remat=False)
    assert float(aux) > 0, "load-balance loss must be active"


def test_sliding_window_restricts_context():
    cfg = configs.get_reduced("qwen2-7b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    key = jax.random.PRNGKey(2)
    S, W = 96, 16
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    lw, _ = forward(params, cfg, toks, remat=False, window=W)
    # changing a token more than W before the end must not change the
    # last logit under the window
    toks2 = toks.at[:, 10].set((toks[:, 10] + 3) % cfg.vocab_size)
    lw2, _ = forward(params, cfg, toks2, remat=False, window=W)
    np.testing.assert_allclose(np.asarray(lw[:, -1]),
                               np.asarray(lw2[:, -1]), atol=1e-4)
    lf, _ = forward(params, cfg, toks, remat=False)
    assert float(jnp.abs(lf[:, -1] - lw[:, -1]).max()) > 1e-3, \
        "window must actually change full-attention outputs"
