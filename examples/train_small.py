"""Train a compact dense LM for a few hundred steps on the synthetic
Markov pipeline, with checkpoint/resume.  (The paper's kind is serving,
so the end-to-end driver is examples/multi_llm_serving.py; this
demonstrates the training substrate.  Scale the config up for a ~100M
run — the same step lowers at 256-chip scale via launch/dryrun.py.)

  PYTHONPATH=src python examples/train_small.py [--steps 150]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, synth_batch
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step

# ~35M-param LLaMA-style model (CPU-trainable in minutes)
CFG = ModelConfig(
    name="demo-35m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=2048,
    source="examples/train_small.py demo config")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    params = init_params(jax.random.PRNGKey(0), CFG, jnp.float32)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{CFG.name}: {n / 1e6:.1f}M params")

    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    state = init_state(params)
    dcfg = DataConfig(vocab_size=CFG.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0, n_patterns=2)
    step_fn = jax.jit(make_train_step(CFG, opt, remat=True))

    losses = []
    t0 = time.perf_counter()
    ckpt_dir = tempfile.mkdtemp(prefix="train_small_")
    for i in range(args.steps):
        toks, labels, _ = synth_batch(dcfg, i)
        params, state, m = step_fn(params, state, jnp.asarray(toks),
                                   jnp.asarray(labels))
        losses.append(float(m["loss"]))
        if (i + 1) % 25 == 0:
            tps = args.batch * args.seq * (i + 1) / (time.perf_counter() - t0)
            print(f"step {i + 1:4d}  loss={losses[-1]:.4f}  "
                  f"lr={float(m['lr']):.2e}  tok/s={tps:.0f}")
        if (i + 1) == args.steps // 2:
            ckpt.save(ckpt_dir, {"p": params, "o": state}, step=i + 1)
            print(f"checkpoint at step {i + 1} → {ckpt_dir}")

    print(f"\nloss: {losses[0]:.3f} → {np.mean(losses[-10:]):.3f} "
          f"(must decrease)")
    assert np.mean(losses[-10:]) < losses[0] - 0.5
    # resume check
    tree, st_step, _ = ckpt.restore(ckpt_dir, {"p": params, "o": state})
    print(f"restored checkpoint from step {st_step} ✓")


if __name__ == "__main__":
    main()
