"""End-to-end driver: MuxServe's spatial-temporal multiplexing of three
LLM families (dense GQA, SSM, audio-decoder) on one shared pool, with
Poisson arrivals — comparing ADBS against FCFS on the same workload.

  PYTHONPATH=src python examples/multi_llm_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.transformer import init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler

ARCHS = ["qwen2-7b", "mamba2-2.7b", "musicgen-medium"]
RATES = {"qwen2-7b": 3.0, "mamba2-2.7b": 1.0, "musicgen-medium": 0.5}


def build(policy: str):
    pool = UnifiedKVPool(300_000, 64, dtype=jnp.float32)
    engines = {}
    for i, a in enumerate(ARCHS):
        cfg = configs.get_reduced(a)
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, 100_000)
        engines[cfg.name] = Engine(cfg, params, view, max_slots=2)
    return MuxScheduler(engines, pool, policy=policy), pool


def workload(seed=0, horizon=6.0, max_new=6):
    rng = np.random.default_rng(seed)
    reqs = []
    rid = 0
    for a in ARCHS:
        cfg = configs.get_reduced(a)
        n = rng.poisson(RATES[a] * horizon)
        for t in np.sort(rng.uniform(0, horizon, n)):
            plen = int(rng.integers(4, 20))
            reqs.append(Request(rid, cfg.name,
                                list(rng.integers(1, cfg.vocab_size, plen)),
                                max_new, arrival=float(t)))
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def serve(policy: str):
    mux, pool = build(policy)
    reqs = workload()
    t0 = time.perf_counter()
    idx = 0
    while idx < len(reqs) or mux.pending():
        now = time.perf_counter() - t0
        while idx < len(reqs) and reqs[idx].arrival <= now:
            mux.submit(reqs[idx])
            idx += 1
        if mux.pending():
            mux.tick()
    wall = time.perf_counter() - t0
    st = mux.stats
    lat = np.array([r.finish - (t0 + r.arrival) for r in st.finished])
    assert pool.allocator.used == 0
    return {"policy": policy, "wall": wall,
            "req_s": len(st.finished) / wall,
            "tok_s": (st.prefill_tokens + st.decode_tokens) / wall,
            "p99_lat": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "finished": len(st.finished), "total": len(reqs)}


def main():
    print(f"colocating {ARCHS} on one unified KV pool")
    for policy in ("adbs", "fcfs"):
        r = serve(policy)
        print(f"[{r['policy']:>5s}] {r['finished']}/{r['total']} reqs in "
              f"{r['wall']:.1f}s → {r['req_s']:.2f} req/s, "
              f"{r['tok_s']:.0f} tok/s, p99 latency {r['p99_lat']:.2f}s")


if __name__ == "__main__":
    main()
