"""Placement demo: run MuxServe's Alg. 1 on the paper's Table-1 model
mix (19 LLaMA-family LLMs, 32 GPUs) and compare the estimated aggregate
throughput against spatial partitioning and memory-greedy placement,
then validate with the discrete-event simulator.

  PYTHONPATH=src python examples/placement_demo.py
"""
from repro.core.placement import (place, place_memory_greedy,
                                  place_spatial)
from repro.core.simulator import simulate
from repro.core.workload import power_law_rates, synthesize, table1_models


def main():
    models = table1_models()
    rates = power_law_rates([m.name for m in models], alpha=2.1,
                            max_rate=20.0)
    models_rates = [(m, rates[m.name]) for m in models]
    print(f"{len(models)} LLMs, α=2.1 power-law rates "
          f"(top model {max(rates.values()):.1f} req/s)")

    pl = place(models_rates, n_devices=32, group_limit=48)
    print("\nMuxServe placement (Alg. 1):")
    print(pl.describe())
    print(f"estimated aggregate throughput: {pl.total_tpt:.1f} req/s")

    sp = place_spatial(models_rates, n_devices=32)
    mg = place_memory_greedy(models_rates, n_devices=32)
    print(f"\nspatial partitioning estimate: {sp.total_tpt:.1f} req/s")
    print(f"memory-greedy estimate:        {mg.total_tpt:.1f} req/s")

    wl = synthesize([m.name for m in models], alpha=2.1, max_rate=20.0,
                    horizon=20.0, seed=0)
    wl.rates = rates
    mux = simulate(pl, wl, mode="spatial-temporal", policy="adbs",
                   slo_scales=(8,))
    base = simulate(sp, wl, mode="spatial", policy="adbs", slo_scales=(8,))
    print(f"\nsimulated: MuxServe {mux.throughput:.2f} req/s "
          f"(SLO@8 {mux.slo_attainment[8]:.0%}) vs spatial "
          f"{base.throughput:.2f} req/s "
          f"(SLO@8 {base.slo_attainment[8]:.0%}) → "
          f"{mux.throughput / max(base.throughput, 1e-9):.2f}×")


if __name__ == "__main__":
    main()
