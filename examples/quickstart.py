"""Quickstart: serve one LLM through the MuxServe runtime.

Builds a reduced qwen2-7b, registers it on a unified KV pool, runs a
prefill + greedy decode through the paged-cache engine, and checks the
result against a plain full-recompute forward.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.transformer import forward, init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool


def main():
    cfg = configs.get_reduced("qwen2-7b")
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model} "
          f"h={cfg.n_heads}/{cfg.n_kv_heads})")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)

    # the unified head-wise KV pool (paper §3.4) + one model view
    pool = UnifiedKVPool(n_head_blocks=100_000, head_dim=cfg.hd,
                         dtype=jnp.float32)
    view = pool.register_model(cfg, quota=100_000)
    engine = Engine(cfg, params, view, max_slots=2)

    rng = np.random.default_rng(0)
    prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, 12)]
    req = Request(req_id=0, model=cfg.name, prompt=prompt,
                  max_new_tokens=8)
    engine.prefill([req])
    while not req.done:
        engine.decode()
    print("prompt:", prompt)
    print("generated:", req.output)

    # sanity: greedy generation by full recompute must match exactly
    seq = list(prompt)
    for _ in range(8):
        logits, _ = forward(params, cfg, jnp.asarray([seq]), remat=False)
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert req.output == seq[len(prompt):], "engine must match recompute"
    print("matches full-recompute greedy decoding ✓")
    print(f"pool blocks used at peak, now free: "
          f"{pool.allocator.free_blocks}/{pool.n_head_blocks}")


if __name__ == "__main__":
    main()
