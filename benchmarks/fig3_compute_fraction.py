"""Fig. 3: batch inference latency vs compute fraction (LLaMA-7B).

The paper's motivating observation: decode latency is flat as the SM
fraction shrinks (memory-bound), prefill scales ~1/f (compute-bound).
Our TPU cost model must reproduce the shape — this is the property the
ADBS colocation win rests on.
"""
from __future__ import annotations

from repro.core import costmodel as cm
from repro.core.costmodel import A100, TPU_V5E
from repro.core.workload import llama_config

from benchmarks.common import save

FRACTIONS = [0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


def run() -> dict:
    cfg = llama_config("llama-7b")
    out = {"fractions": FRACTIONS, "hw": {}}
    for hw in (A100, TPU_V5E):
        prefill = [cm.prefill_latency(cfg, 1, 128, f=f, hw=hw)
                   for f in FRACTIONS]
        decode = [cm.decode_latency(cfg, 32, 400, f=f, hw=hw)
                  for f in FRACTIONS]
        # relative to f=1.0 (the paper plots relative latency)
        out["hw"][hw.name] = {
            "prefill_rel": [p / prefill[-1] for p in prefill],
            "decode_rel": [d / decode[-1] for d in decode],
        }
        print(f"[fig3] {hw.name}: prefill 0.3→1.0 rel "
              f"{prefill[0] / prefill[-1]:.2f}×, decode "
              f"{decode[0] / decode[-1]:.2f}×")
    save("fig3_compute_fraction", out)
    return out


if __name__ == "__main__":
    run()
