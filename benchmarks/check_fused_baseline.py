"""CI perf-smoke gate for the fused multi-LLM tick (DESIGN.md §2).

Compares the current ``fused_tick`` result against the committed
baseline (``experiments/results/fused_tick_baseline.json``) and fails
if the fused decode+prefill throughput advantage regressed by more
than ``--tolerance`` (default 15%).

Absolute tokens/s are machine-dependent, so the gate compares the
fused/serial *aggregate speedup ratio* — both sides are measured in
the same process on the same machine, which makes the ratio stable
across runner generations while still catching a fusion-path
regression (a broken sweep collapses the ratio toward 1×).  The ratio
is only meaningful for the same workload, so the gate first checks
that the workload knobs match the baseline and fails loudly on a
mismatch.  It also re-checks the structural invariants the benchmark
asserts: greedy parity, weight de-duplication, and zero jit traces
after warm-up.

The committed baseline is recorded in ``--quick`` mode — the mode CI
runs.  After intentionally changing the benchmark workload, re-seed
it:

  PYTHONPATH=src python -m benchmarks.run --quick --only fused_tick
  cp experiments/results/fused_tick.json \
     experiments/results/fused_tick_baseline.json

  PYTHONPATH=src python -m benchmarks.check_fused_baseline
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import RESULTS_DIR

BASELINE = "experiments/results/fused_tick_baseline.json"


# the ratio is only comparable between runs of the SAME workload —
# these knobs must match the baseline exactly or the gate is
# calibrated against a different benchmark
WORKLOAD_KEYS = ("n_models", "max_new", "n_per_model", "chunk_tokens",
                 "prompt_lens")


def check(current: dict, baseline: dict, tolerance: float) -> list:
    failures = []
    for key in WORKLOAD_KEYS:
        if current.get(key) != baseline.get(key):
            failures.append(
                f"workload mismatch on {key!r}: current "
                f"{current.get(key)} vs baseline {baseline.get(key)} — "
                f"re-seed the baseline JSON for the new workload")
    if failures:
        return failures
    if not current.get("parity"):
        failures.append("fused/serial token parity broken")
    if not current.get("weight_dedup_ok"):
        failures.append("fused weight bytes exceed serial (copy leaked)")
    for mode, m in current.get("modes", {}).items():
        if m.get("jit_traces_measured", 0) != 0:
            failures.append(
                f"{mode}: {m['jit_traces_measured']} jit traces after "
                f"warm-up (shape-stability regression)")
    cur = current.get("speedup_aggregate", 0.0)
    base = baseline.get("speedup_aggregate", 0.0)
    floor = base * (1.0 - tolerance)
    if cur < floor:
        failures.append(
            f"speedup_aggregate regressed: {cur:.3f}× < {floor:.3f}× "
            f"(baseline {base:.3f}× − {tolerance:.0%})")
    else:
        print(f"[check_fused_baseline] speedup_aggregate: {cur:.3f}× "
              f"(baseline {base:.3f}×, floor {floor:.3f}×) OK")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--result", default=os.path.join(RESULTS_DIR,
                                                     "fused_tick.json"))
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.15)
    args = ap.parse_args()

    with open(args.result) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = check(current, baseline, args.tolerance)
    if failures:
        for msg in failures:
            print(f"[check_fused_baseline] FAIL: {msg}")
        return 1
    print("[check_fused_baseline] fused tick within baseline envelope")
    return 0


if __name__ == "__main__":
    sys.exit(main())
