"""Prefix-cache benchmark — the CI gate on copy-on-write prefix
sharing in the unified KV pool (serving/kvcache.py, DESIGN.md §13).

Four properties are asserted, all on the deterministic tick-cost
clock so the gates are bit-reproducible:

  1. **Parity** — the cache is free when it never hits: at reuse 0
     (every prompt unique) a cache-enabled run reproduces the
     cache-disabled run bit-for-bit (attainment, ticks, TTFT p50).
  2. **Monotone gain** — a nested reuse sweep (the generator draws
     identical arrivals/lengths/suffixes at every reuse level; only
     the prefix-vs-unique coin differs) never *hurts* mean SLO
     attainment as reuse grows, with the cache on.
  3. **Strict win** — at high reuse the cache strictly improves
     aggregate TTFT p50 and strictly improves SLO attainment at ≥ 1
     scale versus the cache-disabled run of the same trace.
  4. **Hit-rate floor** — the measured request hit rate reaches at
     least ``HIT_FLOOR_FACTOR`` × the trace's analytic ceiling
     (``core.workload.prefix_repeat_fraction``); the gap is the
     concurrent-admission window (a request that arrives before its
     prefix donor finished prefill finds nothing to adopt).

Records ``experiments/results/prefix_cache.json`` with the full
per-reuse reports (uploaded by CI next to the other artifacts).
"""
from __future__ import annotations

from repro.core.workload import (power_law_rates, prefix_repeat_fraction,
                                 shared_prefix_trace)
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  serve_workload)

from benchmarks.common import save

ARCH = "qwen2-7b"
N_MODELS = 3
ALPHA = 2.1
CHUNK_TOKENS = 16
MAX_SLOTS = 4
MEAN_PROMPT, MEAN_OUTPUT = 48, 10
PREFIX_LEN, N_PREFIXES = 48, 4
SLO_SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0)
REUSE_LEVELS = (0.0, 0.5, 0.9)
HIT_FLOOR_FACTOR = 0.5
COST = TickCostModel()


def _unit(names, rates, pool_blocks: int, cache: bool):
    return build_unit_from_specs(
        [(n, ARCH, rates[n]) for n in names], pool_blocks=pool_blocks,
        max_slots=MAX_SLOTS, chunk_tokens=CHUNK_TOKENS, seed=0,
        policy="adbs", fused=True, prefix_cache=cache)


def _serve(names, rates, wl, pool_blocks: int, cache: bool):
    return serve_workload([_unit(names, rates, pool_blocks, cache)], wl,
                          seed=1, slo_scales=SLO_SCALES, cost=COST)


def _attainment(rep) -> dict:
    return {s: rep.aggregate.attainment[s] for s in SLO_SCALES}


def _hits_lookups(rep) -> tuple:
    hits = sum(p["hits"] for p in rep.prefix.values())
    lookups = sum(p["lookups"] for p in rep.prefix.values())
    return hits, lookups


def run(quick: bool = False, max_rate: float = 12.0, horizon: float = 4.0,
        pool_blocks: int = 20_000) -> dict:
    if quick:
        max_rate, horizon = 12.0, 3.0
    names = [f"llm{i}" for i in range(N_MODELS)]
    rates = power_law_rates(names, ALPHA, max_rate)

    def trace(reuse: float):
        return shared_prefix_trace(
            rates, horizon, seed=0, mean_prompt=MEAN_PROMPT,
            mean_output=MEAN_OUTPUT, max_len=256,
            n_prefixes=N_PREFIXES, prefix_len=PREFIX_LEN, reuse=reuse)

    traces = {r: trace(r) for r in REUSE_LEVELS}
    wl0 = traces[REUSE_LEVELS[0]]
    # the sweep is nested: every reuse level replays the same arrivals,
    # the same lengths and the same unique suffixes — only the shared
    # prefixes differ.  Anything else would make gate 2 meaningless.
    for r, wl in traces.items():
        assert [(q.model, q.arrival, q.prompt_len, q.output_len)
                for q in wl.requests] ==\
               [(q.model, q.arrival, q.prompt_len, q.output_len)
                for q in wl0.requests], f"reuse sweep not nested at {r}"

    out = {
        "arch": ARCH, "n_models": N_MODELS, "alpha": ALPHA,
        "max_rate": max_rate, "horizon": horizon,
        "pool_blocks": pool_blocks, "n_requests": len(wl0.requests),
        "rates": rates, "slo_scales": list(SLO_SCALES),
        "reuse_levels": list(REUSE_LEVELS),
        "hit_floor_factor": HIT_FLOOR_FACTOR, "runs": {},
    }
    print(f"[prefix] {len(wl0.requests)} requests, α={ALPHA}, rates "
          f"{{{', '.join(f'{n}:{r:.2f}' for n, r in rates.items())}}}")

    # ---- gate 1: reuse-0 cache-on == cache-off bit-for-bit -----------
    base0 = _serve(names, rates, wl0, pool_blocks, cache=False)
    on0 = _serve(names, rates, wl0, pool_blocks, cache=True)
    out["runs"]["reuse_0.0_off"] = base0.to_json()
    out["runs"]["reuse_0.0_on"] = on0.to_json()
    assert _attainment(base0) == _attainment(on0),\
        ("a never-hitting cache must reproduce the uncached run "
         "bit-for-bit", _attainment(base0), _attainment(on0))
    assert base0.ticks == on0.ticks and base0.horizon == on0.horizon
    assert base0.aggregate.ttft.p50 == on0.aggregate.ttft.p50
    hits0, _ = _hits_lookups(on0)
    assert hits0 == 0, ("unique prompts must never hit", on0.prefix)
    print(f"[prefix] parity: reuse 0 cache-on == cache-off "
          f"({base0.ticks} ticks, TTFT p50 "
          f"{base0.aggregate.ttft.p50:.3f}s, 0 hits)")

    # ---- gate 2: mean attainment monotone in reuse (cache on) --------
    means = []
    reps = {}
    for r in REUSE_LEVELS:
        rep = on0 if r == 0.0 else _serve(names, rates, traces[r],
                                          pool_blocks, cache=True)
        reps[r] = rep
        att = _attainment(rep)
        mean = sum(att.values()) / len(att)
        means.append(mean)
        hits, lookups = _hits_lookups(rep)
        out["runs"][f"reuse_{r}_on"] = rep.to_json()
        print(f"[prefix] reuse {r}: {hits}/{lookups} hits, TTFT p50 "
              f"{rep.aggregate.ttft.p50:.3f}s, mean attainment {mean:.4f}")
    out["mean_attainment_by_reuse"] = means
    for lo, hi in zip(means[:-1], means[1:]):
        assert hi >= lo - 1e-9,\
            ("attainment must not degrade as prefix reuse grows "
             "(nested traces)", means)
    print(f"[prefix] monotone gain: {[f'{m:.4f}' for m in means]}")

    # ---- gates 3+4: strict win and hit-rate floor at high reuse ------
    hi = REUSE_LEVELS[-1]
    wl_hi = traces[hi]
    base_hi = _serve(names, rates, wl_hi, pool_blocks, cache=False)
    rep_hi = reps[hi]
    out["runs"][f"reuse_{hi}_off"] = base_hi.to_json()
    assert rep_hi.aggregate.ttft.p50 < base_hi.aggregate.ttft.p50,\
        ("prefix caching must strictly improve aggregate TTFT p50 at "
         f"reuse {hi}", rep_hi.aggregate.ttft.p50,
         base_hi.aggregate.ttft.p50)
    att_on, att_off = _attainment(rep_hi), _attainment(base_hi)
    assert any(att_on[s] > att_off[s] for s in SLO_SCALES),\
        ("prefix caching must strictly improve SLO attainment at ≥ 1 "
         "scale", att_on, att_off)
    assert all(att_on[s] >= att_off[s] - 1e-9 for s in SLO_SCALES),\
        ("prefix caching must not trade one scale against another",
         att_on, att_off)
    print(f"[prefix] strict win at reuse {hi}: TTFT p50 "
          f"{base_hi.aggregate.ttft.p50:.3f}s → "
          f"{rep_hi.aggregate.ttft.p50:.3f}s")

    bound = prefix_repeat_fraction(wl_hi)
    hits, lookups = _hits_lookups(rep_hi)
    measured = hits / lookups if lookups else 0.0
    out["hit_rate"] = {"measured": measured, "analytic_ceiling": bound,
                       "floor_factor": HIT_FLOOR_FACTOR}
    assert measured >= HIT_FLOOR_FACTOR * bound,\
        ("measured hit rate fell below the floor", measured, bound)
    print(f"[prefix] hit rate {measured:.2%} ≥ "
          f"{HIT_FLOOR_FACTOR} × ceiling {bound:.2%}")

    save("prefix_cache", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
