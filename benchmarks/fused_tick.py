"""Fused multi-LLM tick vs serial per-engine ticks — the real runtime
(DESIGN.md §2), not the discrete-event simulator.

Colocates N same-architecture reduced LLMs on one unified KV pool and
drains an identical MIXED prefill+decode workload twice: once with the
serial tick (per-engine chunked-prefill and decode dispatches) and
once with ``fused=True`` (one jitted stacked-weights prefill sweep +
one decode sweep per iteration, zero-copy weights).  Greedy decoding
makes the generated tokens identical in both modes (asserted), so the
throughput ratios isolate the dispatch/launch amortization of the
fusion.  Alongside tokens/s the harness records:

  * weight HBM bytes (de-duplicated — the zero-copy win) and pool
    arena bytes (grown by the reclaimed weight copy in fused mode);
  * jit trace counts during the measured drain — shape-stable
    bucketing means ZERO compilations after warm-up (asserted over a
    drain of ≥ 50 ticks).

``check_fused_baseline.py`` gates CI on the aggregate fused/serial
speedup of this harness against a committed baseline JSON.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import replace
from repro.models.transformer import init_params
from repro.serving.engine import (TRACE_COUNTS, Engine, Request,
                                  unique_tree_bytes)
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler

from benchmarks.common import save

# deterministic prompt-length cycle: spans 2-4 chunks so the prefill
# phase is a real fraction of the work, and keeps the shape buckets of
# the warm-up and measured drains identical
PROMPT_LENS = (24, 40, 56)
CHUNK_TOKENS = 16
# block-table width sized to the workload envelope (16 blocks = 256
# tokens vs a max sequence of 56+24): the attention gather scales with
# table width, and a 64-wide table for 5-block sequences buries the
# dispatch-amortization signal under 92% wasted gather traffic
MAX_BLOCKS = 16


def _build(n_models: int, fused: bool, arch: str = "qwen2-7b",
           max_slots: int = 4, pool_blocks: int = 200_000):
    base = configs.get_reduced(arch)
    pool = UnifiedKVPool(pool_blocks, base.hd, dtype=jnp.float32)
    engines = {}
    for i in range(n_models):
        cfg = replace(base, name=f"llm{i}")
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, pool_blocks // n_models)
        engines[cfg.name] = Engine(cfg, params, view, max_slots=max_slots,
                                   chunk_tokens=CHUNK_TOKENS,
                                   max_blocks_per_seq=MAX_BLOCKS)
    return MuxScheduler(engines, pool, policy="adbs", fused=fused)


def _submit(mux: MuxScheduler, n_per_model: int, max_new: int,
            seed: int, rid_base: int = 0) -> int:
    """Submit one wave; request ids start at ``rid_base`` so ids stay
    unique across waves (the parity check keys on them)."""
    rng = np.random.default_rng(seed)
    rid = rid_base
    for name, eng in mux.engines.items():
        for j in range(n_per_model):
            plen = PROMPT_LENS[j % len(PROMPT_LENS)]
            prompt = list(rng.integers(1, eng.cfg.vocab_size, plen))
            mux.submit(Request(rid, name, prompt, max_new))
            rid += 1
    return rid - rid_base


def _drain(mux: MuxScheduler) -> float:
    t0 = time.perf_counter()
    mux.run(max_ticks=5_000)
    return time.perf_counter() - t0


def run(quick: bool = False) -> dict:
    # quick still needs enough steps for the fused/serial gap to rise
    # above tick-level noise (very short drains are warmup-bound) and a
    # ≥50-tick measured drain for the compile-constancy assertion
    n_models = 3
    max_new = 20 if quick else 24
    n_per_model = 6 if quick else 8

    out = {"n_models": n_models, "max_new": max_new,
           "n_per_model": n_per_model, "chunk_tokens": CHUNK_TOKENS,
           "prompt_lens": list(PROMPT_LENS), "modes": {}}
    outputs = {}
    for fused in (False, True):
        mux = _build(n_models, fused)
        # warmup drain: compiles the jit programs for every shape
        # bucket the measured drain revisits (both modes get the same
        # treatment) — bucketed batching makes this set bounded
        _submit(mux, n_per_model, max_new, seed=1)
        _drain(mux)
        base_prefill = mux.stats.prefill_tokens
        base_decode = mux.stats.decode_tokens
        base_ticks = mux.stats.ticks
        base_finished = len(mux.stats.finished)
        traces_warm = sum(TRACE_COUNTS.values())
        # two measured waves: enough ticks (>50 in either mode) for the
        # compile-constancy assertion to mean something
        n = 0
        wall = 0.0
        for wave in range(2):
            n += _submit(mux, n_per_model, max_new, seed=2 + wave,
                         rid_base=n)
            wall += _drain(mux)
        traces_measured = sum(TRACE_COUNTS.values()) - traces_warm
        prefill_tok = mux.stats.prefill_tokens - base_prefill
        decode_tok = mux.stats.decode_tokens - base_decode
        ticks = mux.stats.ticks - base_ticks
        finished = mux.stats.finished[base_finished:]
        assert len(finished) == n, (len(finished), n)
        assert ticks >= 50, f"need a ≥50-tick measured drain, got {ticks}"
        assert traces_measured == 0,\
            f"shape-stable serving must not re-trace ({traces_measured})"
        outputs[fused] = {r.req_id: r.output for r in finished}
        mode = "fused" if fused else "serial"
        out["modes"][mode] = {
            "prefill_tokens": prefill_tok,
            "decode_tokens": decode_tok,
            "wall_s": wall,
            "ticks": ticks,
            "prefill_tok_per_s": prefill_tok / max(wall, 1e-9),
            "decode_tok_per_s": decode_tok / max(wall, 1e-9),
            "aggregate_tok_per_s": (prefill_tok + decode_tok)
                                   / max(wall, 1e-9),
            "jit_traces_measured": traces_measured,
            "weight_hbm_bytes": unique_tree_bytes(
                [e.params for e in mux.engines.values()]),
            "pool_hbm_bytes": mux.pool.hbm_bytes(),
            "pool_head_blocks": mux.pool.n_head_blocks,
            "reclaimed_weight_bytes": mux.reclaimed_weight_bytes,
        }
        m = out["modes"][mode]
        print(f"[fused_tick] {mode:6s}: {prefill_tok} prefill + "
              f"{decode_tok} decode tokens in {wall:.2f}s over {ticks} "
              f"ticks → {m['aggregate_tok_per_s']:.1f} tok/s aggregate "
              f"({m['prefill_tok_per_s']:.1f} prefill, "
              f"{m['decode_tok_per_s']:.1f} decode; "
              f"{traces_measured} jit traces, "
              f"{m['weight_hbm_bytes'] / 1e6:.1f} MB weights, "
              f"{m['pool_hbm_bytes'] / 1e6:.0f} MB pool, "
              f"{len(mux.fused_groups)} fused groups)")

    assert len(outputs[True]) == len(outputs[False]) == 2 * n_models\
        * n_per_model, "req ids must be unique across measured waves"
    assert outputs[True] == outputs[False],\
        "fused and serial ticks must produce identical tokens"
    out["parity"] = True
    s, f = out["modes"]["serial"], out["modes"]["fused"]
    # ONE speedup number: parity makes both modes process identical
    # token counts, so every per-phase ratio reduces to the same
    # wall-clock ratio — reporting phase-wise "speedups" would imply a
    # per-phase timing that doesn't exist
    out["speedup_aggregate"] = (f["aggregate_tok_per_s"]
                                / max(s["aggregate_tok_per_s"], 1e-9))
    # the zero-copy win, in bytes: fused weights must not exceed serial
    # weights (ONE stacked tree vs N private trees), and the reclaimed
    # copy shows up as extra pool arena
    out["weight_dedup_ok"] = f["weight_hbm_bytes"] <= s["weight_hbm_bytes"]
    assert out["weight_dedup_ok"], (f["weight_hbm_bytes"],
                                    s["weight_hbm_bytes"])
    print(f"[fused_tick] fused/serial: {out['speedup_aggregate']:.2f}× "
          f"aggregate tok/s; fused pool grew by "
          f"{f['pool_head_blocks'] - s['pool_head_blocks']} "
          f"head-blocks from reclaimed weights")
    save("fused_tick", out)
    return out


if __name__ == "__main__":
    run()
