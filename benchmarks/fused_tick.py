"""Fused multi-LLM decode tick vs serial per-engine ticks — the real
runtime (DESIGN.md §2), not the discrete-event simulator.

Colocates N same-architecture reduced LLMs on one unified KV pool and
drains an identical decode-heavy workload twice: once with the serial
tick (N sequential ``Engine.decode`` dispatches per scheduler
iteration) and once with ``fused=True`` (one jitted stacked-weights
sweep per iteration).  Greedy decoding makes the generated tokens
identical in both modes (asserted), so the aggregate decode tokens/s
ratio isolates the dispatch/launch amortization of the fusion.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import replace
from repro.models.transformer import init_params
from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler

from benchmarks.common import save


def _build(n_models: int, fused: bool, arch: str = "qwen2-7b",
           max_slots: int = 4, pool_blocks: int = 200_000):
    base = configs.get_reduced(arch)
    pool = UnifiedKVPool(pool_blocks, base.hd, dtype=jnp.float32)
    engines = {}
    for i in range(n_models):
        cfg = replace(base, name=f"llm{i}")
        params = init_params(jax.random.PRNGKey(i), cfg, jnp.float32)
        view = pool.register_model(cfg, pool_blocks // n_models)
        engines[cfg.name] = Engine(cfg, params, view, max_slots=max_slots)
    return MuxScheduler(engines, pool, policy="adbs", fused=fused)


def _submit(mux: MuxScheduler, n_per_model: int, max_new: int,
            seed: int) -> int:
    rng = np.random.default_rng(seed)
    rid = 0
    for name, eng in mux.engines.items():
        for _ in range(n_per_model):
            prompt = list(rng.integers(1, eng.cfg.vocab_size, 8))
            mux.submit(Request(rid, name, prompt, max_new))
            rid += 1
    return rid


def _drain(mux: MuxScheduler) -> float:
    t0 = time.perf_counter()
    mux.run(max_ticks=5_000)
    return time.perf_counter() - t0


def run(quick: bool = False) -> dict:
    # quick still needs enough decode steps for the fused/serial gap to
    # rise above tick-level noise (very short drains are warmup-bound)
    n_models = 3
    max_new = 16 if quick else 24
    n_per_model = 6 if quick else 8

    out = {"n_models": n_models, "max_new": max_new,
           "n_per_model": n_per_model, "modes": {}}
    outputs = {}
    for fused in (False, True):
        mux = _build(n_models, fused)
        # warmup drain: compiles the jit paths for the batch shapes the
        # measured drain revisits (both modes get the same treatment)
        _submit(mux, n_per_model, max_new, seed=1)
        _drain(mux)
        base_decode = mux.stats.decode_tokens
        base_finished = len(mux.stats.finished)
        n = _submit(mux, n_per_model, max_new, seed=2)
        wall = _drain(mux)
        decode_tok = mux.stats.decode_tokens - base_decode
        finished = mux.stats.finished[base_finished:]
        assert len(finished) == n, (len(finished), n)
        outputs[fused] = {r.req_id: r.output for r in finished}
        tps = decode_tok / max(wall, 1e-9)
        mode = "fused" if fused else "serial"
        out["modes"][mode] = {"decode_tokens": decode_tok, "wall_s": wall,
                              "decode_tok_per_s": tps}
        print(f"[fused_tick] {mode:6s}: {decode_tok} decode tokens in "
              f"{wall:.2f}s → {tps:.1f} tok/s "
              f"({len(mux.fused_groups)} fused groups)")

    assert outputs[True] == outputs[False], \
        "fused and serial ticks must produce identical tokens"
    out["parity"] = True
    out["speedup"] = (out["modes"]["fused"]["decode_tok_per_s"]
                      / max(out["modes"]["serial"]["decode_tok_per_s"],
                            1e-9))
    print(f"[fused_tick] fused/serial decode throughput: "
          f"{out['speedup']:.2f}×")
    save("fused_tick", out)
    return out


if __name__ == "__main__":
    run()
