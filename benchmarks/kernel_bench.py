"""Kernel micro-benchmarks (CSV): wall time of the XLA reference path
and the Pallas kernels in interpret mode (correctness-path timing on
CPU — TPU timings require hardware; the dry-run covers the lowering).

Prints ``name,us_per_call,derived`` rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked
from repro.serving import cache_ops


def _time(fn, *args, n=5) -> float:
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = False) -> dict:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash prefill (XLA oracle path at a serving-ish shape)
    b, s, h, hd = 1, 1024, 8, 64
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    v = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    t_ref = _time(jax.jit(ref.flash_prefill_ref), q, k, v)
    rows.append(("flash_prefill_xla_ref", t_ref,
                 f"b{b}s{s}h{h}d{hd}"))
    t_pl = _time(lambda *a: flash_prefill(*a, block_q=256, block_k=256,
                                          interpret=True), q, k, v, n=1)
    rows.append(("flash_prefill_pallas_interp", t_pl, "interpret=True"))

    # paged decode attention
    bt, nb, kv = 16, 8, 2
    group = 1 * kv
    pool_k = jax.random.normal(key, (nb * group * 4, bt, hd), jnp.float32)
    pool_v = jax.random.normal(key, (nb * group * 4, bt, hd), jnp.float32)
    qd = jax.random.normal(key, (4, h, hd), jnp.float32)
    table = jnp.arange(4 * nb, dtype=jnp.int32).reshape(4, nb) * group
    lens = jnp.full((4,), nb * bt, jnp.int32)
    t_ref = _time(jax.jit(lambda *a: cache_ops.paged_decode_attention(
        *a, 0, kv)), qd, pool_k, pool_v, table, lens)
    rows.append(("paged_decode_xla_ref", t_ref, f"b4 blocks{nb} bt{bt}"))
    t_pl = _time(lambda *a: paged_decode_attention(
        *a, 0, n_kv=kv, interpret=True), qd, pool_k, pool_v, table, lens,
        n=1)
    rows.append(("paged_decode_pallas_interp", t_pl, "interpret=True"))

    # SSD scan
    b2, s2, h2, p2, n2 = 1, 512, 4, 64, 64
    x = jax.random.normal(key, (b2, s2, h2, p2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (b2, s2, h2))) * 0.1
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h2))
    B = jax.random.normal(key, (b2, s2, 1, n2), jnp.float32)
    C = jax.random.normal(key, (b2, s2, 1, n2), jnp.float32)
    d_skip = jnp.ones((h2,))
    t_ref = _time(jax.jit(lambda *a: ssd_chunked(*a, 128)), x, dt, a_log,
                  B, C, d_skip)
    rows.append(("ssd_scan_xla_ref", t_ref, f"s{s2}h{h2}p{p2}n{n2}"))
    t_pl = _time(lambda *a: ssd_scan(*a, chunk=128, interpret=True), x,
                 dt, a_log, B, C, d_skip, n=1)
    rows.append(("ssd_scan_pallas_interp", t_pl, "interpret=True"))

    print("name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
    from benchmarks.common import save
    save("kernel_bench", {"rows": [
        {"name": n, "us": u, "derived": d} for n, u, d in rows]})
    return {"rows": rows}


if __name__ == "__main__":
    run()
