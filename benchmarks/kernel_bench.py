"""Kernel micro-benchmarks (CSV): wall time of the XLA reference path
and the Pallas kernels in interpret mode (correctness-path timing on
CPU — TPU timings require hardware; the dry-run covers the lowering).

Prints ``name,us_per_call,derived`` rows.  ``--quick`` trims shapes and
iteration counts for the per-PR CI smoke job; the JSON written by
``benchmarks.common.save`` is uploaded as a build artifact so fused
decode-path regressions are visible per PR.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked
from repro.serving import cache_ops


def _time(fn, *args, n=5) -> float:
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(quick: bool = False) -> dict:
    rows = []
    key = jax.random.PRNGKey(0)
    n_iters = 2 if quick else 5

    # flash prefill (XLA oracle path at a serving-ish shape)
    b, s, h, hd = (1, 256, 4, 64) if quick else (1, 1024, 8, 64)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    v = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    t_ref = _time(jax.jit(ref.flash_prefill_ref), q, k, v, n=n_iters)
    rows.append(("flash_prefill_xla_ref", t_ref,
                 f"b{b}s{s}h{h}d{hd}"))
    blk = 128 if quick else 256        # full-mode baseline unchanged
    t_pl = _time(lambda *a: flash_prefill(*a, block_q=blk, block_k=blk,
                                          interpret=True), q, k, v, n=1)
    rows.append(("flash_prefill_pallas_interp", t_pl, "interpret=True"))

    # paged decode attention
    bt, nb, kv = 16, (4 if quick else 8), 2
    group = 1 * kv
    pool_k = jax.random.normal(key, (nb * group * 4, bt, hd), jnp.float32)
    pool_v = jax.random.normal(key, (nb * group * 4, bt, hd), jnp.float32)
    qd = jax.random.normal(key, (4, h, hd), jnp.float32)
    table = jnp.arange(4 * nb, dtype=jnp.int32).reshape(4, nb) * group
    lens = jnp.full((4,), nb * bt, jnp.int32)
    t_ref = _time(jax.jit(lambda *a: cache_ops.paged_decode_attention(
        *a, 0, kv)), qd, pool_k, pool_v, table, lens, n=n_iters)
    rows.append(("paged_decode_xla_ref", t_ref, f"b4 blocks{nb} bt{bt}"))
    t_pl = _time(lambda *a: paged_decode_attention(
        *a, 0, n_kv=kv, interpret=True), qd, pool_k, pool_v, table, lens,
        n=1)
    rows.append(("paged_decode_pallas_interp", t_pl, "interpret=True"))

    # fused multi-LLM decode attention (DESIGN.md §2): M colocated
    # models' rows in ONE sweep vs M sequential per-model sweeps.
    M = 2 if quick else 4
    # per-model tables are DISJOINT: model m owns [m*4*nb*group, ...)
    # (each model's table spans 4 sequences × nb blocks × group ids)
    tables = [table + m * 4 * nb * group for m in range(M)]
    qs = [jax.random.normal(jax.random.PRNGKey(m), (4, h, hd), jnp.float32)
          for m in range(M)]
    pool_fk = jax.random.normal(key, (M * 4 * nb * group + 8, bt, hd),
                                jnp.float32)
    pool_fv = jax.random.normal(key, (M * 4 * nb * group + 8, bt, hd),
                                jnp.float32)

    # serial = M separate jitted dispatches (what the serial tick pays);
    # fused = ONE jitted sweep over the concatenated rows
    serial_one = jax.jit(lambda q, t, pk, pv: cache_ops.
                         paged_decode_attention(q, pk, pv, t, lens, 0, kv))

    def serial_sweep(pool_k, pool_v):
        out = None
        for m in range(M):
            out = serial_one(qs[m], tables[m], pool_k, pool_v)
        return out

    def fused_sweep(pool_k, pool_v):
        phys = jnp.concatenate([cache_ops.resolve_physical_blocks(
            tables[m], 0, kv) for m in range(M)])
        return cache_ops.fused_paged_decode_attention(
            jnp.concatenate(qs), pool_k, pool_v, phys,
            jnp.concatenate([lens] * M))

    t_serial = _time(serial_sweep, pool_fk, pool_fv, n=n_iters)
    rows.append(("fused_decode_serial_dispatch", t_serial,
                 f"{M} models x b4 blocks{nb}"))
    t_fused = _time(jax.jit(fused_sweep), pool_fk, pool_fv, n=n_iters)
    rows.append(("fused_decode_one_sweep", t_fused,
                 f"1 sweep x {M * 4} rows"))

    # SSD scan
    b2, s2, h2, p2, n2 = (1, 128, 2, 64, 32) if quick\
        else (1, 512, 4, 64, 64)
    x = jax.random.normal(key, (b2, s2, h2, p2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (b2, s2, h2))) * 0.1
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h2))
    B = jax.random.normal(key, (b2, s2, 1, n2), jnp.float32)
    C = jax.random.normal(key, (b2, s2, 1, n2), jnp.float32)
    d_skip = jnp.ones((h2,))
    chunk = 64 if quick else 128       # full-mode baseline unchanged
    t_ref = _time(jax.jit(lambda *a: ssd_chunked(*a, chunk)), x, dt, a_log,
                  B, C, d_skip, n=n_iters)
    rows.append(("ssd_scan_xla_ref", t_ref, f"s{s2}h{h2}p{p2}n{n2}"))
    t_pl = _time(lambda *a: ssd_scan(*a, chunk=chunk, interpret=True), x,
                 dt, a_log, B, C, d_skip, n=1)
    rows.append(("ssd_scan_pallas_interp", t_pl, "interpret=True"))

    print("name,us_per_call,derived")
    for name, us, extra in rows:
        print(f"{name},{us:.1f},{extra}")
    from benchmarks.common import save
    path = save("kernel_bench", {"quick": quick, "rows": [
        {"name": n, "us": u, "derived": d} for n, u, d in rows]})
    print(f"[kernel_bench] results → {path}")
    return {"rows": rows}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few iters (CI smoke job)")
    run(quick=ap.parse_args().quick)   # exceptions → non-zero exit
