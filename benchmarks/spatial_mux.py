"""Spatial-temporal compute multiplexing vs pure temporal multiplexing
on REAL engines — the runtime proof that enforcing the placement's
``sm_frac`` (DESIGN.md §11) earns its keep, the way the paper's Fig. 5
argues MuxServe's computation multiplexing does.

One colocated 3-LLM unit (same architecture, popularity-skewed α=2.1
arrivals) serves the SAME trace twice under the deterministic
tick-cost clock:

  * **temporal** — the unit is built with ``enforce_shares=False``:
    every job is charged as if it held the whole mesh in turn (the
    legacy accounting — time-sliced round-robin over full-mesh jobs,
    i.e. temporal multiplexing with equal shares);
  * **spatial-temporal** — the same placement with its planned
    compute shares enforced: decode jobs run concurrently, each under
    its ``sm_frac`` (popularity-proportional, filling the mesh — the
    hot LLM holds the big share, exactly like the popularity-
    proportional KV-quota split), prefill fills the residual compute,
    and ``TickCostModel.tick_dt`` charges phases by effective share
    with roofline flatness and contention.

The placement itself comes from the optimizer's greedy assignment
(``core/placement.place_onto_meshes`` — Alg. 1's inner loop) at paper
scale; Alg. 2's *minimal* per-LLM fractions guarantee each arrival
rate and leave the rest to prefill, so for the attainment comparison
the decode shares are then scaled ∝ popularity to fill the mesh (the
share analogue of the rate-proportional quota grant in
``build_unit_from_specs`` — idle SMs help nobody).

CI gates on the ordering (deterministic clock → bit-reproducible):
the spatial-temporal configuration must strictly beat the pure
temporal one in SLO attainment at EVERY scale (asserted), which is
exactly the sim↔runtime gap this mechanism closes — the simulator
always modeled Eq. 3's concurrent decode, the runtime used to drop
``sm_frac`` on the floor.

Artifact: ``experiments/results/spatial_mux.json``.
"""
from __future__ import annotations

from repro import configs
from repro.config import replace
from repro.core.placement import place_onto_meshes
from repro.core.workload import synthesize
from repro.serving.driver import (TickCostModel, serve_workload,
                                  units_from_placement)

from benchmarks.common import save

ARCH = "qwen2-7b"
N_MODELS = 3
N_DEVICES = 4
ALPHA = 2.1                 # strong popularity skew (paper §4.2)
CHUNK_TOKENS = 16
MAX_SLOTS = 4
MEAN_PROMPT, MEAN_OUTPUT = 24, 12
SLO_SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0)
COST = TickCostModel()
SHARE_FLOOR = 0.05


def planned_placement(rates, mean_prompt: int, mean_output: int):
    """Optimizer placement for the colocated mesh, with decode shares
    scaled ∝ popularity to fill the mesh (Alg. 2's minimal fractions
    are rate guarantees, not the attainment-optimal split)."""
    cfg = configs.get(ARCH)
    models = [(replace(cfg, name=n), r) for n, r in rates.items()]
    pl = place_onto_meshes(models, [(0, N_DEVICES)],
                           mean_prompt=mean_prompt,
                           mean_output=mean_output,
                           archs={n: ARCH for n in rates})
    rate_sum = sum(rates.values()) or 1.0
    for m in pl.meshes:
        for s in m.specs:
            s.sm_frac = max(round(s.rate / rate_sum, 2), SHARE_FLOOR)
    return pl


def _serve(pl, wl, enforce: bool, pool_blocks: int):
    units = units_from_placement(pl, pool_blocks=pool_blocks,
                                 max_slots=MAX_SLOTS,
                                 chunk_tokens=CHUNK_TOKENS, seed=0,
                                 policy="adbs", fused=True,
                                 enforce_shares=enforce)
    return serve_workload(units, wl, seed=1, slo_scales=SLO_SCALES,
                          cost=COST)


def run(quick: bool = False, max_rate: float = 60.0,
        horizon: float = 3.0, pool_blocks: int = 20_000) -> dict:
    if quick:
        max_rate, horizon = 60.0, 2.5
    names = [f"llm{i}" for i in range(N_MODELS)]
    wl = synthesize(names, alpha=ALPHA, max_rate=max_rate, horizon=horizon,
                    seed=0, mean_prompt=MEAN_PROMPT, mean_output=MEAN_OUTPUT,
                    max_len=256)
    pl = planned_placement(wl.rates, MEAN_PROMPT, MEAN_OUTPUT)
    shares = {s.name: s.sm_frac for m in pl.meshes for s in m.specs}
    print(f"[spatial_mux] {len(wl.requests)} requests, α={ALPHA}, rates "
          f"{{{', '.join(f'{n}:{r:.2f}' for n, r in wl.rates.items())}}}, "
          f"planned shares "
          f"{{{', '.join(f'{n}:{f:.2f}' for n, f in shares.items())}}}")

    out = {
        "arch": ARCH, "n_models": N_MODELS, "n_devices": N_DEVICES,
        "alpha": ALPHA, "max_rate": max_rate, "horizon": horizon,
        "mean_prompt": MEAN_PROMPT, "mean_output": MEAN_OUTPUT,
        "chunk_tokens": CHUNK_TOKENS, "max_slots": MAX_SLOTS,
        "pool_blocks": pool_blocks, "n_requests": len(wl.requests),
        "rates": wl.rates, "sm_frac": shares,
        "slo_scales": list(SLO_SCALES),
        "tick_cost": {"base": COST.base, "prefill_tok": COST.prefill_tok,
                      "decode_tok": COST.decode_tok,
                      "rho_prefill": COST.rho_prefill,
                      "rho_decode": COST.rho_decode},
        "modes": {},
    }
    reports = {}
    for mode, enforce in (("temporal", False), ("spatial_temporal", True)):
        rep = _serve(pl, wl, enforce, pool_blocks)
        reports[mode] = rep
        out["modes"][mode] = rep.to_json()
        agg = rep.aggregate
        att = ", ".join(f"{s:g}×:{agg.attainment[s]:.2f}"
                        for s in SLO_SCALES)
        print(f"[spatial_mux] {mode:16s}: "
              f"{agg.finished}/{agg.submitted} finished over "
              f"{rep.horizon:.2f} logical s ({rep.ticks} ticks) | "
              f"e2e p99={agg.e2e.p99:.3f}s ttft p99={agg.ttft.p99:.3f}s "
              f"| SLO[{att}]")

    # the tentpole claim, gated: enforcing the planned shares must
    # strictly beat pure temporal multiplexing at every SLO scale
    att_t = reports["temporal"].aggregate.attainment
    att_s = reports["spatial_temporal"].aggregate.attainment
    wins = {s: (att_s[s], att_t[s]) for s in SLO_SCALES}
    out["spatial_strictly_wins_all_scales"] =\
        all(att_s[s] > att_t[s] for s in SLO_SCALES)
    assert out["spatial_strictly_wins_all_scales"], (
        "planned spatial-temporal shares must strictly beat pure "
        f"temporal multiplexing at every SLO scale; (spatial, temporal) "
        f"per scale = {wins}")
    # and it must not pay for the win with throughput (horizon is the
    # finish time of the same request set — lower = faster)
    out["horizon_temporal"] = reports["temporal"].horizon
    out["horizon_spatial"] = reports["spatial_temporal"].horizon
    assert reports["spatial_temporal"].horizon\
        <= reports["temporal"].horizon * 1.05,\
        "share enforcement must not slow the drain materially"
    print(f"[spatial_mux] spatial-temporal strictly wins at every scale; "
          f"drain {out['horizon_spatial']:.2f}s vs temporal "
          f"{out['horizon_temporal']:.2f}s")
    save("spatial_mux", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
