"""Shared helpers for the benchmark harnesses."""
from __future__ import annotations

import json
import os
import time
from typing import Dict


from repro.core.placement import place, place_spatial
from repro.core.simulator import SimReport, simulate
from repro.core.workload import Workload, synthesize, table1_models

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/results")


def save(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def paper_models(n_devices: int = 32):
    """Table-1 model mix (19 LLaMA-family LLMs)."""
    return table1_models()


def workload_for(models, alpha: float, max_rate: float, horizon: float,
                 seed: int = 0, scale_to_avg=None) -> Workload:
    names = [m.name for m in models]
    return synthesize(names, alpha=alpha, max_rate=max_rate,
                      horizon=horizon, seed=seed, scale_to_avg=scale_to_avg)


def three_systems(models_rates, wl, n_devices: int,
                  slo_scales=(4, 8, 16)) -> Dict[str, SimReport]:
    """MuxServe vs spatial partitioning vs temporal multiplexing —
    the comparison of Figs. 5 & 7."""
    mux_pl = place(models_rates, n_devices=n_devices, group_limit=48)
    sp_pl = place_spatial(models_rates, n_devices=n_devices)
    return {
        "muxserve": simulate(mux_pl, wl, mode="spatial-temporal",
                             policy="adbs", slo_scales=slo_scales),
        "spatial": simulate(sp_pl, wl, mode="spatial", policy="adbs",
                            slo_scales=slo_scales),
        "temporal": simulate(mux_pl, wl, mode="temporal", policy="fcfs",
                             slo_scales=slo_scales),
    }


def report_row(tag: str, reports: Dict[str, SimReport]) -> dict:
    row = {"tag": tag}
    for k, r in reports.items():
        row[k] = {
            "throughput": r.throughput,
            "rate_weighted_tpt": r.rate_weighted_tpt,
            "slo": r.slo_attainment,
            "p99_latency": r.p99_latency,
            "p99_ttft": r.p99_ttft,
            "p99_tpot": r.p99_tpot,
            "finished": r.finished,
            "submitted": r.submitted,
        }
    return row


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
