"""Fig. 5: synthetic workloads — throughput + SLO attainment of
MuxServe vs spatial partitioning vs temporal multiplexing, sweeping the
popularity exponent α and the average rate.

Paper setting: 19 LLaMA-family LLMs (Table 1) on 32 GPUs; rates from a
power law with exponent α; Poisson arrivals; ShareGPT-like lengths.
Validation bands (§8 of DESIGN.md): up to ~1.8× throughput vs the best
baseline and up to ~2.9× more requests within 99% SLO attainment at
large α.
"""
from __future__ import annotations


from repro.core.workload import power_law_rates

from benchmarks.common import (paper_models, report_row, save,
                               three_systems, workload_for)

ALPHAS = [0.7, 1.3, 2.1]
RATE_SCALES = [0.5, 1.0]          # × the paper's max 20 req/s
N_DEVICES = 32
HORIZON = 30.0


def run(quick: bool = False) -> dict:
    models = paper_models()
    alphas = ALPHAS[:2] if quick else ALPHAS
    scales = RATE_SCALES[:1] if quick else RATE_SCALES
    rows = []
    for alpha in alphas:
        for scale in scales:
            max_rate = 20.0 * scale
            rates = power_law_rates([m.name for m in models], alpha,
                                    max_rate)
            models_rates = [(m, rates[m.name]) for m in models]
            wl = workload_for(models, alpha, max_rate, HORIZON, seed=0)
            reps = three_systems(models_rates, wl, N_DEVICES)
            row = report_row(f"alpha={alpha},max_rate={max_rate}", reps)
            rows.append(row)
            mx, sp, tp = (reps["muxserve"], reps["spatial"],
                          reps["temporal"])
            best_base = max(sp.throughput, tp.throughput)
            print(f"[fig5] α={alpha} rate×{scale}: mux "
                  f"{mx.throughput:.2f} req/s vs spatial "
                  f"{sp.throughput:.2f} / temporal {tp.throughput:.2f} "
                  f"→ {mx.throughput / max(best_base, 1e-9):.2f}× | "
                  f"SLO@8: {mx.slo_attainment[8]:.0%} vs "
                  f"{sp.slo_attainment[8]:.0%}/{tp.slo_attainment[8]:.0%}")
    out = {"rows": rows}
    save("fig5_synthetic", out)
    return out


if __name__ == "__main__":
    run()
