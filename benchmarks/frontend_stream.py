"""Streaming front-end benchmark — the CI gate on the async serving
layer (serving/frontend.py, router.py, metrics.py; DESIGN.md §14).

Topology: two meshes of UNEQUAL size (a 4-device fast unit and a
1-device slow unit), each hosting one replica of the same three model
families (``llm<i>@0`` fast, ``llm<i>@1`` slow).  The trace names
families, not replicas, so the router decides which mesh serves each
request; rates are popularity-skewed (α = 2.1).  Everything runs on the
deterministic tick-cost clock, so the gates are bit-reproducible.

Three properties are asserted:

  1. **Open-loop == closed-loop** — replaying the same explicit-replica
     trace through the async streaming front end reproduces the
     closed-loop driver bit-for-bit: every streamed token sequence
     equals the driver run's ``Request.output``, and attainment, tick
     count and horizon match exactly.  One scheduling loop, zero
     streaming tax.
  2. **Load-aware routing wins** — with family-named requests,
     least-loaded routing attains at least as much as blind round-robin
     at EVERY SLO scale and strictly more at some scale: round-robin
     sends half the traffic to the 4×-slower mesh and its queues back
     up; least-loaded sees the queue depth + pool pressure and shifts
     traffic to the fast mesh.
  3. **Metrics are live** — the least-loaded run's metrics snapshot
     carries per-LLM submitted/finished counters, TTFT histogram
     observations for every engine that served traffic, and router
     decision counters, all consistent with the report's roll-ups.

Records ``experiments/results/frontend_stream.json`` with both arms'
reports plus the full metrics snapshot (uploaded by CI next to the
other artifacts).
"""
from __future__ import annotations

from repro.core.workload import power_law_rates, synthesize
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  requests_from_workload, serve_requests)
from repro.serving.frontend import ServingFrontend, serve_and_collect
from repro.serving.metrics import ServingMetrics

from benchmarks.common import save

ARCH = "qwen2-7b"
FAMILIES = ("llm0", "llm1", "llm2")
ALPHA = 2.1
CHUNK_TOKENS = 16
MAX_SLOTS = 4
FAST_DEVICES, SLOW_DEVICES = 4, 1
SLO_SCALES = (1.25, 1.5, 2.0, 3.0, 4.0, 6.0)
COST = TickCostModel()


def _units(rates):
    """One replica of every family on each mesh; the fast mesh gets
    4 devices and proportionally more pool blocks, mirroring the
    placement bridge's per-mesh HBM split."""
    units = []
    for mesh_id, devices in ((0, FAST_DEVICES), (1, SLOW_DEVICES)):
        specs = [(f"{fam}@{mesh_id}", ARCH, rates[fam])
                 for fam in FAMILIES]
        blocks = 20_000 * devices // (FAST_DEVICES + SLOW_DEVICES)
        u = build_unit_from_specs(specs, pool_blocks=max(blocks, 4096),
                                  max_slots=MAX_SLOTS,
                                  chunk_tokens=CHUNK_TOKENS, seed=0,
                                  policy="adbs", fused=True)
        u.mesh_id = mesh_id
        u.n_devices = devices
        units.append(u)
    return units


def _family_requests(wl, units, seed: int = 1):
    """Materialize the trace with FAMILY model names: lengths/vocab come
    from the fast replica (all replicas share the architecture), and the
    router resolves the family to a replica at submit time."""
    proxy = {fam: units[0].engines[f"{fam}@0"] for fam in FAMILIES}
    return requests_from_workload(wl, proxy, seed=seed)


def _serve_frontend(wl, strategy, rates):
    units = _units(rates)
    reqs = _family_requests(wl, units)
    metrics = ServingMetrics()
    fe = ServingFrontend(units, reqs, strategy=strategy, metrics=metrics,
                         planned_rates=dict(rates),
                         slo_scales=SLO_SCALES, cost=COST)
    report, outs = serve_and_collect(fe)
    return report, outs, metrics


def _attainment(rep) -> dict:
    return {s: rep.aggregate.attainment[s] for s in SLO_SCALES}


def run(quick: bool = False, max_rate: float = 48.0,
        horizon: float = 3.0) -> dict:
    if quick:
        max_rate, horizon = 48.0, 2.0
    rates = power_law_rates(list(FAMILIES), ALPHA, max_rate)
    wl = synthesize(list(FAMILIES), alpha=ALPHA, max_rate=max_rate,
                    horizon=horizon, seed=0, mean_prompt=16,
                    mean_output=6, max_len=128)
    out = {
        "arch": ARCH, "families": list(FAMILIES), "alpha": ALPHA,
        "max_rate": max_rate, "horizon": horizon,
        "fast_devices": FAST_DEVICES, "slow_devices": SLOW_DEVICES,
        "n_requests": len(wl.requests), "rates": rates,
        "slo_scales": list(SLO_SCALES), "runs": {},
    }
    print(f"[frontend] {len(wl.requests)} requests over {horizon}s, "
          f"meshes {FAST_DEVICES}+{SLOW_DEVICES} devices, rates "
          f"{{{', '.join(f'{n}:{r:.2f}' for n, r in rates.items())}}}")

    # ---- gate 1: open-loop streaming == closed-loop driver ------------
    # Explicit replica names (round-robin pins each family to @0, the
    # only replica the closed-loop arm also uses) keep both arms on ONE
    # unit so the comparison is scheduling-identical.
    units_a = _units(rates)
    reqs_a = _family_requests(wl, units_a)
    for r in reqs_a:
        r.model = f"{r.model}@0"
    rep_closed = serve_requests([units_a[0]], reqs_a,
                                slo_scales=SLO_SCALES, cost=COST)
    units_b = _units(rates)
    reqs_b = _family_requests(wl, units_b)
    for r in reqs_b:
        r.model = f"{r.model}@0"
    fe = ServingFrontend([units_b[0]], reqs_b, slo_scales=SLO_SCALES,
                         cost=COST)
    rep_stream, outs = serve_and_collect(fe)
    by_id = {r.req_id: r for r in reqs_a}
    for r in reqs_b:
        stream = outs[r.req_id]
        assert stream == by_id[r.req_id].output == r.output,\
            ("streamed tokens must equal the closed-loop output "
             "bit-for-bit", r.req_id)
    assert _attainment(rep_closed) == _attainment(rep_stream)
    assert rep_closed.ticks == rep_stream.ticks
    assert rep_closed.horizon == rep_stream.horizon
    out["runs"]["closed_loop"] = rep_closed.to_json()
    out["runs"]["open_loop_stream"] = rep_stream.to_json()
    print(f"[frontend] parity: {len(reqs_b)} streams bit-identical to "
          f"the closed-loop driver ({rep_stream.ticks} ticks)")

    # ---- gate 2: least-loaded ≥ round-robin, strictly better somewhere
    rep_rr, _, _ = _serve_frontend(wl, "round_robin", rates)
    rep_ll, _, m_ll = _serve_frontend(wl, "least_loaded", rates)
    att_rr, att_ll = _attainment(rep_rr), _attainment(rep_ll)
    out["runs"]["round_robin"] = rep_rr.to_json()
    out["runs"]["least_loaded"] = rep_ll.to_json()
    for s in SLO_SCALES:
        print(f"[frontend] scale {s}: round_robin {att_rr[s]:.4f}  "
              f"least_loaded {att_ll[s]:.4f}")
    assert all(att_ll[s] >= att_rr[s] - 1e-9 for s in SLO_SCALES),\
        ("least-loaded routing must not lose to round-robin at any "
         "scale", att_ll, att_rr)
    assert any(att_ll[s] > att_rr[s] + 1e-9 for s in SLO_SCALES),\
        ("least-loaded routing must strictly beat round-robin at some "
         "scale on the skewed unequal-mesh topology", att_ll, att_rr)

    # ---- gate 3: the metrics layer observed the least-loaded run ------
    snap = m_ll.snapshot()
    fams = {f["name"]: f for f in snap["families"]}
    sub = sum(s["value"]
              for s in fams["mux_requests_submitted_total"]["series"])
    fin = sum(s["value"]
              for s in fams["mux_requests_finished_total"]["series"])
    assert sub == rep_ll.aggregate.submitted, (sub, rep_ll.aggregate)
    assert fin == rep_ll.aggregate.finished, (fin, rep_ll.aggregate)
    served = {s["labels"]["llm"]: s["value"]
              for s in fams["mux_requests_finished_total"]["series"]
              if s["value"] > 0}
    ttft_obs = {s["labels"]["llm"]: s["count"]
                for s in fams["mux_ttft_seconds"]["series"]}
    assert all(ttft_obs.get(n, 0) == c for n, c in served.items()),\
        ("every finished request must land in its TTFT histogram",
         served, ttft_obs)
    decisions = sum(s["value"]
                    for s in fams["mux_router_decisions_total"]["series"]
                    if s["labels"]["strategy"] == "least_loaded")
    assert decisions == rep_ll.aggregate.submitted,\
        ("every submitted request routes through the strategy",
         decisions, rep_ll.aggregate.submitted)
    qps = {s["labels"]["llm"]: s["value"]
           for s in fams["mux_llm_qps"]["series"]}
    assert qps and all(v >= 0 for v in qps.values())
    out["metrics_snapshot"] = snap
    print(f"[frontend] metrics: {len(fams)} families, "
          f"{decisions:.0f} routing decisions, per-replica finishes "
          f"{{{', '.join(f'{n}:{v:.0f}' for n, v in sorted(served.items()))}}}")

    save("frontend_stream", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
