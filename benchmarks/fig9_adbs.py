"""Fig. 9: ADBS ablation — token-block usage fairness + throughput of
ADBS vs FCFS vs Round-Robin on colocated LLMs sharing one unit.

Paper settings: (a) LLaMA-30B/13B/7B colocated, request length ratio
2:1:1; (b) LLaMA-65B/30B, ratio 4:1.  Bands: ADBS ≈1.43×/1.85× over
Round-Robin/FCFS; ADBS cache usage tracks the rate distribution."""
from __future__ import annotations

import numpy as np

from repro.core.estimator import LLMSpec
from repro.core.simulator import UnitSim
from repro.core.workload import RequestSpec, llama_config

from benchmarks.common import save


def _make_requests(specs, horizon, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for s in specs:
        n = rng.poisson(s.rate * horizon)
        times = np.sort(rng.uniform(0, horizon, n))
        pl = np.clip(rng.lognormal(np.log(s.mean_prompt), 0.5, n), 8,
                     1024).astype(int)
        ol = np.clip(rng.lognormal(np.log(s.mean_output), 0.5, n), 8,
                     1024).astype(int)
        reqs.extend(RequestSpec(s.name, float(t), int(p), int(o))
                    for t, p, o in zip(times, pl, ol))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def _setting(which: str):
    if which == "a":
        # 30B:13B:7B with request length ratio 2:1:1 on 4 GPUs; rates
        # high enough that KV demand exceeds the shared pool (the
        # regime where quota policy matters — paper Fig. 9a)
        specs = [
            LLMSpec(llama_config("llama-30b"), 8.0, 322, 676, tp=4,
                    sm_frac=0.6),
            LLMSpec(llama_config("llama-13b"), 4.0, 161, 338, tp=4,
                    sm_frac=0.4),
            LLMSpec(llama_config("llama-7b"), 2.0, 161, 338, tp=4,
                    sm_frac=0.4),
        ]
        n_dev = 4
    else:
        # 65B:30B with request length ratio 4:1 on 4 GPUs
        specs = [
            LLMSpec(llama_config("llama-65b"), 3.0, 644, 1352, tp=4,
                    sm_frac=0.7),
            LLMSpec(llama_config("llama-30b"), 1.5, 161, 338, tp=4,
                    sm_frac=0.4),
        ]
        n_dev = 4
    return specs, n_dev


def run(quick: bool = False) -> dict:
    rows = []
    for which in (["a"] if quick else ["a", "b"]):
        specs, n_dev = _setting(which)
        reqs = _make_requests(specs, horizon=30.0)
        row = {"setting": which, "policies": {}}
        for policy in ("adbs", "round_robin", "fcfs"):
            u = UnitSim(specs, n_dev, mode="spatial-temporal",
                        policy=policy, equal_quota=(policy != "adbs"),
                        max_batch=128, adapt_every=8)
            u.load(reqs)
            u.run(horizon=30.0)
            done = u.results()
            horizon = max([r.finish for r in done] + [30.0])
            tpt = len(done) / horizon
            usage = {n: st.quota / u.kv_capacity
                     for n, st in u.llms.items()}
            row["policies"][policy] = {"throughput": tpt,
                                       "finished": len(done),
                                       "quota_share": usage}
            print(f"[fig9-{which}] {policy:12s}: {tpt:.2f} req/s, "
                  f"quota {['%.2f' % v for v in usage.values()]}")
        a = row["policies"]["adbs"]["throughput"]
        rr = row["policies"]["round_robin"]["throughput"]
        fc = row["policies"]["fcfs"]["throughput"]
        row["adbs_vs_rr"] = a / max(rr, 1e-9)
        row["adbs_vs_fcfs"] = a / max(fc, 1e-9)
        print(f"[fig9-{which}] ADBS vs RR {row['adbs_vs_rr']:.2f}×, "
              f"vs FCFS {row['adbs_vs_fcfs']:.2f}×")
        rows.append(row)
    out = {"rows": rows}
    save("fig9_adbs", out)
    return out


if __name__ == "__main__":
    run()
