"""Fig. 8: placement ablation — Alg. 1 (computation-first greedy with
mesh-group enumeration) vs the memory-greedy baseline, on the paper's
two scales: 8 GPUs / 4 LLMs and 16 GPUs / 7 LLMs, 50% of LLMs popular
holding >70% of traffic.  Paper band: up to ~1.3×."""
from __future__ import annotations

from repro.core.placement import place, place_memory_greedy
from repro.core.simulator import simulate
from repro.core.workload import llama_config, synthesize

from benchmarks.common import report_row, save


def _setting(scale: str):
    if scale == "8gpu_4llm":
        cfgs = [llama_config("llama-7b", "-a"), llama_config("llama-7b", "-b"),
                llama_config("llama-7b", "-c"), llama_config("llama-30b", "-d")]
        rates = [9.0, 5.0, 1.2, 0.8]      # 50% popular, >70% traffic
        n_dev = 8
    else:
        cfgs = [llama_config("llama-7b", f"-{i}") for i in range(4)] +\
            [llama_config("llama-13b", "-x"), llama_config("llama-13b", "-y"),
             llama_config("llama-30b", "-z")]
        rates = [10.0, 7.0, 4.0, 1.0, 0.8, 0.6, 0.4]
        n_dev = 16
    return list(zip(cfgs, rates)), n_dev


def run(quick: bool = False) -> dict:
    rows = []
    for scale in (["8gpu_4llm"] if quick else ["8gpu_4llm", "16gpu_7llm"]):
        models, n_dev = _setting(scale)
        wl = synthesize([c.name for c, _ in models], alpha=1.0,
                        max_rate=max(r for _, r in models), horizon=30.0,
                        seed=0)
        wl.rates = {c.name: r for c, r in models}
        pl_ours = place(models, n_devices=n_dev, group_limit=64)
        pl_mem = place_memory_greedy(models, n_devices=n_dev)
        ours = simulate(pl_ours, wl, mode="spatial-temporal", policy="adbs")
        mem = simulate(pl_mem, wl, mode="spatial-temporal", policy="adbs")
        rows.append({"tag": scale,
                     "ours": report_row("", {"r": ours})["r"],
                     "memory_greedy": report_row("", {"r": mem})["r"],
                     "placement_ours": pl_ours.describe(),
                     "placement_mem": pl_mem.describe()})
        print(f"[fig8] {scale}: ours {ours.throughput:.2f} req/s vs "
              f"memory-greedy {mem.throughput:.2f} "
              f"({ours.throughput / max(mem.throughput, 1e-9):.2f}×)")
    out = {"rows": rows}
    save("fig8_placement", out)
    return out


if __name__ == "__main__":
    run()
