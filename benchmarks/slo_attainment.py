"""SLO attainment of adbs vs fcfs vs round_robin on REAL engines —
the runtime counterpart of the simulator's Fig. 9/11 policy ablation
(benchmarks/fig9_adbs.py, fig11_p99.py), measured the way the paper
measures MuxServe: goodput and SLO attainment under a
popularity-skewed Poisson trace, not raw tokens/s.

Three colocated same-architecture reduced LLMs share one unified KV
pool; the SAME ``core/workload.py`` trace is replayed against each
scheduling policy (identical arrivals, prompts and output lengths).
The serving loop runs the deterministic tick-cost clock
(``serving/driver.TickCostModel`` — real jitted engine compute, logical
time), so the attainment numbers are bit-reproducible across machines
and CI can gate on the ordering rather than on wall-clock noise:
ADBS's prefill-priority + quota adaptation must beat both baselines
at some SLO scale (asserted).

Records a JSON artifact (``experiments/results/slo_attainment.json``,
uploaded by CI next to the fused-tick baseline) with the full per-LLM
and aggregate reports per policy.
"""
from __future__ import annotations

from repro.core.workload import synthesize
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  serve_workload)

from benchmarks.common import save

ARCH = "qwen2-7b"
N_MODELS = 3
ALPHA = 2.1                 # strong popularity skew (paper §4.2)
CHUNK_TOKENS = 16
MAX_SLOTS = 4
MEAN_PROMPT, MEAN_OUTPUT = 24, 10
SLO_SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0)
POLICIES = ("adbs", "fcfs", "round_robin")
COST = TickCostModel()


def _unit(names, rates, policy: str, pool_blocks: int):
    # fused where the policy multiplexes; fcfs is the temporal baseline
    # and never reaches the fused tick (MuxScheduler ignores the flag)
    return build_unit_from_specs(
        [(n, ARCH, rates[n]) for n in names], pool_blocks=pool_blocks,
        max_slots=MAX_SLOTS, chunk_tokens=CHUNK_TOKENS, seed=0,
        policy=policy, fused=True)


def run(quick: bool = False, max_rate: float = 16.0,
        horizon: float = 4.0, pool_blocks: int = 20_000) -> dict:
    if quick:
        max_rate, horizon = 20.0, 3.0
    names = [f"llm{i}" for i in range(N_MODELS)]
    wl = synthesize(names, alpha=ALPHA, max_rate=max_rate, horizon=horizon,
                    seed=0, mean_prompt=MEAN_PROMPT, mean_output=MEAN_OUTPUT,
                    max_len=256)
    out = {
        "arch": ARCH, "n_models": N_MODELS, "alpha": ALPHA,
        "max_rate": max_rate, "horizon": horizon,
        "mean_prompt": MEAN_PROMPT, "mean_output": MEAN_OUTPUT,
        "chunk_tokens": CHUNK_TOKENS, "max_slots": MAX_SLOTS,
        "pool_blocks": pool_blocks, "n_requests": len(wl.requests),
        "rates": wl.rates, "slo_scales": list(SLO_SCALES),
        "tick_cost": {"base": COST.base, "prefill_tok": COST.prefill_tok,
                      "decode_tok": COST.decode_tok},
        "policies": {},
    }
    print(f"[slo_attainment] {len(wl.requests)} requests, α={ALPHA}, "
          f"rates {{{', '.join(f'{n}:{r:.2f}' for n, r in wl.rates.items())}}}")
    for policy in POLICIES:
        unit = _unit(names, wl.rates, policy, pool_blocks)
        rep = serve_workload([unit], wl, seed=1, slo_scales=SLO_SCALES,
                             cost=COST)
        out["policies"][policy] = rep.to_json()
        agg = rep.aggregate
        att = ", ".join(f"{s:g}×:{agg.attainment[s]:.2f}"
                        for s in SLO_SCALES)
        print(f"[slo_attainment] {policy:12s}: "
              f"{agg.finished}/{agg.submitted} finished over "
              f"{rep.horizon:.2f} logical s ({rep.ticks} ticks) | "
              f"e2e p99={agg.e2e.p99:.3f}s ttft p99={agg.ttft.p99:.3f}s "
              f"| SLO[{att}]")

    # the paper's claim, in runtime form: ADBS attains strictly more
    # requests than BOTH baselines at some SLO scale
    att_of = {p: out["policies"][p]["aggregate"]["attainment"]
              for p in POLICIES}
    best = [s for s in SLO_SCALES
            if att_of["adbs"][str(s)] > att_of["fcfs"][str(s)]
            and att_of["adbs"][str(s)] > att_of["round_robin"][str(s)]]
    out["adbs_strictly_best_scales"] = best
    assert best, ("adbs must strictly beat fcfs AND round_robin at some "
                  f"slo-scale; attainment={att_of}")
    ge_fcfs = all(att_of["adbs"][str(s)] >= att_of["fcfs"][str(s)]
                  for s in SLO_SCALES)
    out["adbs_ge_fcfs_at_every_scale"] = ge_fcfs
    print(f"[slo_attainment] adbs strictly best at scales {best}; "
          f"adbs ≥ fcfs at every scale: {ge_fcfs}")
    save("slo_attainment", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
