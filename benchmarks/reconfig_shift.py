"""Live reconfiguration vs frozen placement on a regime-shift trace —
the runtime proof that the reconfiguration subsystem
(``serving/reconfig.py``, DESIGN.md §10) earns its keep.

Three colocated reduced LLMs are placed by popularity: the two
popular ones share the big (4-device) mesh, the cold one sits on a
1-device mesh.  Halfway through the trace the popularity FLIPS
(``core/workload.piecewise_poisson_trace``): the cold LLM jumps to
the hot rate and the old favourite goes quiet.  The same trace is
served twice on real engines under the deterministic tick-cost clock
(bit-reproducible — per-unit tick cost scales with mesh devices):

  * **static** — the PR-3 behaviour: the startup placement replays
    unchanged, so the newly-hot LLM grinds on the small mesh;
  * **reconfig** — a ``ReconfigController`` watches EWMA arrival
    rates, detects the drift, re-solves the assignment onto the fixed
    meshes and live-migrates the hot LLM's engine + KV to the big
    mesh (decodes carry their cache, prefills requeue, fused groups
    rebuild), charging the modeled migration stall to the clock.

CI gates on the ordering: live reconfiguration must finish every
request (zero drops) and attain strictly more SLO than the frozen
placement at some scale, with at least one executed migration.
Artifact: ``experiments/results/reconfig_shift.json``.
"""
from __future__ import annotations

from repro import configs
from repro.config import replace
from repro.core.estimator import LLMSpec
from repro.core.placement import Mesh, Placement
from repro.core.workload import piecewise_poisson_trace
from repro.serving.driver import (TickCostModel, serve_workload,
                                  units_from_placement)
from repro.serving.reconfig import MigrationCostModel, ReconfigController

from benchmarks.common import save

ARCH = "qwen2-7b"
NAMES = ("llm0", "llm1", "llm2")
HOT, WARM, COLD = 25.0, 2.0, 0.5     # req/s before the flip
CHUNK_TOKENS = 16
MAX_SLOTS = 4
POOL_BLOCKS = 16_000
MEAN_PROMPT, MEAN_OUTPUT = 24, 10
SLO_SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0)
COST = TickCostModel()


def shift_workload(horizon: float, seed: int = 0):
    """Popularity flip at t = horizon/2: llm0 and llm2 swap rates."""
    pre = {"llm0": HOT, "llm1": WARM, "llm2": COLD}
    post = {"llm0": COLD, "llm1": WARM, "llm2": HOT}
    return piecewise_poisson_trace(
        [(0.0, pre), (horizon / 2, post)], horizon, seed=seed,
        mean_prompt=MEAN_PROMPT, mean_output=MEAN_OUTPUT, max_len=256)


def initial_placement() -> Placement:
    """The popularity-aligned startup plan: hot+warm on the 4-device
    mesh, cold alone on 1 device (what ``place`` picks for the
    pre-flip rates, hand-pinned so the benchmark is self-contained)."""
    cfg = configs.get(ARCH)

    def spec(name, rate):
        return LLMSpec(replace(cfg, name=name), rate,
                       mean_prompt=MEAN_PROMPT, mean_output=MEAN_OUTPUT,
                       tp=1, sm_frac=1.0, arch=ARCH)

    return Placement(
        meshes=[Mesh(0, 4, [spec("llm0", HOT), spec("llm1", WARM)]),
                Mesh(1, 1, [spec("llm2", COLD)])],
        total_tpt=HOT + WARM + COLD)


def _units(pl: Placement, policy: str = "adbs"):
    return units_from_placement(pl, pool_blocks=POOL_BLOCKS,
                                max_slots=MAX_SLOTS,
                                chunk_tokens=CHUNK_TOKENS, seed=0,
                                policy=policy, fused=True)


def run(quick: bool = False, horizon: float = 6.0) -> dict:
    if quick:
        horizon = 4.0
    wl = shift_workload(horizon)
    out = {
        "arch": ARCH, "names": list(NAMES), "horizon": horizon,
        "rates_pre": {"llm0": HOT, "llm1": WARM, "llm2": COLD},
        "rates_post": {"llm0": COLD, "llm1": WARM, "llm2": HOT},
        "mean_prompt": MEAN_PROMPT, "mean_output": MEAN_OUTPUT,
        "chunk_tokens": CHUNK_TOKENS, "max_slots": MAX_SLOTS,
        "pool_blocks": POOL_BLOCKS, "n_requests": len(wl.requests),
        "slo_scales": list(SLO_SCALES),
        "tick_cost": {"base": COST.base, "prefill_tok": COST.prefill_tok,
                      "decode_tok": COST.decode_tok},
        "runs": {},
    }
    print(f"[reconfig_shift] {len(wl.requests)} requests over {horizon}s, "
          f"flip at {horizon / 2}s: llm0 {HOT}→{COLD} req/s, "
          f"llm2 {COLD}→{HOT} req/s")

    # ---- static: the frozen PR-3 placement --------------------------
    pl = initial_placement()
    static_rep = serve_workload(_units(pl), wl, seed=1,
                                slo_scales=SLO_SCALES, cost=COST)
    out["runs"]["static"] = static_rep.to_json()

    # ---- live reconfiguration ---------------------------------------
    pl = initial_placement()
    units = _units(pl)
    ctrl = ReconfigController(pl, units, interval=0.25,
                              drift_threshold=2.0, sustain=2,
                              migration_cost=MigrationCostModel())
    recfg_rep = serve_workload(units, wl, seed=1, slo_scales=SLO_SCALES,
                               cost=COST, reconfig=ctrl)
    out["runs"]["reconfig"] = recfg_rep.to_json()

    for tag, rep in (("static", static_rep), ("reconfig", recfg_rep)):
        agg = rep.aggregate
        att = ", ".join(f"{s:g}×:{agg.attainment[s]:.2f}"
                        for s in SLO_SCALES)
        print(f"[reconfig_shift] {tag:9s}: {agg.finished}/{agg.submitted} "
              f"finished over {rep.horizon:.2f} logical s "
              f"({rep.ticks} ticks) | e2e p99={agg.e2e.p99:.3f}s "
              f"| SLO[{att}]")
    rc = recfg_rep.reconfig
    print(f"[reconfig_shift] reconfig events={rc.events} moves={rc.moves} "
          f"migrated_blocks={rc.migrated_blocks} requeued={rc.requeued} "
          f"stall_ticks={rc.stall_ticks}")

    # ---- CI gates ----------------------------------------------------
    s_att = static_rep.aggregate.attainment
    r_att = recfg_rep.aggregate.attainment
    assert static_rep.aggregate.finished == len(wl.requests),\
        "static run dropped requests"
    assert recfg_rep.aggregate.finished == len(wl.requests),\
        "reconfig run dropped requests"
    assert rc.events >= 1 and rc.moves >= 1,\
        "the regime shift must trigger at least one migration"
    better = [s for s in SLO_SCALES if r_att[s] > s_att[s]]
    out["reconfig_strictly_better_scales"] = better
    out["reconfig_events"] = rc.to_json()
    assert better, ("live reconfiguration must strictly beat the frozen "
                    f"placement at some SLO scale; static={s_att}, "
                    f"reconfig={r_att}")
    print(f"[reconfig_shift] reconfig strictly better at scales {better}")
    save("reconfig_shift", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
