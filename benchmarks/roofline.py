"""§Roofline: per (arch × shape × mesh) three-term roofline from the
dry-run's compiled artifacts (experiments/dryrun/*.json).

  compute term    = HLO_FLOPs / (chips × peak)   [s]
  memory term     = HLO_bytes / (chips × HBM_bw) [s]
  collective term = coll_bytes / (chips × link_bw) [s]

HLO_FLOPs/bytes are the trip-count-corrected per-device numbers from
launch/hlo_analysis.py (×chips restores module totals; dividing by
chips×peak cancels back to per-device — reported per the assignment's
formula).  MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), with
N = active params for MoE.  The useful-FLOPs ratio flags padding /
remat / redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from repro import configs
from repro.config import SHAPES, PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from repro.core import costmodel as cm
from repro.launch.sharding import physical_config

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "experiments/dryrun")

LONG_SKIPS = {
    "granite-moe-3b-a800m", "qwen3-14b", "phi-3-vision-4.2b",
    "command-r-plus-104b", "qwen3-moe-235b-a22b", "deepseek-coder-33b",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analytic_bytes(arch: str, shape_name: str) -> float:
    """First-principles HBM traffic of one step (whole module).

    The CPU backend's HLO bytes-accessed is not a usable memory term:
    bf16 buffers are f32-normalized, defensive whole-cache copies are
    inserted around loop aliasing, and fused DUS windows are charged
    their full operands (measured 10–100× inflation).  The analytic
    model counts exactly what the TPU must move: weights, KV/state
    caches, activations, optimizer state, flash K/V re-reads —
    physical (padded) geometry included.
    """
    shape = SHAPES[shape_name]
    cfg = physical_config(configs.get(arch), 16)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return cm.train_step_bytes(cfg, B, S)
    if shape.kind == "prefill":
        return cm.prefill_bytes(cfg, B, S)
    windowed = shape_name == "long_500k" and cfg.sliding_window
    ctx = min(S, cfg.sliding_window) if windowed else S
    return cm.decode_bytes(cfg, B, ctx)


def lever_hint(dominant: str, kind: str, arch: str) -> str:
    cfg = configs.get(arch)
    if dominant == "collective":
        if cfg.moe:
            return ("overlap the expert all-to-all with expert GEMMs / "
                    "reduce FSDP gather frequency")
        return ("reduce per-layer TP all-gathers (wider seq-shard spans, "
                "comm/compute overlap, or weight-gather caching)")
    if dominant == "memory":
        if kind == "decode":
            return ("shrink KV reads: head-dim-exact sharding instead of "
                    "kv replication, quantized (int8) KV, larger fused "
                    "decode batches per HBM pass")
        return "increase arithmetic intensity (larger per-core tiles)"
    if kind == "decode":
        return "decode should not be compute-bound — check padding waste"
    return ("already compute-dominated: raise MFU via block-size tuning; "
            "remaining headroom is padding + remat recompute")


def load_rows(mesh: Optional[str] = None) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    return rows


def roofline_row(rec: dict) -> dict:
    chips = rec["n_devices"]
    # per-device numbers × chips = module totals; the assignment formula
    # divides by (chips × peak) — i.e. per-device time
    t_comp = rec["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    mem_bytes = analytic_bytes(rec["arch"], rec["shape"])
    t_mem = mem_bytes / chips / HBM_BW
    t_coll = rec["hlo_collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["hlo_flops_per_device"] * chips
    ratio = mf / hlo_total if hlo_total else float("nan")
    kind = rec.get("kind", "?")
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": kind,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_flops_ratio": ratio,
        "analytic_bytes_total": mem_bytes,
        "hlo_bytes_per_device_raw": rec.get("hlo_bytes_per_device"),
        "hbm_gib_per_device": (rec["memory"]["argument_bytes"]
                               + rec["memory"]["temp_bytes"]) / 2 ** 30,
        "lever": lever_hint(dom, kind, rec["arch"]),
    }


def run(mesh: str = "16x16") -> dict:
    rows = [roofline_row(r) for r in load_rows(mesh) if "skipped" not in r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>10s} {'useful':>7s} {'HBM GiB':>8s}")
    print(f"[roofline] mesh={mesh}  ({len(rows)} lowered pairs)")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
              f"{r['t_collective_s']:9.2e} {r['dominant']:>10s} "
              f"{r['useful_flops_ratio']:7.2f} "
              f"{r['hbm_gib_per_device']:8.2f}")
    for arch in sorted(LONG_SKIPS):
        print(f"{arch:24s} {'long_500k':12s} {'—':>9s} {'—':>9s} {'—':>9s} "
              f"{'SKIP':>10s}   (full attention @500k — DESIGN.md §4)")
    from benchmarks.common import save
    save(f"roofline_{mesh}", {"rows": rows,
                              "skips": sorted(LONG_SKIPS)})
    return {"rows": rows}


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "16x16")
