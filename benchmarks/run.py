"""Run every benchmark harness (one per paper table/figure) and the
roofline report.  ``--quick`` trims sweeps for CI-speed runs.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (chaos_degradation, fig3_compute_fraction,
                            fig5_synthetic, fig7_real, fig8_placement,
                            fig9_adbs, fig10_manager, fig11_p99,
                            frontend_stream, fused_tick, kernel_bench,
                            prefix_cache, reconfig_shift, roofline,
                            slo_attainment, spatial_mux)
    jobs = [
        ("fig3_compute_fraction", lambda: fig3_compute_fraction.run()),
        ("fig5_synthetic", lambda: fig5_synthetic.run(args.quick)),
        ("fig7_real", lambda: fig7_real.run(args.quick)),
        ("fig8_placement", lambda: fig8_placement.run(args.quick)),
        ("fig9_adbs", lambda: fig9_adbs.run(args.quick)),
        ("fig10_manager", lambda: fig10_manager.run(args.quick)),
        ("fig11_p99", lambda: fig11_p99.run(args.quick)),
        ("fused_tick", lambda: fused_tick.run(args.quick)),
        ("slo_attainment", lambda: slo_attainment.run(args.quick)),
        ("spatial_mux", lambda: spatial_mux.run(args.quick)),
        ("reconfig_shift", lambda: reconfig_shift.run(args.quick)),
        ("chaos_degradation", lambda: chaos_degradation.run(args.quick)),
        ("prefix_cache", lambda: prefix_cache.run(args.quick)),
        ("frontend_stream", lambda: frontend_stream.run(args.quick)),
        ("kernel_bench", lambda: kernel_bench.run(args.quick)),
        ("roofline_16x16", lambda: roofline.run("16x16")),
        ("roofline_2x16x16", lambda: roofline.run("2x16x16")),
    ]
    failures = []
    for name, fn in jobs:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception:                                 # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
