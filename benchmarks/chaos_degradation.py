"""Chaos harness for the serving loop — the CI gate on *graceful*
degradation (serving/faults.py, DESIGN.md §12).

Three properties are asserted, all on the deterministic tick-cost
clock so the gates are bit-reproducible:

  1. **Parity** — the degradation machinery is free when idle: a run
     with bounded queues + deadline shedding armed and a severity-0
     (empty) fault plan reproduces the plain baseline's attainment
     bit-for-bit.
  2. **Monotone degradation** — a nested severity sweep
     (``FaultPlan.random``: higher severity strictly adds faults to
     the same schedule) degrades mean SLO attainment monotonically —
     no cliffs, no paradoxical improvements — and every run terminates
     with ``submitted = finished + shed`` (each request disposed of
     exactly once, never silently lost or duplicated).
  3. **Overload + crash survival** — a 2× overload burst with an
     engine crash mid-run, bounded admission queues and deadline
     shedding: the run terminates, sheds deliberately (recorded,
     SLO-missed) rather than queuing without bound, and still loses
     nothing silently.

Records ``experiments/results/chaos_degradation.json`` with the full
per-severity reports (uploaded by CI next to the other artifacts).
"""
from __future__ import annotations

from repro.core.workload import synthesize
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  serve_workload)
from repro.serving.faults import FaultPlan

from benchmarks.common import save

ARCH = "qwen2-7b"
N_MODELS = 3
ALPHA = 2.1
CHUNK_TOKENS = 16
MAX_SLOTS = 4
MEAN_PROMPT, MEAN_OUTPUT = 24, 10
SLO_SCALES = (1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0)
SEVERITIES = (0.0, 1 / 3, 2 / 3, 1.0)
COST = TickCostModel()


def _unit(names, rates, pool_blocks: int, chaos: bool):
    """One fused colocated unit; ``chaos`` arms the degradation ladder
    (bounded queues + deadline shedding) the chaos runs serve under."""
    return build_unit_from_specs(
        [(n, ARCH, rates[n]) for n in names], pool_blocks=pool_blocks,
        max_slots=MAX_SLOTS, chunk_tokens=CHUNK_TOKENS, seed=0,
        policy="adbs", fused=True,
        max_queue=(256 if chaos else None),
        shed_policy=("deadline" if chaos else "none"))


def _attainment(rep) -> dict:
    return {s: rep.aggregate.attainment[s] for s in SLO_SCALES}


def _assert_exactly_once(rep, n_requests: int, tag: str) -> None:
    agg = rep.aggregate
    assert agg.submitted == n_requests, (tag, agg.submitted, n_requests)
    assert agg.submitted == agg.finished + agg.shed,\
        (tag, "every request must finish or be shed — exactly once",
         agg.submitted, agg.finished, agg.shed)
    per = rep.per_llm.values()
    assert sum(p.submitted for p in per) == n_requests, tag
    assert sum(p.finished + p.shed for p in per) == n_requests, tag


def run(quick: bool = False, max_rate: float = 10.0, horizon: float = 4.0,
        pool_blocks: int = 20_000) -> dict:
    if quick:
        max_rate, horizon = 10.0, 3.0
    names = [f"llm{i}" for i in range(N_MODELS)]
    wl = synthesize(names, alpha=ALPHA, max_rate=max_rate, horizon=horizon,
                    seed=0, mean_prompt=MEAN_PROMPT, mean_output=MEAN_OUTPUT,
                    max_len=256)
    out = {
        "arch": ARCH, "n_models": N_MODELS, "alpha": ALPHA,
        "max_rate": max_rate, "horizon": horizon,
        "pool_blocks": pool_blocks, "n_requests": len(wl.requests),
        "rates": wl.rates, "slo_scales": list(SLO_SCALES),
        "severities": list(SEVERITIES), "runs": {},
    }
    print(f"[chaos] {len(wl.requests)} requests, α={ALPHA}, rates "
          f"{{{', '.join(f'{n}:{r:.2f}' for n, r in wl.rates.items())}}}")

    # ---- gate 1: severity-0 chaos config == plain baseline -----------
    base = serve_workload([_unit(names, wl.rates, pool_blocks, False)],
                          wl, seed=1, slo_scales=SLO_SCALES, cost=COST)
    sev0 = serve_workload(
        [_unit(names, wl.rates, pool_blocks, True)], wl, seed=1,
        slo_scales=SLO_SCALES, cost=COST,
        faults=FaultPlan.random(names, horizon, 0.0, seed=11,
                                pool_blocks=pool_blocks))
    out["runs"]["baseline"] = base.to_json()
    assert _attainment(base) == _attainment(sev0),\
        ("severity-0 chaos must reproduce the baseline bit-for-bit",
         _attainment(base), _attainment(sev0))
    assert base.horizon == sev0.horizon and base.ticks == sev0.ticks
    assert sev0.faults is not None and sev0.faults.injected == 0
    print(f"[chaos] parity: severity-0 == baseline "
          f"({base.ticks} ticks, attainment bit-identical)")

    # ---- gate 1b: the invariant sanitizer is a pure reader -----------
    # (serving/sanitize.py, DESIGN.md §15): the same severity-0 run
    # with every-tick invariant checking on must reproduce the
    # unsanitized report bit-for-bit — wall_s is the one field allowed
    # to differ (real elapsed wall time, a diagnostic).
    sev0_san = serve_workload(
        [_unit(names, wl.rates, pool_blocks, True)], wl, seed=1,
        slo_scales=SLO_SCALES, cost=COST,
        faults=FaultPlan.random(names, horizon, 0.0, seed=11,
                                pool_blocks=pool_blocks),
        sanitize=True)
    plain, sanitized = sev0.to_json(), sev0_san.to_json()
    plain.pop("wall_s"), sanitized.pop("wall_s")
    assert plain == sanitized,\
        ("a sanitized run must be bit-identical to an unsanitized one "
         "(the sanitizer is a pure reader)")
    print(f"[chaos] sanitize parity: severity-0 with MUXSERVE_SANITIZE "
          f"semantics == plain run, bit-identical over {sev0.ticks} "
          f"checked ticks")

    # ---- gate 2: nested severity sweep degrades monotonically --------
    means = []
    for sev in SEVERITIES:
        plan = FaultPlan.random(names, horizon, sev, seed=11,
                                pool_blocks=pool_blocks)
        rep = serve_workload([_unit(names, wl.rates, pool_blocks, True)],
                             wl, seed=1, slo_scales=SLO_SCALES, cost=COST,
                             faults=plan)
        _assert_exactly_once(rep, len(wl.requests), f"severity {sev:.2f}")
        att = _attainment(rep)
        mean = sum(att.values()) / len(att)
        means.append(mean)
        out["runs"][f"severity_{sev:.2f}"] = rep.to_json()
        fs = rep.faults
        print(f"[chaos] severity {sev:.2f}: {len(plan)} faults → "
              f"{rep.aggregate.finished}/{rep.aggregate.submitted} "
              f"finished, {rep.aggregate.shed} shed, "
              f"{fs.recoveries} recoveries, {fs.blocks_lost} blocks "
              f"lost, mean attainment {mean:.4f}")
    out["mean_attainment_by_severity"] = means
    for lo, hi in zip(means[1:], means[:-1]):
        assert lo <= hi + 1e-9,\
            ("attainment must degrade monotonically with fault severity "
             "(nested plans)", means)
    print(f"[chaos] monotone degradation: {[f'{m:.4f}' for m in means]}")

    # ---- gate 3: 2× overload burst + crash survives ------------------
    wl2 = synthesize(names, alpha=ALPHA, max_rate=2 * max_rate,
                     horizon=horizon, seed=2, mean_prompt=MEAN_PROMPT,
                     mean_output=MEAN_OUTPUT, max_len=256)
    unit = build_unit_from_specs(
        [(n, ARCH, wl2.rates[n]) for n in names], pool_blocks=pool_blocks,
        max_slots=MAX_SLOTS, chunk_tokens=CHUNK_TOKENS, seed=0,
        policy="adbs", fused=True, max_queue=8, shed_policy="deadline")
    crash_t = 0.5 * horizon
    rep = serve_workload(
        [unit], wl2, seed=1, slo_scales=SLO_SCALES, cost=COST,
        faults=FaultPlan.parse(f"crash:{names[0]}@{crash_t}"),
        shed_scale=2.0)
    _assert_exactly_once(rep, len(wl2.requests), "overload")
    assert rep.faults.recoveries == 1, rep.faults.to_json()
    assert rep.aggregate.shed > 0,\
        "a 2× burst over bounded queues must shed deliberately"
    out["runs"]["overload_crash"] = rep.to_json()
    print(f"[chaos] overload+crash: {rep.aggregate.finished} finished, "
          f"{rep.aggregate.shed} shed "
          f"({dict(rep.aggregate.shed_reasons)}), zero lost")

    save("chaos_degradation", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.quick)
