"""Fig. 7: real-workload evaluation — ChatLMSYS-like trace, 16 LLMs on
32 GPUs, ~20% of the LLMs receive ~50% of traffic; rates rescaled to
sweep the average rate.  Paper band: up to 1.38×/1.46× over
spatial/temporal at SLO scale 8."""
from __future__ import annotations

from repro.core.workload import chatlmsys_like, llama_config

from benchmarks.common import report_row, save, three_systems

N_DEVICES = 32
AVG_RATES = [1.2, 2.4, 4.8]


def _model_mix():
    """16 LLMs: 10×7B, 4×13B, 2×30B (a ChatLMSYS-like spread)."""
    out = []
    for i in range(10):
        out.append(llama_config("llama-7b", f"-r{i}"))
    for i in range(4):
        out.append(llama_config("llama-13b", f"-r{i}"))
    for i in range(2):
        out.append(llama_config("llama-30b", f"-r{i}"))
    return out


def run(quick: bool = False) -> dict:
    models = _model_mix()
    rows = []
    for avg in (AVG_RATES[:1] if quick else AVG_RATES):
        wl = chatlmsys_like(n_models=16, horizon=30.0, avg_rate=avg,
                            seed=0)
        # bind trace model names to configs
        name_map = {f"llm-{i}": m.name for i, m in enumerate(models)}
        wl.rates = {name_map[k]: v for k, v in wl.rates.items()}
        for r in wl.requests:
            r.model = name_map[r.model]
        models_rates = [(m, wl.rates[m.name]) for m in models]
        reps = three_systems(models_rates, wl, N_DEVICES, slo_scales=(8,))
        rows.append(report_row(f"avg_rate={avg}", reps))
        mx, sp, tp = reps["muxserve"], reps["spatial"], reps["temporal"]
        print(f"[fig7] avg={avg}: mux {mx.throughput:.2f} vs spatial "
              f"{sp.throughput:.2f} ({mx.throughput / max(sp.throughput, 1e-9):.2f}×) "
              f"/ temporal {tp.throughput:.2f} "
              f"({mx.throughput / max(tp.throughput, 1e-9):.2f}×), "
              f"SLO@8 {mx.slo_attainment[8]:.0%}")
    out = {"rows": rows}
    save("fig7_real", out)
    return out


if __name__ == "__main__":
    run()
