"""Appendix A.1 (Fig. 11): P99 average / TPOT / TTFT latency of the
three multiplexing approaches on the synthetic workloads.

Paper bands: MuxServe's P99 average latency below both baselines; P99
TPOT slightly above spatial (interference) but far below temporal; P99
TTFT below both (queuing time dominates, which colocation removes).
"""
from __future__ import annotations

from repro.core.workload import power_law_rates

from benchmarks.common import paper_models, save, three_systems,\
    workload_for

ALPHAS = [0.7, 2.1]
N_DEVICES = 32


def run(quick: bool = False) -> dict:
    models = paper_models()
    rows = []
    for alpha in (ALPHAS[:1] if quick else ALPHAS):
        rates = power_law_rates([m.name for m in models], alpha, 20.0)
        models_rates = [(m, rates[m.name]) for m in models]
        wl = workload_for(models, alpha, 20.0, 30.0, seed=0)
        reps = three_systems(models_rates, wl, N_DEVICES)
        row = {"alpha": alpha}
        for name, r in reps.items():
            row[name] = {"p99_latency": r.p99_latency,
                         "p99_ttft": r.p99_ttft,
                         "p99_tpot": r.p99_tpot}
        rows.append(row)
        mx, sp, tp = reps["muxserve"], reps["spatial"], reps["temporal"]
        print(f"[fig11] α={alpha}: p99 latency mux {mx.p99_latency:.1f}s "
              f"vs spatial {sp.p99_latency:.1f}s / temporal "
              f"{tp.p99_latency:.1f}s | p99 TTFT {mx.p99_ttft:.2f} vs "
              f"{sp.p99_ttft:.2f}/{tp.p99_ttft:.2f} | p99 TPOT(ms) "
              f"{mx.p99_tpot * 1e3:.0f} vs {sp.p99_tpot * 1e3:.0f}/"
              f"{tp.p99_tpot * 1e3:.0f}")
    out = {"rows": rows}
    save("fig11_p99", out)
    return out


if __name__ == "__main__":
    run()
