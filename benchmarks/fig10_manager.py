"""Fig. 10: unified resource manager ablation — gradually enable
computation management (prefill/decode separation + spatial-temporal
colocation) and the unified memory manager (rate-proportional adaptive
quotas over one KV pool).

Arms:
  base      : temporal multiplexing, static equal KV partitions
  +compute  : spatial-temporal colocation, static equal KV partitions
  +memory   : spatial-temporal + unified adaptive quota (full MuxServe)

Paper bands: +compute ≈ 1.7×, +memory ≈ another 1.2× and 3.6× SLO.
4 LLMs on 4 GPUs, power-law rates.
"""
from __future__ import annotations

from repro.core.estimator import LLMSpec
from repro.core.placement import Mesh, Placement
from repro.core.simulator import simulate
from repro.core.workload import llama_config, power_law_rates, synthesize

from benchmarks.common import report_row, save

ALPHAS = [0.7, 1.3, 2.1]


def run(quick: bool = False) -> dict:
    # 4×30B colocated on 4 GPUs: weights fill most of HBM, the shared
    # KV pool is scarce, and decode is weight-read-dominated — the
    # regime where both the compute manager (colocation) and the
    # unified memory manager (adaptive quota → bigger hot-model
    # batches) pay off, as in the paper's Fig. 10
    cfgs = [llama_config("llama-30b", f"-{i}") for i in range(4)]
    rows = []
    for alpha in (ALPHAS[:1] if quick else ALPHAS):
        rates = power_law_rates([c.name for c in cfgs], alpha,
                                max_rate=8.0)
        wl = synthesize([c.name for c in cfgs], alpha=alpha,
                        max_rate=8.0, horizon=30.0, seed=0)
        wl.rates = rates
        # one colocated unit of all 4 LLMs on the 4-GPU mesh (the
        # ablation isolates the manager, not the placement)
        specs = [LLMSpec(c, rates[c.name], tp=4, sm_frac=0.5)
                 for c in cfgs]
        pl = Placement([Mesh(0, 4, specs)], 0.0)
        base = simulate(pl, wl, mode="temporal", policy="fcfs",
                        equal_quota=True, slo_scales=(8,), max_batch=256)
        comp = simulate(pl, wl, mode="spatial-temporal",
                        policy="round_robin", equal_quota=True,
                        slo_scales=(8,), max_batch=256)
        full = simulate(pl, wl, mode="spatial-temporal", policy="adbs",
                        slo_scales=(8,), max_batch=256)
        rows.append({"alpha": alpha,
                     **report_row("", {"base": base, "compute": comp,
                                       "full": full})})
        print(f"[fig10] α={alpha}: base {base.throughput:.2f} → +compute "
              f"{comp.throughput:.2f} "
              f"({comp.throughput / max(base.throughput, 1e-9):.2f}×) → "
              f"+memory {full.throughput:.2f} "
              f"({full.throughput / max(comp.throughput, 1e-9):.2f}×); "
              f"SLO@8 {base.slo_attainment[8]:.0%}→"
              f"{full.slo_attainment[8]:.0%}")
    out = {"rows": rows}
    save("fig10_manager", out)
    return out


if __name__ == "__main__":
    run()
