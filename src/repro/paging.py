"""Shared paged-KV index arithmetic and decode oracles (layer-neutral).

Physical head-block id for (token-block base b, layer l, kv head h) of
a model with KV kv-heads: ``b + l*KV + h`` (groups are contiguous —
see serving/kvcache.py).  Both the XLA oracle (serving/cache_ops) and
the Pallas kernels (kernels/paged_attention) resolve tables through
this one function so the two layers can never disagree on the pool
layout.

The paged *decode attention* oracles live here too: they are pure
functions of (query, arena, resolved blocks) with no serving-state
dependency, and both the serving engine (via serving/cache_ops) and
the kernel test oracles (kernels/ref.py) consume them.  Hosting them
in this shared leaf keeps the layer DAG acyclic — kernels must not
import serving (ARCHITECTURE.md; enforced by ``tools/muxlint``
``layering``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def resolve_physical_blocks(table, layer, n_kv):
    """Resolve a group-base block table to physical head-block ids.

    table: [..., max_blocks] int32 group bases (−1 padded) — any number
    of leading batch dims (the fused multi-LLM sweeps pass their row
    batches flattened or as [M, rows]).
    Returns [..., n_kv, max_blocks] int32 physical ids (invalid → 0;
    the caller masks those positions via seq_lens / query positions).
    Rows of a *fused* multi-LLM batch can come from different models as
    long as their (layer, n_kv) resolution has already been applied
    here — this is the per-row handoff point between the pool and the
    fused kernels (decode AND prefill).
    """
    layer = jnp.asarray(layer, jnp.int32)
    heads = jnp.arange(n_kv, dtype=jnp.int32)[:, None]       # [n_kv, 1]
    phys = jnp.maximum(table, 0)[..., None, :] + layer * n_kv + heads
    return jnp.where(table[..., None, :] >= 0, phys, 0).astype(jnp.int32)


def fused_paged_decode_attention(q, pool_k, pool_v, phys, seq_lens):
    """Multi-sequence decode attention over pre-resolved physical blocks.

    The fused multi-LLM tick (DESIGN.md §2) flattens the decode rows of
    all colocated same-architecture engines into one batch; each row's
    ``phys`` entries already encode (model, layer) → physical id, so
    the attention sweep itself is model-agnostic.

    q: [B, H, hd] — one query token per row (post-RoPE)
    pool_k/v: [N, BT, hd]
    phys: [B, n_kv, max_blocks] int32 physical head-block ids
    seq_lens: [B] (length INCLUDING the current token)
    Returns [B, H, hd].
    """
    B, H, hd = q.shape
    BT = pool_k.shape[1]
    n_kv, max_blocks = phys.shape[1], phys.shape[2]
    group = H // n_kv
    scale = 1.0 / math.sqrt(hd)

    k = pool_k[phys].reshape(B, n_kv, max_blocks * BT, hd)
    v = pool_v[phys].reshape(B, n_kv, max_blocks * BT, hd)

    qh = q.reshape(B, n_kv, group, hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qh, k).astype(jnp.float32) * scale
    t_pos = jnp.arange(max_blocks * BT)[None, None, None, :]
    mask = t_pos < seq_lens[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, v)
    return out.reshape(B, H, hd)


def paged_decode_attention(q, pool_k, pool_v, table, seq_lens, layer, n_kv):
    """Single-token decode attention against the paged pool (oracle).

    q: [B, H, hd] — one query token per sequence (post-RoPE)
    pool_k/v: [N, BT, hd]
    table: [B, max_blocks]; seq_lens: [B] (length INCLUDING current token,
    whose KV must already be written).
    Returns [B, H, hd].
    """
    phys = resolve_physical_blocks(table, layer, n_kv)
    return fused_paged_decode_attention(q, pool_k, pool_v, phys, seq_lens)
