"""Shared paged-KV index arithmetic (layer-neutral).

Physical head-block id for (token-block base b, layer l, kv head h) of
a model with KV kv-heads: ``b + l*KV + h`` (groups are contiguous —
see serving/kvcache.py).  Both the XLA oracle (serving/cache_ops) and
the Pallas kernels (kernels/paged_attention) resolve tables through
this one function so the two layers can never disagree on the pool
layout.
"""
from __future__ import annotations

import jax.numpy as jnp


def resolve_physical_blocks(table, layer, n_kv):
    """Resolve a group-base block table to physical head-block ids.

    table: [B, max_blocks] int32 group bases (−1 padded)
    Returns [B, n_kv, max_blocks] int32 physical ids (invalid → 0; the
    caller masks those positions via seq_lens).  Rows of a *fused*
    multi-LLM batch can come from different models as long as their
    (layer, n_kv) resolution has already been applied here — this is
    the per-row handoff point between the pool and the fused kernel.
    """
    layer = jnp.asarray(layer, jnp.int32)
    phys = (jnp.maximum(table, 0)[:, None, :] + layer * n_kv
            + jnp.arange(n_kv, dtype=jnp.int32)[None, :, None])
    return jnp.where(table[:, None, :] >= 0, phys, 0).astype(jnp.int32)
