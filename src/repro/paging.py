"""Shared paged-KV index arithmetic (layer-neutral).

Physical head-block id for (token-block base b, layer l, kv head h) of
a model with KV kv-heads: ``b + l*KV + h`` (groups are contiguous —
see serving/kvcache.py).  Both the XLA oracle (serving/cache_ops) and
the Pallas kernels (kernels/paged_attention) resolve tables through
this one function so the two layers can never disagree on the pool
layout.
"""
from __future__ import annotations

import jax.numpy as jnp


def resolve_physical_blocks(table, layer, n_kv):
    """Resolve a group-base block table to physical head-block ids.

    table: [..., max_blocks] int32 group bases (−1 padded) — any number
    of leading batch dims (the fused multi-LLM sweeps pass their row
    batches flattened or as [M, rows]).
    Returns [..., n_kv, max_blocks] int32 physical ids (invalid → 0;
    the caller masks those positions via seq_lens / query positions).
    Rows of a *fused* multi-LLM batch can come from different models as
    long as their (layer, n_kv) resolution has already been applied
    here — this is the per-row handoff point between the pool and the
    fused kernels (decode AND prefill).
    """
    layer = jnp.asarray(layer, jnp.int32)
    heads = jnp.arange(n_kv, dtype=jnp.int32)[:, None]       # [n_kv, 1]
    phys = jnp.maximum(table, 0)[..., None, :] + layer * n_kv + heads
    return jnp.where(table[..., None, :] >= 0, phys, 0).astype(jnp.int32)
