"""Weight-only + KV-cache int8 quantization for serving (§Perf).

Beyond-paper optimization: the paper serves bf16 weights; on 16 GiB
v5e chips a 104B model forces FSDP-style weight sharding whose per-step
all-gathers dominate the decode roofline (command-r decode_32k:
t_coll 0.33 s vs t_mem 11 ms).  Per-channel symmetric int8 weights
halve the footprint so the model serves with 1-D (model-axis-only)
sharding — no weight collectives at all — and int8 KV halves the
decode's HBM traffic.

Quantization is per OUTPUT channel (the last axis), so dequantization
commutes with the matmul:  (x @ Wq)·s == x @ (Wq·s)  exactly — kernels
dequantize after the GEMM, no big bf16 weight temporaries.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# stacked weight leaves that get int8 treatment (per family)
_QUANT_LEAVES = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                 "in_proj", "out_proj"}


def quantize_tensor(w: jnp.ndarray, axis: int = -1
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-channel int8 over ``axis`` (the output channels).

    Returns (q int8 same shape, scale f32 with ``axis`` kept)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)),
                   axis=tuple(i for i in range(w.ndim)
                              if i != (axis % w.ndim)),
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _stacked_scale_axes(name: str, ndim: int) -> Tuple[int, ...]:
    """Reduction axes for a stacked [L, ..., d_out] weight: everything
    except the layer dim (0) and the output dim (-1)."""
    return tuple(range(1, ndim - 1))


def quantize_leaf(name: str, w: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(layer, output-channel) int8 for a stacked weight."""
    red = _stacked_scale_axes(name, w.ndim)
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red,
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def quantize_params(params: Pytree) -> Pytree:
    """Quantize a model param tree for serving.

    Matmul weights → (name+"_q" int8, name+"_s" f32 broadcastable);
    norms / biases / small leaves stay as-is.
    """
    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k in _QUANT_LEAVES and v.ndim >= 3:
                q, s = quantize_leaf(k, v)
                out[k + "_q"] = q
                out[k + "_s"] = s
            elif k == "embed":
                q, s = quantize_tensor(v, axis=-1)
                out["embed_q"] = q
                out["embed_s"] = s
            elif k == "lm_head":
                q, s = quantize_tensor(v, axis=-1)
                out["lm_head_q"] = q
                out["lm_head_s"] = s
            else:
                out[k] = v
        return out

    return walk(params)


def qmatmul(x: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray
            ) -> jnp.ndarray:
    """x @ dequant(q, s) computed as (x @ q)·s (exact for per-output-
    channel scales; no bf16 weight temporary)."""
    y = x.astype(jnp.bfloat16) @ q.astype(jnp.bfloat16)
    return (y.astype(jnp.float32) * jnp.squeeze(s)).astype(x.dtype)


class QLayerView:
    """Per-layer dict view over a quantized stacked-param tree that the
    existing layer functions can index with ``li = 0``: weights are
    dequantized lazily as [1, ...] bf16 slices (per-device slice only —
    the full stack stays int8 in HBM)."""

    def __init__(self, qtree: Dict, li):
        self.qtree = qtree
        self.li = li

    def __contains__(self, k):
        return k in self.qtree or (k + "_q") in self.qtree

    def __getitem__(self, k):
        t = self.qtree
        if k + "_q" in t:
            q = jax.lax.dynamic_index_in_dim(t[k + "_q"], self.li,
                                             keepdims=False)
            s = jax.lax.dynamic_index_in_dim(t[k + "_s"], self.li,
                                             keepdims=False)
            return (q.astype(jnp.bfloat16)
                    * s.astype(jnp.bfloat16))[None]
        return jax.lax.dynamic_index_in_dim(t[k], self.li,
                                            keepdims=True)


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------
def quantize_kv(k: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token's KV [B, KV, hd] → (int8, scale [B, KV])."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """[..., hd] int8 + [...] scale → f32."""
    return q.astype(jnp.float32) * scale[..., None]
