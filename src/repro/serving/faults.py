"""Fault injection & graceful degradation (DESIGN.md §12).

MuxServe's SLO story is measured on healthy hardware, but the north
star ("heavy traffic from millions of users") means the multiplexed
runtime must also *degrade* instead of *collapse*: a crashed engine,
a bad HBM region eating KV blocks, an aborted migration or a
transiently failing step must each leave the unit in a consistent,
serving state — and sustained overload must shed work deliberately
(recorded, SLO-missed) rather than carry it on an unbounded queue
forever.  This module is the *injection* half of that contract:

  * **FaultEvent / FaultPlan** — a deterministic, seedable schedule of
    faults on the serving clock.  Four fault classes:

      - ``engine_crash``    — engine ``target`` dies at time ``at``;
        its device state (slots, SSM carries, KV view) is lost and the
        scheduler must rebuild it (``MuxScheduler.recover_engine``);
      - ``block_loss``      — the pool backing ``target``'s unit loses
        ``magnitude`` head-blocks off the arena tail at ``at`` (a bad
        HBM region): sequences with pages there are torn down and
        requeued, the arena shrinks;
      - ``transient_step``  — ``target``'s jitted steps fail for
        ``magnitude`` consecutive ticks starting at ``at`` (driver
        hiccup): the scheduler retries the same work next tick, and
        escalates to a crash recovery past its retry budget;
      - ``migration_abort`` — the next reconfiguration move at or
        after ``at`` aborts mid-copy; the executor re-homes the engine
        on its source unit through the same rollback path a
        fragmentation abort uses (``reconfig.MigrationExecutor``).

  * **FaultInjector** — the runtime hook.  ``MuxScheduler.tick`` polls
    it once per tick (``poll`` fires due crash/block-loss events for
    the engines that unit owns, ``consume_transient`` burns one failed
    tick), and ``MigrationExecutor`` asks ``take_migration_abort``
    before every page copy.  The injector never reads a clock or an
    RNG at runtime — the plan is fixed up front — so a faulted run
    under the deterministic clock is bit-reproducible.

  * **RecoveryCostModel** — logical seconds a recovery stalls the unit
    in deterministic mode, priced like ``TickCostModel`` prices a tick
    (``serving/driver.py`` charges it when it drains a unit's
    ``fault_events``).  Realtime runs skip it: the teardown/rebuild
    wall time is real and already on the clock.

The *survival* half — bounded admission queues, deadline-aware
shedding, retry budgets, crash recovery, the serving-loop watchdog —
lives in ``serving/mux.py`` and ``serving/driver.py``;
``benchmarks/chaos_degradation.py`` gates CI on the combination
degrading smoothly (no cliffs, no hangs, no lost requests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

FAULT_KINDS = ("engine_crash", "block_loss", "transient_step",
               "migration_abort")

# CLI spelling of each kind (launch/serve.py --faults)
_PARSE_KINDS = {"crash": "engine_crash", "block_loss": "block_loss",
                "transient": "transient_step",
                "migration_abort": "migration_abort"}


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on the serving clock."""
    kind: str                       # one of FAULT_KINDS
    at: float                       # clock seconds (logical/wall)
    target: Optional[str] = None    # engine/LLM name (None: migration_abort)
    magnitude: int = 0              # blocks lost / consecutive failed ticks

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        assert self.at >= 0, "fault time must be non-negative"
        if self.kind == "migration_abort":
            assert self.target is None or isinstance(self.target, str)
        else:
            assert self.target, f"{self.kind} needs a target engine name"
        if self.kind in ("block_loss", "transient_step"):
            assert self.magnitude > 0, f"{self.kind} needs magnitude > 0"

    def to_json(self) -> dict:
        return {"kind": self.kind, "at": self.at, "target": self.target,
                "magnitude": self.magnitude}


@dataclass
class FaultPlan:
    """A deterministic fault schedule (sorted by time)."""
    events: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: (e.at, e.kind,
                                                         e.target or ""))

    def __len__(self) -> int:
        return len(self.events)

    def targets(self) -> List[str]:
        return sorted({e.target for e in self.events if e.target})

    def to_json(self) -> List[dict]:
        return [e.to_json() for e in self.events]

    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``--faults`` CLI syntax: a comma list of

            crash:<name>@<t>
            block_loss:<name>:<blocks>@<t>
            transient:<name>:<ticks>@<t>
            migration_abort@<t>

        e.g. ``crash:llm0@2.0,block_loss:llm1:256@1.5``.  Raises
        ``ValueError`` with the offending token on malformed input.
        """
        events: List[FaultEvent] = []
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            head, sep, t_str = tok.partition("@")
            if not sep:
                raise ValueError(f"fault {tok!r}: missing '@<time>'")
            try:
                at = float(t_str)
            except ValueError:
                raise ValueError(
                    f"fault {tok!r}: bad time {t_str!r}") from None
            parts = head.split(":")
            kind = _PARSE_KINDS.get(parts[0])
            if kind is None:
                raise ValueError(
                    f"fault {tok!r}: unknown kind {parts[0]!r} "
                    f"(known: {', '.join(_PARSE_KINDS)})")
            try:
                if kind == "migration_abort":
                    if len(parts) != 1:
                        raise ValueError
                    events.append(FaultEvent(kind, at))
                elif kind == "engine_crash":
                    if len(parts) != 2 or not parts[1]:
                        raise ValueError
                    events.append(FaultEvent(kind, at, parts[1]))
                else:                     # block_loss / transient_step
                    if len(parts) != 3 or not parts[1]:
                        raise ValueError
                    events.append(FaultEvent(kind, at, parts[1],
                                             int(parts[2])))
            except (ValueError, AssertionError):
                raise ValueError(
                    f"fault {tok!r}: expected "
                    f"crash:<name>@<t>, block_loss:<name>:<blocks>@<t>, "
                    f"transient:<name>:<ticks>@<t> or "
                    f"migration_abort@<t>") from None
        return cls(events)

    @classmethod
    def random(cls, names: Sequence[str], horizon: float,
               severity: float, seed: int = 0,
               pool_blocks: int = 4096) -> "FaultPlan":
        """Seeded severity-scaled plan for chaos sweeps.

        A master event list for severity 1.0 is drawn once from
        ``seed`` (per LLM: one crash, one block loss of 1/8 of the
        pool, one 2-tick transient window, all in the middle 60% of
        the horizon, plus one migration abort); ``severity`` ∈ [0, 1]
        takes a *prefix* of that list.  Plans at increasing severity
        are therefore **nested** — more severity strictly adds faults,
        never reshuffles them — which is what lets
        ``benchmarks/chaos_degradation.py`` assert attainment degrades
        monotonically.  Severity 0 is the empty plan.
        """
        assert 0.0 <= severity <= 1.0, severity
        rng = np.random.default_rng(seed)

        def t() -> float:
            return float(rng.uniform(0.2 * horizon, 0.8 * horizon))

        master: List[FaultEvent] = []
        for n in names:
            master.append(FaultEvent("engine_crash", t(), n))
        for n in names:
            master.append(FaultEvent("block_loss", t(), n,
                                     max(pool_blocks // 8, 1)))
        for n in names:
            master.append(FaultEvent("transient_step", t(), n, 2))
        master.append(FaultEvent("migration_abort", t()))
        k = int(round(severity * len(master)))
        return cls(master[:k])


class FaultInjector:
    """Runtime half of the fault plan: polled by the scheduler tick and
    the migration executor, records every fired fault.

    One injector serves every unit of a run (the driver threads it
    through ``serve_requests(faults=...)``): an event fires on the
    first ``poll`` whose unit owns the event's target engine and whose
    clock has reached ``at``.  Events whose target never exists simply
    never fire (reported in ``unfired``).  The injector holds no RNG
    and never reads a clock — determinism is the plan's.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired = [False] * len(plan.events)
        self._transient_left: Dict[str, int] = {}
        self.records: List[dict] = []

    # ------------------------------------------------------------------
    def poll(self, unit, now: float) -> List[FaultEvent]:
        """Fire every due crash/block-loss event owned by ``unit`` and
        arm due transient windows; returns the crash/block-loss events
        for the scheduler to apply (in plan order)."""
        out: List[FaultEvent] = []
        for i, ev in enumerate(self.plan.events):
            if self._fired[i] or ev.at > now:
                continue
            if ev.kind == "migration_abort" or ev.target not in unit.engines:
                continue
            self._fired[i] = True
            self.records.append({**ev.to_json(), "fired_t": now})
            if ev.kind == "transient_step":
                self._transient_left[ev.target] = (
                    self._transient_left.get(ev.target, 0) + ev.magnitude)
            else:
                out.append(ev)
        return out

    def consume_transient(self, name: str) -> bool:
        """One engine-tick of an armed transient window: returns True
        (and burns one failed tick) while the window is open."""
        left = self._transient_left.get(name, 0)
        if left <= 0:
            return False
        self._transient_left[name] = left - 1
        return True

    def clear_transient(self, name: str) -> None:
        """Drop any remaining transient window for ``name`` — a crash
        recovery rebuilt the engine, which clears the wedged state the
        window modeled."""
        self._transient_left.pop(name, None)

    def take_migration_abort(self, now: float) -> bool:
        """Consume one due ``migration_abort`` event (the executor asks
        once per scheduled move, before the page copy)."""
        for i, ev in enumerate(self.plan.events):
            if self._fired[i] or ev.kind != "migration_abort" \
                    or ev.at > now:
                continue
            self._fired[i] = True
            self.records.append({**ev.to_json(), "fired_t": now})
            return True
        return False

    def unfired(self) -> List[FaultEvent]:
        """Plan events that never fired (target absent, or the run
        ended first) — surfaced so a typo'd target is visible."""
        return [ev for i, ev in enumerate(self.plan.events)
                if not self._fired[i]]


@dataclass(frozen=True)
class RecoveryCostModel:
    """Logical seconds one recovery/degradation event stalls the unit
    in deterministic mode (the driver charges it to the
    ``LogicalClock`` when it drains ``MuxScheduler.fault_events`` —
    the fault-handling twin of ``reconfig.MigrationCostModel``):

        dt = base + requeued · per_requeue + blocks · per_block

    ``base`` is the teardown/rebuild control-plane cost, ``per_requeue``
    the re-dispatch cost per torn-down request, ``per_block`` the scrub
    cost per freed/lost head-block.  Shed requests charge nothing —
    shedding is the cheap path by design.
    """
    base: float = 20e-3
    per_requeue: float = 1e-3
    per_block: float = 5e-6

    def dt(self, requeued: int = 0, blocks: int = 0) -> float:
        return (self.base + requeued * self.per_requeue
                + blocks * self.per_block)
