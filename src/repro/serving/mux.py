"""MuxScheduler — spatial-temporal multiplexing of colocated LLMs.

Implements the paper's ADBS (Alg. 3) over real ``Engine`` instances
sharing one ``UnifiedKVPool``:

  * prefill jobs are prioritized and selected round-robin across LLMs;
  * remaining capacity is filled with decode jobs round-robin;
  * per-LLM token-block quotas bound KV usage (fairness, Eq. 2's R);
  * quotas adapt periodically from low- to high-utilization LLMs.

On TPU the "fill remaining SMs" of the paper becomes fusing the decode
batches of all colocated LLMs into the same scheduler tick (DESIGN.md
§2); on this CPU runtime a tick executes the selected jobs back-to-back
and the wall-clock benefit shows up as higher aggregate tokens/s than
FCFS/temporal multiplexing (benchmarks/fig9).

``policy``: "adbs" (paper), "fcfs" (temporal multiplexing baseline),
"round_robin" (no prefill priority, fixed quotas).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.serving.engine import Engine, Request
from repro.serving.kvcache import UnifiedKVPool


@dataclass
class MuxStats:
    finished: List[Request] = field(default_factory=list)
    prefill_tokens: int = 0
    decode_tokens: int = 0
    ticks: int = 0

    def throughput_reqs(self, horizon: float) -> float:
        return len(self.finished) / max(horizon, 1e-9)


class MuxScheduler:
    def __init__(self, engines: Dict[str, Engine], pool: UnifiedKVPool,
                 policy: str = "adbs", adapt_every: int = 16):
        self.engines = engines
        self.pool = pool
        self.policy = policy
        self.adapt_every = adapt_every
        self.queues: Dict[str, Deque[Request]] = {
            name: deque() for name in engines}
        self._names = list(engines)
        self._prefill_rr = 0
        self._decode_rr = 0
        self.stats = MuxStats()
        self.clock = 0.0  # logical time (ticks); callers may use wall time

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queues[req.model].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values()) + sum(
            len(e.active_slots()) for e in self.engines.values())

    # ------------------------------------------------------------------
    def _run_prefill_round_robin(self) -> bool:
        """Try one prefill job round-robin across LLMs (ADBS main loop)."""
        n = len(self._names)
        for i in range(n):
            name = self._names[(self._prefill_rr + i) % n]
            q = self.queues[name]
            eng = self.engines[name]
            batch = []
            while q and len(batch) < len(eng.free_slots()):
                if eng.can_admit(q[0]):
                    batch.append(q.popleft())
                else:
                    break
            if batch or eng.has_prefill_work():
                toks = eng.prefill(batch)
                for r in batch:
                    r.prefill_done = time.perf_counter()
                self.stats.prefill_tokens += toks
                self._prefill_rr = (self._prefill_rr + i + 1) % n
                return True
        return False

    def _run_decode_round_robin(self) -> int:
        """Fill the tick with decode jobs from every LLM (colocation)."""
        total = 0
        n = len(self._names)
        for i in range(n):
            name = self._names[(self._decode_rr + i) % n]
            eng = self.engines[name]
            if eng.has_decode_work():
                total += eng.decode()
        self._decode_rr = (self._decode_rr + 1) % n
        return total

    def _harvest(self) -> None:
        for eng in self.engines.values():
            if eng.finished:
                self.stats.finished.extend(eng.finished)
                eng.finished.clear()

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One scheduler iteration (paper Alg. 3 main loop)."""
        self.stats.ticks += 1
        if self.policy == "adbs":
            ran_prefill = self._run_prefill_round_robin()
            # decode jobs fill the remaining resources (always in this
            # runtime: jobs serialize on CPU, colocate on TPU)
            self.stats.decode_tokens += self._run_decode_round_robin()
            if self.stats.ticks % self.adapt_every == 0:
                self.pool.adapt_quotas()
        elif self.policy == "round_robin":
            # no prefill priority, no quota adaptation
            if self.stats.ticks % 2 == 0:
                self._run_prefill_round_robin()
            self.stats.decode_tokens += self._run_decode_round_robin()
        elif self.policy == "fcfs":
            # temporal multiplexing: serve the LLM with the oldest
            # pending request, prefill+decode to completion batch-wise
            oldest_name, oldest_t = None, float("inf")
            for name, q in self.queues.items():
                if q and q[0].arrival < oldest_t:
                    oldest_name, oldest_t = name, q[0].arrival
            active = [n for n, e in self.engines.items()
                      if e.has_decode_work()]
            if oldest_name is not None and not active:
                eng = self.engines[oldest_name]
                batch = []
                q = self.queues[oldest_name]
                while q and len(batch) < len(eng.free_slots()) \
                        and eng.can_admit(q[0]):
                    batch.append(q.popleft())
                if batch:
                    self.stats.prefill_tokens += eng.prefill(batch)
            for name in active:
                self.stats.decode_tokens += self.engines[name].decode()
        else:
            raise ValueError(self.policy)
        self._harvest()

    def run(self, max_ticks: int = 10_000) -> MuxStats:
        """Drain all queues."""
        t = 0
        while self.pending() and t < max_ticks:
            self.tick()
            t += 1
        return self.stats
