"""MuxScheduler — spatial-temporal multiplexing of colocated LLMs.

Implements the paper's ADBS (Alg. 3) over real ``Engine`` instances
sharing one ``UnifiedKVPool``:

  * prefill jobs are prioritized and selected round-robin across LLMs;
  * remaining capacity is filled with decode jobs round-robin;
  * per-LLM token-block quotas bound KV usage (fairness, Eq. 2's R);
  * quotas adapt periodically from low- to high-utilization LLMs.

On TPU the "fill remaining SMs" of the paper becomes fusing the decode
batches of all colocated LLMs into the same scheduler tick (DESIGN.md
§2).  With ``fused=True`` this runtime executes that fusion for real:
same-architecture engines' weights are stacked once (cached per group)
and every tick runs ONE jitted batched step — cross-model rows share a
single paged-attention + MLP sweep over the unified pool — instead of
N sequential ``Engine.decode`` dispatches.  Heterogeneous leftovers
(SSM engines keep their own scan, MoE its routed FFN, singleton
architectures) fall back to the serial per-engine path in the same
tick.  With ``fused=False`` every engine decodes back-to-back and the
benefit of colocation shows up only as higher aggregate tokens/s than
FCFS/temporal multiplexing (benchmarks/fig9).

``policy``: "adbs" (paper), "fcfs" (temporal multiplexing baseline),
"round_robin" (no prefill priority, fixed quotas).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Deque, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import Engine, Request, _fused_decode_impl
from repro.serving.kvcache import UnifiedKVPool, fused_block_tables


@dataclass
class MuxStats:
    finished: List[Request] = field(default_factory=list)
    prefill_tokens: int = 0
    decode_tokens: int = 0
    ticks: int = 0

    def throughput_reqs(self, horizon: float) -> float:
        return len(self.finished) / max(horizon, 1e-9)


class FusedDecodeGroup:
    """Colocated engines whose decode steps run as ONE jitted sweep.

    Engines land in the same group when ``Engine.fusion_signature()``
    matches (same layer/head geometry, vocab padding, param dtype and
    block-table width).  Their weight trees are stacked once on a
    leading model axis and cached here — per-tick work is only the
    (small) host-side batch assembly, so the fused step amortizes both
    dispatch overhead and kernel-launch count across the group.

    Known cost: the stacked tree is a second copy of each member's
    weights (engines keep their own for prefill and the lone-engine
    fallback), so fused groups pay ~2× weight memory.  De-duplicating
    (engines indexing one stacked buffer) is the planned fix once the
    prefill path can consume stacked trees — see DESIGN.md §2.
    """

    def __init__(self, engines: List[Engine]):
        assert len(engines) >= 2
        sigs = {e.fusion_signature() for e in engines}
        assert len(sigs) == 1 and None not in sigs, \
            "fused group requires matching fusion signatures"
        self.engines = engines
        self.cfg = engines[0].cfg
        self.max_blocks = engines[0].max_blocks
        # fixed row count: padding every tick to max_slots keeps the
        # jitted sweep at ONE compilation per group (a shrinking
        # active-row count would otherwise re-trace the whole stacked
        # forward for every distinct batch size)
        self.rows = max(e.max_slots for e in engines)
        self.params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[e.params for e in engines])
        self._fn = jax.jit(partial(_fused_decode_impl, cfg=self.cfg),
                           donate_argnums=(3, 4))

    def decode(self, jobs) -> int:
        """Run one fused decode step.  ``jobs`` is aligned with
        ``self.engines`` (None where an engine has no decode work this
        tick — its rows are padded and masked, since the stacked param
        tree always carries every group member).  Returns #tokens."""
        pool = self.engines[0].pool
        rows = self.rows
        toks = np.zeros((len(self.engines), rows), np.int32)
        for m, job in enumerate(jobs):
            if job is not None:
                toks[m, :len(job)] = job.last_tok
        tables, lens = fused_block_tables(
            [(eng.view, job.seq_ids if job is not None else [])
             for eng, job in zip(self.engines, jobs)],
            rows, self.max_blocks)
        pool.k, pool.v, logits = self._fn(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            pool.k, pool.v, jnp.asarray(tables))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))        # [M, rows]
        total = 0
        for m, (eng, job) in enumerate(zip(self.engines, jobs)):
            if job is not None:
                total += eng.apply_decode_result(job, nxt[m, :len(job)])
        return total


class MuxScheduler:
    def __init__(self, engines: Dict[str, Engine], pool: UnifiedKVPool,
                 policy: str = "adbs", adapt_every: int = 16,
                 fused: bool = False):
        self.engines = engines
        self.pool = pool
        self.policy = policy
        self.adapt_every = adapt_every
        self.queues: Dict[str, Deque[Request]] = {
            name: deque() for name in engines}
        self._names = list(engines)
        self._prefill_rr = 0
        self._decode_rr = 0
        self.stats = MuxStats()
        self.clock = 0.0  # logical time (ticks); callers may use wall time
        # fused multi-LLM decode tick (DESIGN.md §2): group colocated
        # engines by fusion signature; stacked weights are cached per
        # group for the lifetime of the scheduler.  fcfs (the temporal
        # baseline) never reaches the fused tick — don't pay the
        # stacked-weight copy for it.
        self.fused = fused and policy != "fcfs"
        self.fused_groups: List[FusedDecodeGroup] = []
        self._serial_names = list(engines)
        if self.fused:
            by_sig: Dict[tuple, List[str]] = {}
            for name, eng in engines.items():
                sig = eng.fusion_signature()
                if sig is not None:
                    by_sig.setdefault(sig, []).append(name)
            grouped = set()
            for names in by_sig.values():
                if len(names) >= 2:
                    self.fused_groups.append(
                        FusedDecodeGroup([engines[n] for n in names]))
                    grouped.update(names)
            self._serial_names = [n for n in engines if n not in grouped]

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queues[req.model].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values()) + sum(
            len(e.active_slots()) for e in self.engines.values())

    # ------------------------------------------------------------------
    def _run_prefill_round_robin(self) -> bool:
        """Try one prefill job round-robin across LLMs (ADBS main loop)."""
        n = len(self._names)
        for i in range(n):
            name = self._names[(self._prefill_rr + i) % n]
            q = self.queues[name]
            eng = self.engines[name]
            if q and eng.lifetime_blocks(q[0]) > eng.view.quota:
                # adapt_quotas shrank this LLM's quota below the head
                # request's whole lifetime — it would re-queue forever;
                # pull spare quota back before trying to admit
                self.pool.grant_min_quota(eng.view,
                                          eng.lifetime_blocks(q[0]))
            batch = []
            pending = 0   # lifetime blocks of already-selected requests
            while q and len(batch) < len(eng.free_slots()):
                if eng.can_admit(q[0], pending):
                    pending += eng.lifetime_blocks(q[0])
                    batch.append(q.popleft())
                else:
                    break
            if batch or eng.has_prefill_work():
                toks = eng.prefill(batch)
                for r in batch:
                    r.prefill_done = time.perf_counter()
                self.stats.prefill_tokens += toks
                self._prefill_rr = (self._prefill_rr + i + 1) % n
                return True
        return False

    def _run_decode_round_robin(self) -> int:
        """Fill the tick with decode jobs from every LLM (colocation)."""
        total = 0
        n = len(self._names)
        for i in range(n):
            name = self._names[(self._decode_rr + i) % n]
            eng = self.engines[name]
            if eng.has_decode_work():
                total += eng.decode()
        self._decode_rr = (self._decode_rr + 1) % n
        return total

    def _run_decode_fused(self) -> int:
        """Fused multi-LLM decode tick: one jitted sweep per fused
        group, serial fallback for heterogeneous leftovers."""
        total = 0
        for grp in self.fused_groups:
            jobs = [eng.export_decode_job() for eng in grp.engines]
            n_active = sum(j is not None for j in jobs)
            if n_active == 0:
                continue
            if n_active == 1:
                # a lone active engine gains nothing from the fused
                # sweep — run its (already exported) job serially
                m = next(i for i, j in enumerate(jobs) if j is not None)
                total += grp.engines[m].decode(jobs[m])
            else:
                total += grp.decode(jobs)
        n = len(self._serial_names)
        for i in range(n):
            name = self._serial_names[(self._decode_rr + i) % n]
            eng = self.engines[name]
            if eng.has_decode_work():
                total += eng.decode()
        self._decode_rr = (self._decode_rr + 1) % max(n, 1)
        return total

    def _decode_tick(self) -> int:
        return self._run_decode_fused() if self.fused \
            else self._run_decode_round_robin()

    def _harvest(self) -> None:
        for name, eng in self.engines.items():
            if eng.finished:
                self.stats.finished.extend(eng.finished)
                eng.finished.clear()
            if eng.preempted:
                # stall-escape evictions go back to the head of their
                # queue and restart from scratch on the next prefill
                for r in reversed(eng.preempted):
                    self.queues[name].appendleft(r)
                eng.preempted.clear()

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One scheduler iteration (paper Alg. 3 main loop)."""
        self.stats.ticks += 1
        if self.policy == "adbs":
            self._run_prefill_round_robin()
            # decode jobs fill the remaining resources: one fused
            # multi-LLM sweep when fused=True, back-to-back otherwise
            self.stats.decode_tokens += self._decode_tick()
            if self.stats.ticks % self.adapt_every == 0:
                self.pool.adapt_quotas()
        elif self.policy == "round_robin":
            # no prefill priority, no quota adaptation
            if self.stats.ticks % 2 == 0:
                self._run_prefill_round_robin()
            self.stats.decode_tokens += self._decode_tick()
        elif self.policy == "fcfs":
            # temporal multiplexing: serve the LLM with the oldest
            # pending request, prefill+decode to completion batch-wise
            oldest_name, oldest_t = None, float("inf")
            for name, q in self.queues.items():
                if q and q[0].arrival < oldest_t:
                    oldest_name, oldest_t = name, q[0].arrival
            active = [n for n, e in self.engines.items()
                      if e.has_decode_work()]
            if oldest_name is not None and not active:
                eng = self.engines[oldest_name]
                batch = []
                pending = 0
                q = self.queues[oldest_name]
                while q and len(batch) < len(eng.free_slots()) \
                        and eng.can_admit(q[0], pending):
                    pending += eng.lifetime_blocks(q[0])
                    batch.append(q.popleft())
                if batch:
                    self.stats.prefill_tokens += eng.prefill(batch)
            for name in active:
                self.stats.decode_tokens += self.engines[name].decode()
        else:
            raise ValueError(self.policy)
        self._harvest()

    def run(self, max_ticks: int = 10_000) -> MuxStats:
        """Drain all queues."""
        t = 0
        while self.pending() and t < max_ticks:
            self.tick()
            t += 1
        return self.stats
