"""MuxScheduler — spatial-temporal multiplexing of colocated LLMs.

Implements the paper's ADBS (Alg. 3) over real ``Engine`` instances
sharing one ``UnifiedKVPool``:

  * prefill jobs are prioritized and selected round-robin across LLMs;
  * remaining capacity is filled with decode jobs round-robin;
  * per-LLM token-block quotas bound KV usage (fairness, Eq. 2's R);
  * quotas adapt periodically from low- to high-utilization LLMs.

On TPU the "fill remaining SMs" of the paper becomes fusing the jobs
of all colocated LLMs into the same scheduler tick (DESIGN.md §2).
With ``fused=True`` this runtime executes that fusion for real, in
BOTH phases: same-architecture engines form a ``FusedGroup`` whose
stacked weight tree is the *single* weight copy for the whole group
(members index it on the leading model axis — zero-copy), every tick
runs ONE jitted decode sweep, and — when the engines use chunked
prefill — ONE jitted prefill sweep advances every member's in-flight
prompt chunks.  The HBM reclaimed by de-duplicating weights is granted
to the unified pool as extra head-blocks (more admitted sequences —
the paper's memory-multiplexing argument).  Heterogeneous leftovers
(SSM engines keep their own scan, MoE its routed FFN, singleton
architectures) fall back to the serial per-engine path in the same
tick — off the same stacked buffers when they belong to a group.  With
``fused=False`` every engine steps back-to-back and the benefit of
colocation shows up only as higher aggregate tokens/s than
FCFS/temporal multiplexing (benchmarks/fig9).

``policy``: "adbs" (paper), "fcfs" (temporal multiplexing baseline),
"round_robin" (no prefill priority, fixed quotas).

``sm_frac``: per-engine compute shares from the placement optimizer
(Alg. 2's candidates).  When given, the scheduler *enforces* them —
the runtime twin of the paper's MPS SM-percentage assignment
(DESIGN.md §11): decode jobs are dispatched first under their planned
shares and prefill chunks fill the residual compute of the tick
(Fig. 4's dispatch order), every tick is metered per engine and per
phase (``tick_prefill_by`` / ``tick_decode_by``), and the
deterministic clock (``serving/driver.TickCostModel.tick_dt``)
charges each phase by ``tokens / (devices × effective_share)`` with
roofline flatness and oversubscription contention.  Without shares
the unit keeps the legacy temporal accounting (every job charged as
if it took the whole mesh in turn).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import (Engine, Request, jitted_step, tree_bytes,
                                  unique_tree_bytes)
from repro.serving.faults import FaultInjector
from repro.serving.kvcache import UnifiedKVPool, fused_block_tables

SHED_POLICIES = ("none", "reject", "deadline")


@dataclass
class MuxStats:
    finished: List[Request] = field(default_factory=list)
    # deliberately dropped requests (DESIGN.md §12): backpressure,
    # deadline shedding, requeue-budget exhaustion, watchdog drains.
    # Each carries its ``shed_reason``; the driver rolls them up as
    # SLO misses with a visible disposition, never silent losses.
    shed: List[Request] = field(default_factory=list)
    # client-abandoned requests (DESIGN.md §14): the third disposition —
    # the server stayed healthy, the CLIENT walked away; reports keep
    # ``submitted = finished + shed + cancelled``
    cancelled: List[Request] = field(default_factory=list)
    prefill_tokens: int = 0
    decode_tokens: int = 0
    ticks: int = 0

    def throughput_reqs(self, horizon: float) -> float:
        return len(self.finished) / max(horizon, 1e-9)


class FusedGroup:
    """Colocated engines whose decode (and chunked-prefill) steps run
    as ONE jitted sweep.

    Engines land in the same group when ``Engine.fusion_signature()``
    matches (same layer/head geometry, vocab padding, param dtype,
    block-table width and chunk window).  Their weight trees are
    concatenated once on a leading model axis and the members *adopt*
    the stacked tree (``Engine.adopt_stacked``): each engine's private
    copy is freed and every step — the fused sweeps, serial prefill,
    the lone-active-engine fallback — indexes the one shared buffer.
    A fused group therefore pays ~1× weight memory (asserted by
    ``unique_tree_bytes`` in tests).  ``reclaimed_bytes`` is the
    second full weight copy fused serving paid BEFORE de-duplication
    (private trees alongside the stacked cache — the "known cost" this
    design removes); the scheduler grants exactly those bytes to the
    pool as extra head-blocks, so a fused deployment's HBM budget is
    unchanged while the former duplicate-copy waste now admits
    sequences.  Relative to *serial* serving the grant is additional
    arena, sized only by what fusion used to waste.
    """

    def __init__(self, engines: List[Engine],
                 names: Optional[List[str]] = None):
        assert len(engines) >= 2
        sigs = {e.fusion_signature() for e in engines}
        assert len(sigs) == 1 and None not in sigs, \
            "fused group requires matching fusion signatures"
        self.engines = engines
        self.names = list(names) if names else [e.cfg.name for e in engines]
        self.cfg = engines[0].cfg
        self.cfg_key = engines[0].cfg_key
        self.max_blocks = engines[0].max_blocks
        self.chunk_tokens = engines[0].chunk_tokens
        # fixed row count: padding every tick to max_slots keeps the
        # jitted sweeps at ONE compilation per group (a shrinking
        # active-row count would otherwise re-trace the whole stacked
        # forward for every distinct batch size)
        self.rows = max(e.max_slots for e in engines)
        # zero-copy adoption: concatenate the members' [1, ...] stacks
        # into the group tree, then point every member at it — the
        # per-engine trees are freed, leaving exactly ONE weight copy
        member_bytes = sum(tree_bytes(e.params) for e in engines)
        self.params = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0),
            *[e.params for e in engines])
        for m, e in enumerate(engines):
            e.adopt_stacked(self.params, m)
        self.reclaimed_bytes = member_bytes
        # pool grant bookkeeping, set by the scheduler when it converts
        # reclaimed_bytes into head-blocks: total blocks grown into the
        # pool and the per-member quota share — dissolve() needs both
        # to hand the grant back (live reconfiguration, DESIGN.md §10)
        self.granted_blocks = 0
        self.quota_share = 0
        self._decode_fn = jitted_step("fused_decode", self.cfg_key)
        self._prefill_fn = (jitted_step("fused_prefill_chunk", self.cfg_key)
                            if self.chunk_tokens else None)

    def weight_bytes(self) -> int:
        """Live weight bytes of the whole group (de-duplicated)."""
        return unique_tree_bytes([e.params for e in self.engines])

    def dissolve(self) -> None:
        """Undo the zero-copy adoption: every member re-materializes a
        private ``[1, ...]`` slice of its weights so the shared stacked
        tree can be dropped.  The scheduler pairs this with revoking
        the quota shares and shrinking the pool by ``granted_blocks``
        (``MuxScheduler.dissolve_fused_groups``)."""
        for e in self.engines:
            e.materialize_private()

    def decode(self, jobs) -> Dict[str, int]:
        """Run one fused decode step.  ``jobs`` is aligned with
        ``self.engines`` (None where an engine has no decode work this
        tick — its rows are padded and masked, since the stacked param
        tree always carries every group member).  Returns committed
        #tokens per member name (the scheduler's per-phase share
        metering needs the split, not just the sum)."""
        pool = self.engines[0].pool
        rows = self.rows
        toks = np.zeros((len(self.engines), rows), np.int32)
        for m, job in enumerate(jobs):
            if job is not None:
                toks[m, :len(job)] = job.last_tok
        tables, lens = fused_block_tables(
            [(eng.view, job.seq_ids if job is not None else [])
             for eng, job in zip(self.engines, jobs)],
            rows, self.max_blocks)
        pool.k, pool.v, logits = self._decode_fn(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            pool.k, pool.v, jnp.asarray(tables))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))        # [M, rows]
        per: Dict[str, int] = {}
        for m, (eng, job) in enumerate(zip(self.engines, jobs)):
            if job is not None:
                per[eng.cfg.name] = eng.apply_decode_result(
                    job, nxt[m, :len(job)])
        return per

    def prefill(self, jobs) -> Dict[str, int]:
        """Run one fused chunked-prefill sweep: every member's in-flight
        prompt chunks advance by one window in ONE jitted step.
        ``jobs`` is aligned with ``self.engines`` (None where a member
        has nothing prefilling — its rows are padded: −1 tables drop
        the KV writes, zero chunk lengths mark the logits dead).
        Returns #prompt tokens processed per member name."""
        pool = self.engines[0].pool
        rows, C, M = self.rows, self.chunk_tokens, len(self.engines)
        toks = np.zeros((M, rows, C), np.int32)
        offs = np.zeros((M, rows), np.int32)
        clens = np.zeros((M, rows), np.int32)
        tables = np.full((M, rows, self.max_blocks), -1, np.int32)
        for m, (eng, job) in enumerate(zip(self.engines, jobs)):
            if job is None:
                continue
            b = len(job)
            toks[m, :b] = job.toks
            offs[m, :b] = job.offs
            clens[m, :b] = job.clens
            tables[m, :b] = eng.view.block_table(job.seq_ids,
                                                 self.max_blocks)
        pool.k, pool.v, logits = self._prefill_fn(
            self.params, jnp.asarray(toks), jnp.asarray(offs),
            jnp.asarray(clens), pool.k, pool.v, jnp.asarray(tables))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))        # [M, rows]
        per: Dict[str, int] = {}
        for m, (eng, job) in enumerate(zip(self.engines, jobs)):
            if job is not None:
                per[eng.cfg.name] = eng.apply_prefill_result(
                    job, nxt[m, :len(job)])
        return per


# backwards-compatible name (the group now fuses prefill too)
FusedDecodeGroup = FusedGroup


class MuxScheduler:
    """Paper Alg. 3 (ADBS) over real engines.

    Simulator counterpart: ``core/simulator.UnitSim`` runs the same
    policy branches against cost-model latencies — each branch below
    names the Alg. 3 step it implements so sim/runtime divergence is
    auditable (the sim's version lives in
    ``UnitSim._round_spatial_temporal``).

    ``clock``: zero-argument callable supplying the current time for
    request timestamps (``Request.first_token`` / ``finish`` /
    ``prefill_done``).  Defaults to wall time; a deterministic driver
    (``serving/driver.py``) passes a logical clock it advances itself,
    which makes SLO accounting reproducible across machines.
    """

    def __init__(self, engines: Dict[str, Engine], pool: UnifiedKVPool,
                 policy: str = "adbs", adapt_every: int = 16,
                 fused: bool = False, clock=None,
                 sm_frac: Optional[Dict[str, float]] = None,
                 injector: Optional[FaultInjector] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "none",
                 requeue_budget: int = 3, retry_budget: int = 3):
        assert shed_policy in SHED_POLICIES, shed_policy
        assert max_queue is None or max_queue > 0, max_queue
        self.engines = engines
        self.pool = pool
        self.policy = policy
        self.adapt_every = adapt_every
        # graceful degradation (DESIGN.md §12) — all default-off:
        #   injector        fault plan polled at every tick
        #   max_queue       per-LLM admission-queue bound (backpressure
        #                   sheds NEW arrivals when full; requeues from
        #                   preemption/recovery bypass it — in-flight
        #                   work is never dropped by the bound)
        #   shed_policy     "none" | "reject" (backpressure only) |
        #                   "deadline" (also shed queue heads whose
        #                   Request.deadline has passed)
        #   requeue_budget  teardowns one request may survive before it
        #                   is shed instead of requeued
        #   retry_budget    consecutive transiently-failed ticks before
        #                   a transient window escalates to crash
        #                   recovery
        self.injector = injector
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.requeue_budget = requeue_budget
        self.retry_budget = retry_budget
        # recovery/degradation events of this unit, drained (and clock-
        # charged in deterministic mode) by serving/driver.py
        self.fault_events: List[dict] = []
        self._down: set = set()                  # transient-down engines
        self._transient_ticks: Dict[str, int] = {}
        self.queues: Dict[str, Deque[Request]] = {
            name: deque() for name in engines}
        self._names = list(engines)
        self._prefill_rr = 0
        self._decode_rr = 0
        self.stats = MuxStats()
        # per-engine compute shares (placement sm_frac, DESIGN.md §11).
        # Shares are *enforced* only when the caller supplies them —
        # hand-built units keep the legacy temporal accounting, and
        # fcfs (the temporal-multiplexing baseline) never enforces: a
        # baseline that serves one LLM at a time has no shares to hold.
        self.sm_frac: Dict[str, float] = {n: 1.0 for n in engines}
        if sm_frac:
            self.sm_frac.update({n: float(f) for n, f in sm_frac.items()
                                 if n in engines})
        self.enforce_shares = sm_frac is not None and policy != "fcfs"
        # per-tick, per-engine phase metering (reset every tick): which
        # engines prefilled/decoded how many tokens — the deterministic
        # clock's share-aware tick cost reads these
        self.tick_prefill_by: Dict[str, int] = {}
        self.tick_decode_by: Dict[str, int] = {}
        # one time domain for every timestamp: the scheduler's clock is
        # pushed onto all engines so Request timelines are coherent
        self.clock = clock if clock is not None else time.perf_counter
        for eng in engines.values():
            eng.clock = self.clock
        # token-emission hook (serving/frontend.py) — see ``set_emit``
        self.emit = None
        # fused multi-LLM tick (DESIGN.md §2): group colocated engines
        # by fusion signature; members adopt ONE stacked weight tree
        # per group (zero-copy) for the lifetime of the scheduler, and
        # the HBM the de-dup reclaims is granted to the pool as extra
        # head-blocks (split across the group's views as quota).  fcfs
        # (the temporal baseline) never reaches the fused tick — don't
        # regroup its weights for it.
        self.fused = fused and policy != "fcfs"
        self.fused_groups: List[FusedGroup] = []
        self._serial_names = list(engines)          # serial decode set
        self._prefill_serial_names = list(engines)  # serial prefill set
        self.reclaimed_weight_bytes = 0
        # mesh identity + device count inside a placement
        # (units_from_placement tags both); −1 / 1 for hand-built
        # units.  The reconfiguration subsystem keys its migration
        # schedule on mesh_id; the deterministic clock scales a tick's
        # per-token cost by n_devices (bigger mesh = faster tick).
        self.mesh_id = -1
        self.n_devices = 1
        # un-returned zero-copy grant: blocks a dissolve wanted back
        # but the pool's in-use tail kept (UnifiedKVPool.shrink
        # clamps).  The next build settles this debt before growing,
        # so repeated dissolve/rebuild cycles (live reconfiguration)
        # cannot inflate the arena past its reclaimed-weight backing.
        self._grant_debt = 0
        # optional runtime invariant checker (serving.sanitize);
        # SchedulerSanitizer installs itself here so the block-loss
        # fault path can report arena shrinks that change the base
        self.sanitizer = None
        if self.fused:
            self._build_fused_groups()

    def _build_fused_groups(self) -> None:
        """Group engines by fusion signature, stack weights zero-copy,
        and grant the de-dup dividend to the pool (the __init__ path,
        shared with live-reconfiguration rebuilds)."""
        by_sig: Dict[tuple, List[str]] = {}
        for name, eng in self.engines.items():
            sig = eng.fusion_signature()
            if sig is not None:
                by_sig.setdefault(sig, []).append(name)
        grouped, chunk_grouped = set(), set()
        for names in by_sig.values():
            if len(names) >= 2:
                grp = FusedGroup([self.engines[n] for n in names], names)
                self.fused_groups.append(grp)
                grouped.update(names)
                if grp.chunk_tokens:
                    chunk_grouped.update(names)
                # zero-copy dividend: de-duplicated weight bytes
                # become KV head-blocks for the group's LLMs — minus
                # any un-returned grant from a prior dissolve (the
                # arena still holds those blocks; re-growing the full
                # amount would double-count the reclaimed bytes)
                want = grp.reclaimed_bytes // self.pool.head_block_bytes
                settle = min(self._grant_debt, want)
                self._grant_debt -= settle
                granted = self.pool.grow(want - settle) + settle
                share = granted // len(grp.engines)
                grp.granted_blocks = granted
                grp.quota_share = share
                if share:
                    for e in grp.engines:
                        e.view.quota += share
                self.reclaimed_weight_bytes += grp.reclaimed_bytes
        self._serial_names = [n for n in self.engines if n not in grouped]
        self._prefill_serial_names = [n for n in self.engines
                                      if n not in chunk_grouped]

    def dissolve_fused_groups(self) -> int:
        """Undo every fused group: members re-own private weight
        copies, their quota shares are revoked (clamped so quota never
        drops below live usage) and the pool shrinks by the zero-copy
        grant — ``UnifiedKVPool.shrink`` refuses to cut below in-use
        blocks, so a grant whose tail is occupied is only partially
        returned (the arena re-grows on the next build).  Returns the
        head-blocks actually shrunk."""
        shrunk = 0
        for grp in self.fused_groups:
            grp.dissolve()
            if grp.quota_share:
                for e in grp.engines:
                    e.view.quota -= min(grp.quota_share,
                                        max(e.view.quota - e.view.used, 0))
            got = self.pool.shrink(grp.granted_blocks)
            self._grant_debt += grp.granted_blocks - got
            shrunk += got
            self.reclaimed_weight_bytes -= grp.reclaimed_bytes
        self.fused_groups = []
        self._serial_names = list(self.engines)
        self._prefill_serial_names = list(self.engines)
        return shrunk

    def rebuild_fused_groups(self) -> None:
        """Re-derive fused groups after a membership change (an engine
        joined or left the unit).  Dissolve-then-build keeps one code
        path for the zero-copy stacking and its pool grant."""
        self.dissolve_fused_groups()
        if self.fused:
            self._build_fused_groups()

    # ------------------------------------------------------------------
    def remove_engine(self, name: str):
        """Detach one engine for migration: dissolve its fused group
        (and rebuild the remainder), drop it from every scheduling
        structure and hand back ``(engine, queued_requests)``.  The
        engine keeps its live slots and cache view — the caller
        migrates the view and re-homes the engine via ``add_engine``.
        """
        assert name in self.engines, name
        eng = self.engines.pop(name)
        queued = list(self.queues.pop(name))
        self.sm_frac.pop(name, None)
        self._names = list(self.engines)
        self._prefill_rr = self._decode_rr = 0
        self.rebuild_fused_groups()
        return eng, queued

    def add_engine(self, name: str, eng, queued=(),
                   sm_frac: float = 1.0) -> None:
        """Adopt a migrated engine (and its carried queue) into this
        unit: it joins the tick rotation, inherits the scheduler's
        clock and compute share (``sm_frac``, re-set from the new plan
        by ``MigrationExecutor.apply_shares``), and fuses with
        matching-signature residents."""
        assert name not in self.engines, name
        assert eng.pool is self.pool, \
            "migrate the engine's view to this unit's pool first"
        self.engines[name] = eng
        self.queues[name] = deque(queued)
        self.sm_frac[name] = float(sm_frac)
        eng.clock = self.clock
        eng.emit = self.emit
        self._names = list(self.engines)
        self._prefill_rr = self._decode_rr = 0
        self.rebuild_fused_groups()

    # ------------------------------------------------------------------
    def set_emit(self, fn) -> None:
        """Install the token-emission hook on this unit and every
        engine it hosts: ``fn(event, request, token)`` with events
        "token" / "finish" / "reset" (engine-level commit points),
        "shed" and "cancelled" (scheduler dispositions).  ``add_engine``
        re-applies the hook, so engines rebuilt by crash recovery or
        adopted after a migration keep streaming (the fused sweeps need
        no wiring of their own — they commit through the member
        engines' ``apply_*_result``)."""
        self.emit = fn
        for eng in self.engines.values():
            eng.emit = fn

    def submit(self, req: Request) -> None:
        q = self.queues[req.model]
        if (self.shed_policy != "none" and self.max_queue is not None
                and len(q) >= self.max_queue):
            # bounded admission queue: backpressure sheds the NEW
            # arrival (recorded, SLO-missed) instead of growing the
            # queue without bound under overload
            self._shed(req, "queue_full")
            return
        q.append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values()) + sum(
            len(e.active_slots()) for e in self.engines.values())

    # ---- graceful degradation (DESIGN.md §12) ------------------------
    def _shed(self, req: Request, reason: str) -> None:
        """Deliberately drop one request: flagged (never silent),
        ``finish`` stays −1 so the roll-up counts an SLO miss with a
        ``shed`` disposition."""
        req.shed = True
        req.shed_reason = reason
        self.stats.shed.append(req)
        if self.emit is not None:
            self.emit("shed", req, -1)

    def _shed_expired(self) -> None:
        """Deadline-aware shedding: pop queue heads whose admission
        deadline has passed — by ``Request.deadline``'s construction
        (driver-stamped) even immediate solo-speed service would miss
        their scaled TTFT target, so carrying them only burns capacity
        other requests could still meet their SLOs with."""
        now = self.clock()
        for q in self.queues.values():
            while q and q[0].deadline < now:
                self._shed(q.popleft(), "deadline")

    def cancel(self, req: Request) -> bool:
        """Client abandonment (DESIGN.md §14): release everything the
        request holds NOW — its queue position, or its engine slot plus
        KV blocks and prefix-index refs (``evict_seqs`` → ``free_seq``
        drops shared-prefix refcounts with the rest) — and record the
        ``cancelled`` disposition.  Distinct from shedding: the server
        sheds to protect itself, the client cancels; the roll-up keeps
        ``submitted = finished + shed + cancelled``.  Returns False
        when the request already finished, was shed, or isn't held by
        this unit (nothing to free)."""
        if req.cancelled or req.shed or req.finish >= 0:
            return False
        removed = False
        q = self.queues.get(req.model)
        if q is not None and req in q:
            q.remove(req)
            removed = True
        else:
            eng = self.engines.get(req.model)
            if eng is not None:
                if req in eng.preempted:
                    # evicted this tick, awaiting requeue — drop it
                    # before _harvest puts it back on the queue
                    eng.preempted.remove(req)
                    removed = True
                else:
                    for slot in eng.active_slots():
                        if eng.slots[slot] is req:
                            eng.evict_seqs([int(eng.slot_seq[slot])])
                            removed = True
                            break
        if not removed:
            return False
        req.cancelled = True
        self.stats.cancelled.append(req)
        if self.emit is not None:
            self.emit("cancelled", req, -1)
        return True

    def _apply_faults(self) -> None:
        """Tick preamble: fire due plan events for this unit and track
        transient windows (serving/faults.py).  Crash and block-loss
        events mutate the unit immediately; a transient window marks
        its engine down for this tick (its phase work is skipped and
        retried next tick) and escalates to crash recovery once it has
        burned ``retry_budget`` consecutive ticks."""
        now = self.clock()
        for ev in self.injector.poll(self, now):
            if ev.kind == "engine_crash":
                self.recover_engine(ev.target, reason="crash")
            elif ev.kind == "block_loss":
                self._lose_blocks(ev.magnitude)
        for name in list(self.engines):
            if self.injector.consume_transient(name):
                ticks = self._transient_ticks.get(name, 0) + 1
                if ticks > self.retry_budget:
                    # retry budget exhausted: the engine is wedged, not
                    # hiccuping — rebuild it (clears the window too)
                    self._transient_ticks.pop(name, None)
                    self.injector.clear_transient(name)
                    self.recover_engine(name, reason="transient")
                else:
                    self._transient_ticks[name] = ticks
                    self._down.add(name)
            else:
                self._transient_ticks.pop(name, None)

    def recover_engine(self, name: str, reason: str = "crash") -> dict:
        """Crash recovery: tear down the dead engine and rebuild it on
        a fresh pool view, requeueing its in-flight requests.  Reuses
        the PR-4 migration machinery end to end — ``remove_engine``
        dissolves the fused groups (settling grant debt on rebuild),
        the eviction path is the migration eviction path, and
        ``add_engine`` re-fuses the rebuilt engine with its matching-
        signature residents.  The rebuilt engine starts from clean
        device state (zero SSM carries, empty slots) because the crash
        lost the old state; restart-from-scratch is exact under greedy
        decoding.  Requests past ``requeue_budget`` teardowns are shed
        instead of requeued (a request must not ping-pong through
        recoveries forever).  Returns the recovery record (also
        appended to ``fault_events`` for the driver to clock-charge).
        """
        share = self.sm_frac.get(name, 1.0)
        eng, queued = self.remove_engine(name)
        blocks_held = eng.view.used
        evicted = eng.evict_seqs(eng.live_seq_ids())
        quota = eng.view.quota
        self.pool.unregister_model(name)
        view = self.pool.register_model(eng.cfg, quota)
        params = jax.tree_util.tree_map(lambda a: a[0], eng.params)
        fresh = Engine(eng.cfg, params, view, max_slots=eng.max_slots,
                       max_blocks_per_seq=eng.max_blocks,
                       chunk_tokens=eng.chunk_tokens, clock=self.clock)
        for r in evicted:
            r.requeues += 1
        carried: List[Request] = []
        shed = 0
        # deterministic arrival-order requeue: evicted in-flight work
        # and the carried queue re-enter in (arrival, req_id) order,
        # independent of slot/eviction order
        for r in sorted(list(evicted) + list(queued),
                        key=lambda r: (r.arrival, r.req_id)):
            if r.requeues > self.requeue_budget:
                self._shed(r, "requeue_budget")
                shed += 1
            else:
                carried.append(r)
        self.add_engine(name, fresh, carried, sm_frac=share)
        rec = {"kind": "engine_crash", "reason": reason,
               "t": self.clock(), "target": name,
               "requeued": len(evicted), "shed": shed,
               "blocks": blocks_held}
        self.fault_events.append(rec)
        return rec

    def _lose_blocks(self, n: int) -> dict:
        """Block-loss fault: the arena loses its last ``n`` head-blocks
        (a bad HBM region).  Sequences with pages in the doomed tail
        are torn down at the engine level (pool accounting stays
        exact) and requeued at the head of their queues in arrival
        order; once the victims are gone the tail is entirely free and
        the pool shrinks by exactly the lost blocks.  A shared doomed
        block evicts every sharer (each sharer's block table names it,
        so ``tail_victims`` lists them all), and ``pool.shrink`` drops
        doomed prefix-index entries with it — no dangling cached base
        can survive a block loss."""
        n = min(max(n, 0), self.pool.n_head_blocks)
        requeued = shed = 0
        for name, sids in self.pool.tail_victims(n).items():
            eng = self.engines.get(name)
            if eng is None:
                continue
            evicted = eng.evict_seqs(sids)
            keep: List[Request] = []
            for r in evicted:
                r.requeues += 1
                if r.requeues > self.requeue_budget:
                    self._shed(r, "requeue_budget")
                    shed += 1
                else:
                    keep.append(r)
            for r in sorted(keep, key=lambda r: (r.arrival, r.req_id),
                            reverse=True):
                self.queues[name].appendleft(r)
            requeued += len(evicted)
        removed = self.pool.shrink(n)
        if self.sanitizer is not None:
            self.sanitizer.note_blocks_lost(removed)
        rec = {"kind": "block_loss", "t": self.clock(), "target": None,
               "requeued": requeued, "shed": shed, "blocks": removed}
        self.fault_events.append(rec)
        return rec

    def prefix_stats(self) -> Dict[str, dict]:
        """Per-LLM prefix-cache counters for this unit's pool (empty
        when ``--prefix-cache`` is off) — the ServeReport's hit-rate
        source.  Read from the pool's CURRENT views, so counters
        survive engine replacement on crash recovery (the fresh view's
        index starts cold, as it must: the old refs died with it)."""
        return self.pool.prefix_stats()

    def shed_all(self, reason: str = "watchdog") -> int:
        """Force-drain the unit: shed every queued AND in-flight
        request (the watchdog's last resort — a stall that survived
        every recovery path must still terminate with ``submitted =
        finished + shed``, not hang).  Returns the number shed."""
        n = 0
        for q in self.queues.values():
            while q:
                self._shed(q.popleft(), reason)
                n += 1
        for eng in self.engines.values():
            for r in eng.evict_seqs(eng.live_seq_ids()):
                self._shed(r, reason)
                n += 1
        return n

    # ------------------------------------------------------------------
    def _meter(self, counter: Dict[str, int], name: str, toks: int) -> None:
        """Credit one engine's phase tokens for this tick (share-aware
        clock input; reset at every ``tick``)."""
        if toks:
            counter[name] = counter.get(name, 0) + toks

    # ------------------------------------------------------------------
    def _pull_batch(self, name: str) -> List[Request]:
        """Pop an admissible batch for one LLM — Alg. 3's
        ``resource_enough`` gate (Eq. 2's per-LLM cache share R):
        whole-lifetime quota check, cumulative across the batch.
        Simulator counterpart: ``UnitSim._try_prefill_batch`` (same
        lifetime reservation, in bytes instead of head-blocks)."""
        if name in self._down:
            # transient step failure this tick: admit nothing, retry
            # the same queue next tick
            return []
        q = self.queues[name]
        eng = self.engines[name]
        if q and eng.lifetime_blocks(q[0]) > eng.view.quota:
            # adapt_quotas shrank this LLM's quota below the head
            # request's whole lifetime — it would re-queue forever;
            # pull spare quota back before trying to admit
            self.pool.grant_min_quota(eng.view,
                                      eng.lifetime_blocks(q[0]))
        batch: List[Request] = []
        pending = 0   # lifetime blocks of already-selected requests
        while q and len(batch) < len(eng.free_slots()):
            if eng.can_admit(q[0], pending):
                pending += eng.lifetime_blocks(q[0])
                batch.append(q.popleft())
            else:
                break
        return batch

    def _run_prefill_round_robin(self) -> bool:
        """Try one prefill job round-robin across the serially-prefilled
        LLMs — Alg. 3's prefill-selection step (prefill jobs are
        prioritized; round-robin order across LLMs is the fairness
        rule).  Fused-prefill group members are handled by
        ``_run_prefill_fused_groups`` instead.  Simulator counterpart:
        the round-robin prefill loop in
        ``UnitSim._round_spatial_temporal``."""
        names = self._prefill_serial_names
        n = len(names)
        for i in range(n):
            name = names[(self._prefill_rr + i) % n]
            if name in self._down:
                continue
            eng = self.engines[name]
            batch = self._pull_batch(name)
            if batch or eng.has_prefill_work():
                toks = eng.prefill(batch)
                for r in batch:
                    r.prefill_done = self.clock()
                self.stats.prefill_tokens += toks
                self._meter(self.tick_prefill_by, name, toks)
                self._prefill_rr = (self._prefill_rr + i + 1) % n
                return True
        return False

    def _run_prefill_fused_groups(self) -> bool:
        """Fused multi-LLM prefill tick: admit round-robin into every
        chunked group member (host-side bookkeeping only), then advance
        ALL members' in-flight chunks in one jitted sweep per group —
        the prefill-phase mirror of the fused decode tick."""
        ran = False
        for grp in self.fused_groups:
            if grp.chunk_tokens is None:
                continue
            now = self.clock()
            for name, eng in zip(grp.names, grp.engines):
                batch = self._pull_batch(name)
                if batch:
                    eng.admit_chunked(batch)
                    for r in batch:
                        r.prefill_done = now
            jobs = [None if name in self._down else eng.export_prefill_job()
                    for name, eng in zip(grp.names, grp.engines)]
            n_active = sum(j is not None for j in jobs)
            if n_active == 0:
                continue
            if n_active == 1:
                # a lone prefilling engine gains nothing from the fused
                # sweep — run its exported job serially (off the SAME
                # stacked buffers, via its model index)
                m = next(i for i, j in enumerate(jobs) if j is not None)
                toks = grp.engines[m].run_chunk_job(jobs[m])
                self.stats.prefill_tokens += toks
                self._meter(self.tick_prefill_by, grp.names[m], toks)
            else:
                per = grp.prefill(jobs)
                self.stats.prefill_tokens += sum(per.values())
                for name, toks in per.items():
                    self._meter(self.tick_prefill_by, name, toks)
            ran = True
        return ran

    def _run_prefill(self) -> bool:
        ran = self._run_prefill_fused_groups() if self.fused else False
        return self._run_prefill_round_robin() or ran

    def _run_decode_round_robin(self) -> int:
        """Fill the tick with decode jobs from every LLM — Alg. 3's
        decode-fill step ("remaining resources go to decode jobs"),
        i.e. decode-decode colocation.  Simulator counterpart: the
        concurrent-decode block of ``UnitSim._round_spatial_temporal``
        (``t_round = Σ t_p + max_m t_d^m``, Eq. 3's round shape)."""
        total = 0
        n = len(self._names)
        for i in range(n):
            name = self._names[(self._decode_rr + i) % n]
            if name in self._down:
                continue
            eng = self.engines[name]
            if eng.has_decode_work():
                toks = eng.decode()
                self._meter(self.tick_decode_by, name, toks)
                total += toks
        self._decode_rr = (self._decode_rr + 1) % max(n, 1)
        return total

    def _run_decode_fused(self) -> int:
        """Fused multi-LLM decode tick: one jitted sweep per fused
        group, serial fallback for heterogeneous leftovers."""
        total = 0
        for grp in self.fused_groups:
            jobs = [None if name in self._down else eng.export_decode_job()
                    for name, eng in zip(grp.names, grp.engines)]
            n_active = sum(j is not None for j in jobs)
            if n_active == 0:
                continue
            if n_active == 1:
                # a lone active engine gains nothing from the fused
                # sweep — run its (already exported) job serially
                m = next(i for i, j in enumerate(jobs) if j is not None)
                toks = grp.engines[m].decode(jobs[m])
                self._meter(self.tick_decode_by, grp.names[m], toks)
                total += toks
            else:
                per = grp.decode(jobs)
                for name, toks in per.items():
                    self._meter(self.tick_decode_by, name, toks)
                total += sum(per.values())
        n = len(self._serial_names)
        for i in range(n):
            name = self._serial_names[(self._decode_rr + i) % n]
            if name in self._down:
                continue
            eng = self.engines[name]
            if eng.has_decode_work():
                toks = eng.decode()
                self._meter(self.tick_decode_by, name, toks)
                total += toks
        self._decode_rr = (self._decode_rr + 1) % max(n, 1)
        return total

    def _decode_tick(self) -> int:
        return self._run_decode_fused() if self.fused \
            else self._run_decode_round_robin()

    def _harvest(self) -> None:
        for name, eng in self.engines.items():
            if eng.finished:
                self.stats.finished.extend(eng.finished)
                eng.finished.clear()
            if eng.preempted:
                # stall-escape evictions go back to the head of their
                # queue and restart from scratch on the next prefill —
                # in (arrival, req_id) order, NOT eviction order: the
                # engine preempts youngest-first, and letting that
                # order leak into the retry queue would serve a later
                # arrival before an earlier one evicted the same tick
                # (and make the requeue order depend on slot layout)
                for r in sorted(eng.preempted,
                                key=lambda r: (r.arrival, r.req_id),
                                reverse=True):
                    self.queues[name].appendleft(r)
                eng.preempted.clear()

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One scheduler iteration (paper Alg. 3 main loop).

        Branch ↔ paper mapping (sim counterpart in parentheses — both
        must stay in step, tests/test_slo_driver.py compares them on
        shared conventions):

        * ``adbs`` — Alg. 3 verbatim: prefill-priority round-robin
          selection, decode fills the remaining resources, and
          ``adapt_quota_periodically`` every ``adapt_every`` ticks
          (``UnitSim._round_spatial_temporal`` + ``_adapt_quotas``).
        * ``round_robin`` — Fig. 9 ablation arm: the same loop without
          prefill priority (prefill only every other tick) and with
          FIXED quotas — isolates what ADBS's two mechanisms add.
        * ``fcfs`` — temporal-multiplexing baseline (AlpaServe-style):
          strict global arrival order, one LLM at a time, no quotas
          (``UnitSim._round_temporal``).

        With ``enforce_shares`` the adbs branch flips its intra-tick
        phase order: decode jobs are dispatched FIRST, each under its
        planned ``sm_frac``, and prefill chunks fill the residual
        compute afterwards — the paper's Fig.-4 dispatch (decode jobs
        hold their small SM shares, prefill takes the rest) and the
        order the share-aware clock assumes when it computes the
        residual share from the tick's decode set (DESIGN.md §11).
        """
        self.stats.ticks += 1
        self.tick_prefill_by = {}
        self.tick_decode_by = {}
        # fault/degradation preamble (DESIGN.md §12): shed expired
        # queue heads, fire due fault-plan events, mark transient-down
        # engines for this tick — before any policy branch, so every
        # policy sees the same post-fault unit
        self._down = set()
        if self.shed_policy == "deadline":
            self._shed_expired()
        if self.injector is not None:
            self._apply_faults()
        if self.policy == "adbs":
            if self.enforce_shares:
                # decode under the planned shares first; prefill fills
                # the residual compute of the tick
                self.stats.decode_tokens += self._decode_tick()
                self._run_prefill()
            else:
                self._run_prefill()
                # decode jobs fill the remaining resources: one fused
                # multi-LLM sweep when fused=True, back-to-back
                # otherwise
                self.stats.decode_tokens += self._decode_tick()
            if self.stats.ticks % self.adapt_every == 0:
                # Alg. 3's adapt_quota_periodically (sim counterpart:
                # UnitSim._adapt_quotas, same low→high utilization move)
                self.pool.adapt_quotas()
        elif self.policy == "round_robin":
            # no prefill priority, no quota adaptation
            if self.stats.ticks % 2 == 0:
                self._run_prefill()
            self.stats.decode_tokens += self._decode_tick()
        elif self.policy == "fcfs":
            # temporal multiplexing: serve the LLM with the oldest
            # pending request, prefill+decode to completion batch-wise.
            # In-flight prompt chunks must keep advancing regardless of
            # admission — a chunked prefill that only moved when a NEW
            # batch was admissible would stall forever once slots or
            # quota block the queue head (the unit is busy until the
            # current batch completes; new admissions wait).
            # the one-LLM-at-a-time admission gate reads the FULL busy
            # sets; transient-down engines only skip the work loops
            # (their in-flight batch still blocks new admissions)
            busy_prefill = [n for n, e in self.engines.items()
                            if e.has_prefill_work()]
            busy_decode = [n for n, e in self.engines.items()
                           if e.has_decode_work()]
            prefilling = [n for n in busy_prefill if n not in self._down]
            for name in prefilling:
                toks = self.engines[name].prefill([])
                self.stats.prefill_tokens += toks
                self._meter(self.tick_prefill_by, name, toks)
            active = [n for n in busy_decode if n not in self._down]
            oldest_name, oldest_t = None, float("inf")
            for name, q in self.queues.items():
                if q and q[0].arrival < oldest_t:
                    oldest_name, oldest_t = name, q[0].arrival
            if oldest_name is not None and not busy_decode \
                    and not busy_prefill and oldest_name not in self._down:
                eng = self.engines[oldest_name]
                q = self.queues[oldest_name]
                if q and eng.lifetime_blocks(q[0]) > eng.view.quota:
                    # same escape as _pull_batch: a head request whose
                    # lifetime exceeds the LLM's quota would re-queue
                    # forever (fcfs has no adaptation to fix it)
                    self.pool.grant_min_quota(eng.view,
                                              eng.lifetime_blocks(q[0]))
                batch = []
                pending = 0
                while q and len(batch) < len(eng.free_slots()) \
                        and eng.can_admit(q[0], pending):
                    pending += eng.lifetime_blocks(q[0])
                    batch.append(q.popleft())
                if batch:
                    now = self.clock()
                    for r in batch:
                        r.prefill_done = now
                    toks = eng.prefill(batch)
                    self.stats.prefill_tokens += toks
                    self._meter(self.tick_prefill_by, oldest_name, toks)
            for name in active:
                toks = self.engines[name].decode()
                self.stats.decode_tokens += toks
                self._meter(self.tick_decode_by, name, toks)
        else:
            raise ValueError(self.policy)
        self._harvest()

    def run(self, max_ticks: int = 10_000) -> MuxStats:
        """Drain all queues."""
        t = 0
        while self.pending() and t < max_ticks:
            self.tick()
            t += 1
        return self.stats
