"""Per-LLM runtime engine: disaggregated prefill / decode jobs.

Mirrors MuxServe's runtime-engine design (§3.4): prefill and decode are
*separate jobs* operating on shared weights and the unified KV pool.
The global ADBS scheduler (serving/mux.py) decides which job runs each
tick; the analogue of MPS SM-assignment is the fused multi-LLM decode
step (DESIGN.md §2) — ``export_decode_job`` / ``apply_decode_result``
are this engine's half of that contract, ``_fused_decode_impl`` the
stacked-weights sweep itself.

The engine manages a fixed number of decode *slots* (continuous
batching): a sequence occupies a slot from prefill completion until
finish, and its attention KV lives in the unified pool while SSM state
(constant-size) lives in per-slot dense arrays.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import BLOCK_TOKENS, ModelConfig
from repro.models import mamba2 as M2
from repro.models import moe as MoE
from repro.models.layers import (attn_qkv, causal_attention, lm_logits,
                                 mlp, rms_norm)
from repro.serving import cache_ops
from repro.serving.kvcache import ModelCacheView, UnifiedKVPool


@dataclass
class Request:
    req_id: int
    model: str
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    # runtime state
    output: List[int] = field(default_factory=list)
    prefill_done: float = -1.0
    finish: float = -1.0

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass
class DecodeJob:
    """One engine's decode rows for the current tick, in export form.

    The fused multi-LLM tick (DESIGN.md §2) stacks the jobs of all
    colocated same-architecture engines into a single jitted step; the
    serial path consumes a job one engine at a time.  Block tables and
    sequence lengths are resolved from the pool view at execution time
    (``ModelCacheView.block_table`` / ``fused_block_tables``) so the
    job stays valid across the padding decisions of either path.
    """
    slots: List[int]
    reqs: List[Request]
    seq_ids: List[int]
    last_tok: np.ndarray          # [B] int32 — token decoded this step

    def __len__(self) -> int:
        return len(self.reqs)


class Engine:
    """Inference engine for one LLM over the shared pool (CPU/XLA path)."""

    def __init__(self, cfg: ModelConfig, params, view: ModelCacheView,
                 max_slots: int = 8, max_blocks_per_seq: int = 64,
                 rng_seed: int = 0, chunk_tokens: Optional[int] = None):
        """``chunk_tokens``: enable CHUNKED PREFILL (beyond-paper —
        Sarathi-style): prompts are processed ``chunk_tokens`` at a
        time, one chunk per scheduler tick, so colocated LLMs' decode
        jobs interleave between chunks and a long prompt cannot
        monopolize the unit (bounds TTFT interference under ADBS).
        Attention families only (SSM state chunking is a natural
        extension — the mixer already carries state)."""
        self.cfg = cfg
        self.params = params
        self.view = view
        self.pool = view.pool
        self.max_slots = max_slots
        self.max_blocks = max_blocks_per_seq
        # chunked prefill: attention families chunk against the pool;
        # pure-SSM models chunk via the mixer's state carry.  Hybrid
        # (zamba2) keeps whole-prompt prefill (mixed cache chunking is
        # a straightforward extension, not done here).
        self.chunk_tokens = None if cfg.family == "hybrid" else chunk_tokens
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.slot_seq: np.ndarray = np.full(max_slots, -1, np.int64)
        self.finished: List[Request] = []
        self.preempted: List[Request] = []      # evicted by stall escape
        self._prefilling: Dict[int, int] = {}   # slot → next prompt pos
        self._stall_ticks = 0
        self._rolled_rows: List[int] = []
        self._next_seq = 0
        self._rng = np.random.default_rng(rng_seed)

        # SSM per-slot state
        if cfg.ssm:
            sc = cfg.ssm
            conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
            self.ssm_state = jnp.zeros(
                (cfg.n_layers, max_slots, cfg.n_ssm_heads, sc.head_dim,
                 sc.d_state), jnp.float32)
            self.conv_tail = jnp.zeros(
                (cfg.n_layers, max_slots, sc.conv_kernel - 1, conv_dim),
                jnp.bfloat16 if params["tok"]["embed"].dtype == jnp.bfloat16
                else params["tok"]["embed"].dtype)
        else:
            self.ssm_state = None
            self.conv_tail = None

        self._prefill_fn = jax.jit(partial(_prefill_impl, cfg=cfg),
                                   donate_argnums=(3, 4))
        self._decode_fn = jax.jit(partial(_decode_impl, cfg=cfg),
                                  donate_argnums=(3, 4))
        if cfg.family == "ssm":
            self._chunk_fn = jax.jit(partial(_prefill_chunk_ssm_impl,
                                             cfg=cfg),
                                     donate_argnums=(3, 4))
        else:
            self._chunk_fn = jax.jit(partial(_prefill_chunk_impl, cfg=cfg),
                                     donate_argnums=(4, 5))

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def lifetime_blocks(self, req: Request) -> int:
        """Head-blocks this request needs over its whole lifetime
        (prompt + max_new tokens, plus SSM state pages)."""
        total = len(req.prompt) + req.max_new_tokens
        blocks = -(-total // BLOCK_TOKENS) * self.view.group_size
        if self.cfg.ssm:
            blocks += self.view._ssm_blocks_per_seq
        return blocks

    def can_admit(self, req: Request, pending_blocks: int = 0) -> bool:
        """Whether the request's whole-lifetime quota fits the current
        headroom.  ``pending_blocks``: lifetime blocks of requests
        already selected for the same batch but not yet reserved —
        batch admission must accumulate it, or every candidate is
        checked against the same un-decremented headroom and the batch
        overcommits the quota."""
        if not self.free_slots():
            return False
        return self.lifetime_blocks(req) + pending_blocks <= min(
            self.view.quota_headroom(),
            self.pool.allocator.free_blocks)

    # ------------------------------------------------------------------
    def prefill(self, reqs: List[Request]) -> int:
        """Run one prefill job for up to len(free_slots) requests.

        Returns number of prompt tokens processed (0 if nothing ran).
        With ``chunk_tokens`` set, admits the requests and advances all
        in-flight prefills by one chunk instead (call again next tick).
        """
        if self.chunk_tokens:
            return self._prefill_chunked(reqs)
        reqs = reqs[:len(self.free_slots())]
        admitted = []
        pending = 0
        for r in reqs:
            if self.can_admit(r, pending):
                admitted.append(r)
                pending += self.lifetime_blocks(r)
        if not admitted:
            return 0
        B = len(admitted)
        S = _round_up(max(len(r.prompt) for r in admitted), BLOCK_TOKENS)
        toks = np.zeros((B, S), np.int32)
        lens = np.array([len(r.prompt) for r in admitted], np.int32)
        slot_ids = self.free_slots()[:B]
        seq_ids = []
        for i, r in enumerate(admitted):
            toks[i, :lens[i]] = r.prompt
            sid = self._next_seq
            self._next_seq += 1
            seq_ids.append(sid)
            ok = self.view.append_tokens(sid, int(lens[i]))
            assert ok, "admission check guaranteed quota"
            self.slots[slot_ids[i]] = r
            self.slot_seq[slot_ids[i]] = sid
            r._seq_id = sid

        table = self.view.block_table(seq_ids, self.max_blocks)
        pool_k, pool_v, logits, new_ssm, new_tail = self._prefill_fn(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            self.pool.k, self.pool.v, jnp.asarray(table))
        self.pool.k, self.pool.v = pool_k, pool_v
        if self.cfg.ssm:
            sl = jnp.asarray(slot_ids)
            self.ssm_state = self.ssm_state.at[:, sl].set(new_ssm)
            self.conv_tail = self.conv_tail.at[:, sl].set(
                new_tail.astype(self.conv_tail.dtype))
        # sample first token
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, r in enumerate(admitted):
            # reserve BEFORE committing the token: on quota overcommit
            # (admission point-checks headroom per request) the token
            # is dropped and decode regenerates it at the same
            # position once blocks free up — never a silent desync
            if self.view.append_tokens(seq_ids[i], 1):
                r.output.append(int(nxt[i]))
        return int(lens.sum())

    # ------------------------------------------------------------------
    def _prefill_chunked(self, reqs: List[Request]) -> int:
        """Admit new requests, then advance every in-flight prefill by
        one ``chunk_tokens`` window (one jitted step for the batch)."""
        # admission: same cumulative lifetime check as the unchunked
        # path; prompts reserve immediately, so only the not-yet-
        # reserved growth of earlier admits carries into ``pending``
        pending = 0
        for r in reqs[:len(self.free_slots())]:
            if not self.free_slots():
                break
            if not self.can_admit(r, pending):
                continue
            slot = self.free_slots()[0]
            sid = self._next_seq
            self._next_seq += 1
            used_before = self.view.used
            ok = self.view.append_tokens(sid, len(r.prompt))
            assert ok
            pending += self.lifetime_blocks(r) - (self.view.used
                                                  - used_before)
            self.slots[slot] = r
            self.slot_seq[slot] = sid
            r._seq_id = sid
            self._prefilling[slot] = 0

        if not self._prefilling:
            return 0
        C = self.chunk_tokens
        slots = sorted(self._prefilling)
        B = len(slots)
        toks = np.zeros((B, C), np.int32)
        offs = np.zeros((B,), np.int32)
        clens = np.zeros((B,), np.int32)
        for i, sl in enumerate(slots):
            r = self.slots[sl]
            pos = self._prefilling[sl]
            n = min(C, len(r.prompt) - pos)
            toks[i, :n] = r.prompt[pos:pos + n]
            offs[i] = pos
            clens[i] = n
        seq_ids = [int(self.slot_seq[sl]) for sl in slots]
        if self.cfg.ssm:
            sl_idx = jnp.asarray(np.array(slots))
            st = self.ssm_state[:, sl_idx]
            tail = self.conv_tail[:, sl_idx]
            # fresh sequences start from zero state
            fresh = jnp.asarray((offs == 0).astype(np.float32))
            st = st * (1.0 - fresh)[None, :, None, None, None]
            tail = tail * (1.0 - fresh[None, :, None, None]).astype(
                tail.dtype)
            logits, new_st, new_tail = self._chunk_fn(
                self.params, jnp.asarray(toks), jnp.asarray(clens),
                st, tail)
            self.ssm_state = self.ssm_state.at[:, sl_idx].set(new_st)
            self.conv_tail = self.conv_tail.at[:, sl_idx].set(
                new_tail.astype(self.conv_tail.dtype))
        else:
            table = self.view.block_table(seq_ids, self.max_blocks)
            pool_k, pool_v, logits = self._chunk_fn(
                self.params, jnp.asarray(toks), jnp.asarray(offs),
                jnp.asarray(clens), self.pool.k, self.pool.v,
                jnp.asarray(table))
            self.pool.k, self.pool.v = pool_k, pool_v
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        done_tokens = 0
        for i, sl in enumerate(slots):
            r = self.slots[sl]
            self._prefilling[sl] += int(clens[i])
            done_tokens += int(clens[i])
            if self._prefilling[sl] >= len(r.prompt):
                del self._prefilling[sl]
                # first generated token — same reserve-then-commit as
                # the unchunked path (decode retries on overcommit)
                if self.view.append_tokens(r._seq_id, 1):
                    r.output.append(int(nxt[i]))
        return done_tokens

    # ------------------------------------------------------------------
    def export_decode_job(self) -> Optional[DecodeJob]:
        """Snapshot the tensors the fused multi-LLM tick needs from this
        engine: active decode rows (prefilling slots are excluded until
        their prompt completes) plus per-row sequence identity for
        block-table resolution against the pool.  Returns None when the
        engine has no decode work this tick."""
        act = [s for s in self.active_slots() if s not in self._prefilling]
        if not act:
            return None
        reqs = [self.slots[i] for i in act]
        last = np.array([r.output[-1] if r.output else r.prompt[-1]
                         for r in reqs], np.int32)
        return DecodeJob(slots=act, reqs=reqs,
                         seq_ids=[r._seq_id for r in reqs], last_tok=last)

    def apply_decode_result(self, job: DecodeJob, nxt: np.ndarray) -> int:
        """Commit one decode step's sampled tokens back into engine and
        pool bookkeeping (shared by the serial and fused paths).

        Rows that cannot reserve their next-token block are rolled back
        (indices recorded in ``self._rolled_rows`` for the caller to
        revert any non-idempotent per-step state, e.g. SSM carries).
        """
        done_tokens = 0
        self._rolled_rows = []
        for i, r in enumerate(job.reqs):
            r.output.append(int(nxt[i]))
            done_tokens += 1
            if r.done:
                r.finish = time.perf_counter()
                self.view.free_seq(job.seq_ids[i])
                slot = job.slots[i]
                self.slots[slot] = None
                self.slot_seq[slot] = -1
                self.finished.append(r)
            else:
                ok = self.view.append_tokens(job.seq_ids[i], 1)
                if not ok:
                    # quota overcommit (admitted sequences' future
                    # growth is not reserved, and adapt_quotas may
                    # shrink the quota): a silent miss here would
                    # desync lens/pos and corrupt the sequence's KV on
                    # the next step.  Instead roll the token back and
                    # retry next tick — lens is unchanged, so the
                    # retry recomputes the same position (greedy ⇒ the
                    # same token) once another sequence frees blocks.
                    # The KV rewrite is idempotent; decode() reverts
                    # SSM state for rolled-back rows.
                    r.output.pop()
                    done_tokens -= 1
                    self._rolled_rows.append(i)
        # stall escape: if EVERY row rolled back and nothing finished,
        # no sequence can ever free blocks for the others — after two
        # such ticks, preempt the youngest sequence (evict its cache,
        # restart it from scratch via the scheduler queue; greedy ⇒ it
        # regenerates the same tokens) so the rest can proceed.
        rollbacks = len(self._rolled_rows)
        if rollbacks and rollbacks == len(job.reqs):
            self._stall_ticks += 1
            if self._stall_ticks >= 2:
                self._preempt_youngest()
                self._stall_ticks = 0
        else:
            self._stall_ticks = 0
        return done_tokens

    def _preempt_youngest(self) -> None:
        """Evict the most recently admitted sequence: free its cache,
        reset its progress, and hand the request back via
        ``self.preempted`` (the scheduler re-queues it; direct engine
        users resubmit through ``prefill``).  Restart-from-scratch is
        exact for every family — a fresh prefill rebuilds KV and SSM
        state alike."""
        act = [s for s in self.active_slots() if s not in self._prefilling]
        if not act:
            return
        slot = max(act, key=lambda s: self.slot_seq[s])
        r = self.slots[slot]
        self.view.free_seq(int(self.slot_seq[slot]))
        self.slots[slot] = None
        self.slot_seq[slot] = -1
        r.output.clear()
        r.prefill_done = -1.0
        self.preempted.append(r)

    def decode(self, job: Optional[DecodeJob] = None) -> int:
        """One decode step over all active slots.  Returns #tokens."""
        job = job or self.export_decode_job()
        if job is None:
            return 0
        lens = self.view.seq_lens(job.seq_ids)  # incl. reserved current token
        table = self.view.block_table(job.seq_ids, self.max_blocks)
        sl = jnp.asarray(np.array(job.slots))

        ssm_state = self.ssm_state[:, sl] if self.cfg.ssm else None
        conv_tail = self.conv_tail[:, sl] if self.cfg.ssm else None
        pool_k, pool_v, logits, new_ssm, new_tail = self._decode_fn(
            self.params, jnp.asarray(job.last_tok), jnp.asarray(lens),
            self.pool.k, self.pool.v, jnp.asarray(table),
            ssm_state, conv_tail)
        self.pool.k, self.pool.v = pool_k, pool_v
        if self.cfg.ssm:
            prev_ssm, prev_tail = self.ssm_state, self.conv_tail
            self.ssm_state = self.ssm_state.at[:, sl].set(new_ssm)
            self.conv_tail = self.conv_tail.at[:, sl].set(new_tail)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        toks = self.apply_decode_result(job, nxt)
        if self.cfg.ssm and self._rolled_rows:
            # rolled-back rows must retry from the PRE-step state: the
            # SSM carry is not idempotent (re-advancing it on retry
            # would silently change the eventually-committed token)
            rs = jnp.asarray(np.array([job.slots[i]
                                       for i in self._rolled_rows]))
            self.ssm_state = self.ssm_state.at[:, rs].set(prev_ssm[:, rs])
            self.conv_tail = self.conv_tail.at[:, rs].set(prev_tail[:, rs])
        return toks

    def has_decode_work(self) -> bool:
        return any(s not in self._prefilling for s in self.active_slots())

    def has_prefill_work(self) -> bool:
        return bool(self._prefilling)

    # ------------------------------------------------------------------
    def fusion_signature(self) -> Optional[tuple]:
        """Key under which this engine's decode step can be fused with
        other colocated engines (DESIGN.md §2): engines whose signature
        matches share one stacked-weights jitted step.  ``None`` marks
        the engine fusion-ineligible (SSM/hybrid keep their own scan;
        MoE keeps its own routed FFN) — the scheduler falls back to the
        serial per-engine tick for those.

        The signature pins everything that shapes the stacked param
        tree and the fused computation: layer geometry, head layout,
        projection extras, vocab padding, param dtype and the device
        block-table width.
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "vlm", "audio") or cfg.ssm \
                or cfg.moe:
            return None
        return (cfg.family, cfg.n_layers, cfg.d_model, cfg.n_heads,
                cfg.n_kv_heads, cfg.hd, cfg.d_ff, cfg.vocab_size,
                cfg.qkv_bias, cfg.qk_norm, cfg.rope_theta, cfg.rms_eps,
                cfg.tie_embeddings, cfg.frontend_dim, cfg.n_prefix_tokens,
                str(self.params["tok"]["embed"].dtype), self.max_blocks)


# ---------------------------------------------------------------------------
# jitted step implementations (XLA reference path)
# ---------------------------------------------------------------------------
def _prefill_chunk_impl(params, toks, offs, clens, pool_k, pool_v, table,
                        *, cfg: ModelConfig):
    """One chunked-prefill step: process C prompt tokens per sequence at
    absolute positions offs+i, writing KV into the pool and attending
    against everything written so far.  Garbage KV at padded positions
    (i ≥ clens) lands on future decode slots, which decode overwrites
    before attending — harmless by construction."""
    B, C = toks.shape
    x = params["tok"]["embed"][toks]
    positions = offs[:, None] + jnp.arange(C)[None, :]
    lp = params["layers"]

    attn_li = 0
    for li in range(cfg.n_layers):
        h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp, li, cfg, positions)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k, v, table, offs, attn_li, cfg.n_kv_heads)
        o = cache_ops.paged_chunk_attention(
            q, pool_k, pool_v, table, offs, attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, C, -1) @ lp["wo"][li]
        attn_li += 1
        h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
        if cfg.family == "moe":
            out, _ = MoE.moe_ffn_dropless(h, lp, li, cfg)
            x = x + out
        else:
            x = x + mlp(h, lp, li)

    idx = jnp.maximum(clens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, params["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits


def _prefill_chunk_ssm_impl(params, toks, clens, ssm_state, conv_tail, *,
                            cfg: ModelConfig):
    """Chunked prefill for pure-SSM models: the mixer's conv-tail +
    state carry IS the chunk boundary.  ``clens`` masks padded chunk
    positions (dt=0 ⇒ state frozen past the true chunk length)."""
    B, C = toks.shape
    x = params["tok"]["embed"][toks]
    mask = jnp.arange(C)[None, :] < clens[:, None]
    lp = params["layers"]
    new_ssm = ssm_state
    new_tail = conv_tail
    for li in range(cfg.n_layers):
        h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
        out, st, tail = M2.mamba2_mixer(
            h, lp, li, cfg, conv_tail=conv_tail[li],
            ssm_state=ssm_state[li], return_cache=True, length_mask=mask)
        x = x + out
        new_ssm = new_ssm.at[li].set(st)
        new_tail = new_tail.at[li].set(tail.astype(new_tail.dtype))
    idx = jnp.maximum(clens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, params["tok"], cfg)[..., :cfg.vocab_size]
    return logits, new_ssm, new_tail
def _prefill_impl(params, toks, lens, pool_k, pool_v, table, *,
                  cfg: ModelConfig):
    """Prefill: full causal forward, write KV/state caches, last logits."""
    B, S = toks.shape
    x = params["tok"]["embed"][toks]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    lp = params["layers"]
    n_attn_seen = 0  # static counter for attn layer index within cache

    new_ssm = None
    new_tail = None
    if cfg.ssm:
        sc = cfg.ssm
        conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
        new_ssm = jnp.zeros((cfg.n_layers, B, cfg.n_ssm_heads, sc.head_dim,
                             sc.d_state), jnp.float32)
        new_tail = jnp.zeros((cfg.n_layers, B, sc.conv_kernel - 1, conv_dim),
                             x.dtype)

    def attn_layer(x, li, attn_li, lp_attn, pool_k, pool_v):
        h = rms_norm(x, lp_attn["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp_attn, li, cfg, positions)
        o = causal_attention(q, k, v)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k, v, table, jnp.zeros((B,), jnp.int32),
            attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, S, -1) @ lp_attn["wo"][li]
        return x, pool_k, pool_v

    # NOTE: python loop over layers (engine path is CPU small-model;
    # lowering cost is acceptable and lets attn-layer cache indices be
    # static).
    attn_li = 0
    for li in range(cfg.n_layers):
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            x, pool_k, pool_v = attn_layer(x, li, attn_li, lp, pool_k, pool_v)
            attn_li += 1
            h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
            if cfg.family == "moe":
                out, _ = MoE.moe_ffn_dropless(h, lp, li, cfg)
                x = x + out
            else:
                x = x + mlp(h, lp, li)
        else:  # ssm / hybrid
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, fstate, tail = M2.mamba2_mixer(
                h, lp, li, cfg, return_cache=True,
                length_mask=positions < lens[:, None])
            x = x + out
            new_ssm = new_ssm.at[li].set(fstate)
            new_tail = new_tail.at[li].set(tail.astype(x.dtype))
            if cfg.family == "hybrid" and (li + 1) % cfg.attn_every == 0:
                sa = params["shared_attn"]
                x, pool_k, pool_v = attn_layer(x, 0, attn_li, sa,
                                               pool_k, pool_v)
                attn_li += 1
                h2 = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h2, sa, 0)

    # logits at the true last prompt token
    idx = jnp.maximum(lens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, params["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits, new_ssm, new_tail


def _decode_impl(params, last_tok, lens, pool_k, pool_v, table,
                 ssm_state, conv_tail, *, cfg: ModelConfig):
    """One decode step: write KV of current token, attend, next logits.

    ``lens`` includes the current token (its slot is already reserved);
    its position is lens-1.
    """
    B = last_tok.shape[0]
    x = params["tok"]["embed"][last_tok]                    # [B,d]
    pos = (lens - 1).astype(jnp.int32)
    lp = params["layers"]

    new_ssm = ssm_state
    new_tail = conv_tail

    def attn_layer(x, li, attn_li, lp_attn, pool_k, pool_v):
        h = rms_norm(x, lp_attn["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h[:, None, :], lp_attn, li, cfg, pos[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # [B,H,hd]
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k[:, None], v[:, None], table, pos,
            attn_li, cfg.n_kv_heads)
        o = cache_ops.paged_decode_attention(
            q, pool_k, pool_v, table, lens, attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, -1) @ lp_attn["wo"][li]
        return x, pool_k, pool_v

    attn_li = 0
    for li in range(cfg.n_layers):
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            x, pool_k, pool_v = attn_layer(x, li, attn_li, lp, pool_k, pool_v)
            attn_li += 1
            h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
            if cfg.family == "moe":
                out, _ = MoE.moe_ffn_dropless(h[:, None, :], lp, li, cfg)
                x = x + out[:, 0]
            else:
                x = x + mlp(h, lp, li)
        else:
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, tail_i, st_i = M2.mamba2_decode_step(
                h, lp, li, cfg, conv_tail[li], ssm_state[li])
            x = x + out
            new_ssm = new_ssm.at[li].set(st_i)
            new_tail = new_tail.at[li].set(tail_i)
            if cfg.family == "hybrid" and (li + 1) % cfg.attn_every == 0:
                sa = params["shared_attn"]
                x, pool_k, pool_v = attn_layer(x, 0, attn_li, sa,
                                               pool_k, pool_v)
                attn_li += 1
                h2 = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h2, sa, 0)

    logits = lm_logits(x, params["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits, new_ssm, new_tail


def _fused_decode_impl(params, toks, lens, pool_k, pool_v, tables, *,
                       cfg: ModelConfig):
    """Fused multi-LLM decode step (DESIGN.md §2).

    One jitted sweep advances every colocated same-architecture engine
    by one token: model-private matmuls run as batched contractions over
    the stacked weight axis M, while KV writes and paged attention
    flatten all M×R rows into a single pool operation — the per-row
    block tables already resolve each row to its own model's physical
    head-blocks, so the shared arena needs no per-model dispatch.

    params: engine param trees stacked on a leading [M] axis
    toks: [M, R] int32 last tokens (padded rows are masked by the
        caller; their table entries are −1 so their KV writes drop)
    lens: [M, R] lengths incl. the current token (1 on padded rows)
    tables: [M, R, W] int32 group bases (−1 padded)
    Returns (pool_k, pool_v, logits [M, R, vocab]).
    """
    M, R = toks.shape
    W = tables.shape[2]
    lp = params["layers"]
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    x = jax.vmap(lambda e, t: e[t])(params["tok"]["embed"], toks)  # [M,R,d]
    pos = (lens - 1).astype(jnp.int32)                             # [M,R]
    flat_table = tables.reshape(M * R, W)
    flat_pos = pos.reshape(M * R)
    flat_lens = lens.reshape(M * R)

    # per-layer semantics (projections, bias, qk_norm, rope, SwiGLU,
    # final logits) come from the SAME helpers the serial path uses,
    # vmapped over the stacked model axis — the fused path cannot
    # drift from models/layers.py
    for li in range(cfg.n_layers):
        def qkv_m(xm, lpm, posm, li=li):
            h = rms_norm(xm, lpm["ln1"][li], cfg.rms_eps)
            q, k, v = attn_qkv(h[:, None, :], lpm, li, cfg, posm[:, None])
            return q[:, 0], k[:, 0], v[:, 0]                  # [R,{H,KV},hd]

        def post_m(xm, om, lpm, li=li):
            xm = xm + om.reshape(om.shape[0], -1) @ lpm["wo"][li]
            h = rms_norm(xm, lpm["ln2"][li], cfg.rms_eps)
            return xm + mlp(h, lpm, li)

        q, k, v = jax.vmap(qkv_m)(x, lp, pos)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k.reshape(M * R, 1, n_kv, hd),
            v.reshape(M * R, 1, n_kv, hd), flat_table, flat_pos, li, n_kv)
        phys = cache_ops.resolve_physical_blocks(flat_table, li, n_kv)
        o = cache_ops.fused_paged_decode_attention(
            q.reshape(M * R, n_h, hd), pool_k, pool_v, phys, flat_lens)
        x = jax.vmap(post_m)(x, o.reshape(M, R, n_h, hd), lp)

    logits = jax.vmap(lambda xm, tokm: lm_logits(xm, tokm, cfg))(
        x, params["tok"])
    return pool_k, pool_v, logits[..., :cfg.vocab_size]
