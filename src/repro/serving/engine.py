"""Per-LLM runtime engine: disaggregated prefill / decode jobs.

Mirrors MuxServe's runtime-engine design (§3.4): prefill and decode are
*separate jobs* operating on shared weights and the unified KV pool.
The global ADBS scheduler (serving/mux.py) decides which job runs each
tick; the analogue of MPS SM-assignment is the fused multi-LLM step
(DESIGN.md §2) — ``export_decode_job`` / ``apply_decode_result`` and
``export_prefill_job`` / ``apply_prefill_result`` are this engine's
half of that contract, ``_fused_decode_impl`` /
``_fused_prefill_chunk_impl`` the stacked-weights sweeps themselves.

Zero-copy stacked weights (DESIGN.md §2): every jitted step takes a
param tree stacked on a leading model axis ``M`` plus a model index —
a singleton engine carries an ``M=1`` stack of its own weights, and an
engine adopted into a fused group (``adopt_stacked``) points at the
group's shared tree instead of keeping a private copy.  The per-model
slice happens *inside* the jitted program (a dynamic index on the
leading axis), so one compiled program serves every group member and
no second weight copy ever lives in HBM.

Shape stability: every hot-path batch is padded to a bucketed shape —
powers-of-2 batch rows (masked via −1 block tables / zero lengths) and
block-multiple prompt lengths — so steady-state serving compiles a
bounded set of programs instead of re-tracing per tick.  The
``TRACE_COUNTS`` hook counts impl traces (each jit compilation traces
the impl exactly once) and is asserted bounded in tests and reported
by ``benchmarks/fused_tick``.

The engine manages a fixed number of decode *slots* (continuous
batching): a sequence occupies a slot from prefill completion until
finish, and its attention KV lives in the unified pool while SSM state
(constant-size) lives in per-slot dense arrays.
"""
from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import BLOCK_TOKENS, ModelConfig, replace
from repro.models import mamba2 as M2
from repro.models import moe as MoE
from repro.models.layers import (attn_qkv, causal_attention, lm_logits,
                                 mlp, rms_norm)
from repro.serving import cache_ops
from repro.serving.kvcache import ModelCacheView


@dataclass
class Request:
    """One serving request, carrying its whole latency timeline.

    Timestamps are stamped by the engine/scheduler from the owning
    scheduler's clock (``MuxScheduler(clock=...)``), so they live in a
    single time domain — wall seconds for live serving, logical
    seconds under a deterministic clock (serving/driver.py):

      * ``arrival``      — trace arrival time (set by the submitter;
        queueing delay before admission counts toward TTFT/E2E, as in
        the paper's latency accounting);
      * ``prefill_done`` — prefill job dispatched (admission time);
      * ``first_token``  — first output token committed (TTFT end);
      * ``finish``       — last token committed (E2E end).

    DESIGN.md §9 defines the derived metrics (TTFT/TPOT/E2E) and the
    SLO-attainment convention shared with ``core/simulator.py``.

    Degradation disposition (DESIGN.md §12): a request is never
    silently dropped — overload/fault handling either requeues it
    (``requeues`` counts teardowns it survived; a finished request
    with ``requeues > 0`` was *recovered*) or sheds it (``shed`` set,
    ``finish`` stays −1 so it is an SLO miss at every scale, and
    ``shed_reason`` records why).  ``deadline`` is the absolute clock
    instant past which admission can no longer meet the request's
    scaled TTFT target (stamped by the driver under
    ``shed_policy="deadline"``; +inf = never deadline-shed).
    """
    req_id: int
    model: str
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    # runtime state
    output: List[int] = field(default_factory=list)
    prefill_done: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0
    # degradation disposition (serving/faults.py, DESIGN.md §12)
    deadline: float = float("inf")
    shed: bool = False
    shed_reason: str = ""
    requeues: int = 0
    # client abandonment (DESIGN.md §14): the third disposition next to
    # finished/shed — ``MuxScheduler.cancel`` frees the request's slot,
    # KV blocks and prefix refs immediately and reports preserve
    # ``submitted = finished + shed + cancelled``
    cancelled: bool = False

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _next_pow2(x: int) -> int:
    """Smallest power of two ≥ x (bucketed batch rows — DESIGN.md §5)."""
    return 1 << max(0, (x - 1).bit_length())


def _pad_rows(rows: int, *specs):
    """Pad each ``(array, fill)`` to ``rows`` leading rows.

    One place defines the padded-row invariants of every bucketed
    batch: −1 block tables (KV writes drop, attention resolves to a
    masked block), 0 tokens/lengths (dead logits, sliced off
    host-side) and length-1 decode rows (one masked garbage softmax).
    """
    out = []
    for arr, fill in specs:
        p = np.full((rows,) + arr.shape[1:], fill, arr.dtype)
        p[:arr.shape[0]] = arr
        out.append(p)
    return out


# ---------------------------------------------------------------------------
# weight-tree accounting (zero-copy stacked weights, DESIGN.md §2)
# ---------------------------------------------------------------------------
def tree_bytes(tree) -> int:
    """Total bytes of every leaf in a param tree."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree))


def unique_tree_bytes(trees) -> int:
    """Bytes of the *distinct* buffers across several param trees.

    Engines of a fused group share one stacked tree, so their leaves
    are the same objects — counting each buffer once is the live-memory
    accounting that proves the group pays ~1× (not 2×) weight memory.
    """
    seen: set = set()
    total = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if id(leaf) not in seen:
                seen.add(id(leaf))
                total += leaf.nbytes
    return total


# ---------------------------------------------------------------------------
# trace counting (shape-stability instrumentation)
# ---------------------------------------------------------------------------
# Each entry counts how many times jit TRACED the named step impl —
# i.e. how many distinct programs were compiled for it.  A shape-stable
# runtime stops growing these after warm-up (asserted in
# tests/test_zero_copy.py, reported by benchmarks/fused_tick).
TRACE_COUNTS: Counter = Counter()


def _note_trace(name: str) -> None:
    TRACE_COUNTS[name] += 1


def total_traces() -> int:
    return sum(TRACE_COUNTS.values())


def _select_model(params, midx):
    """Slice one model's tree out of a stacked ``[M, ...]`` tree.

    ``midx`` is a *traced* scalar, so the slice is a dynamic index
    inside the compiled program: every member of a fused group (and
    the M=1 singleton case) shares ONE compilation per shape bucket,
    and no per-model weight copy persists outside the step.
    """
    return jax.tree_util.tree_map(lambda a: a[midx], params)


@dataclass
class DecodeJob:
    """One engine's decode rows for the current tick, in export form.

    The fused multi-LLM tick (DESIGN.md §2) stacks the jobs of all
    colocated same-architecture engines into a single jitted step; the
    serial path consumes a job one engine at a time.  Block tables and
    sequence lengths are resolved from the pool view at execution time
    (``ModelCacheView.block_table`` / ``fused_block_tables``) so the
    job stays valid across the padding decisions of either path.
    """
    slots: List[int]
    reqs: List[Request]
    seq_ids: List[int]
    last_tok: np.ndarray          # [B] int32 — token decoded this step

    def __len__(self) -> int:
        return len(self.reqs)


@dataclass
class PrefillJob:
    """One engine's in-flight prompt chunks for the current tick.

    Mirror of ``DecodeJob`` for the chunked-prefill phase: the fused
    multi-LLM prefill sweep pads the jobs of all group members to the
    group's fixed row count and advances them in ONE jitted step; the
    serial path pads to a power-of-2 row bucket instead.  Arrays are
    exported *unpadded* — the runner owns the padding policy.
    """
    slots: List[int]
    reqs: List[Request]
    seq_ids: List[int]
    toks: np.ndarray              # [B, C] int32 chunk tokens
    offs: np.ndarray              # [B] int32 absolute chunk start
    clens: np.ndarray             # [B] int32 true chunk lengths

    def __len__(self) -> int:
        return len(self.reqs)


class Engine:
    """Inference engine for one LLM over the shared pool (CPU/XLA path)."""

    def __init__(self, cfg: ModelConfig, params, view: ModelCacheView,
                 max_slots: int = 8, max_blocks_per_seq: int = 64,
                 rng_seed: int = 0, chunk_tokens: Optional[int] = None,
                 clock=time.perf_counter):
        """``chunk_tokens``: enable CHUNKED PREFILL (beyond-paper —
        Sarathi-style): prompts are processed ``chunk_tokens`` at a
        time, one chunk per scheduler tick, so colocated LLMs' decode
        jobs interleave between chunks and a long prompt cannot
        monopolize the unit (bounds TTFT interference under ADBS).
        Attention families only (SSM state chunking is a natural
        extension — the mixer already carries state)."""
        self.cfg = cfg
        # request timestamps (first_token/finish) are stamped from this
        # clock so a deterministic driver can own the time domain
        # (serving/driver.py); MuxScheduler re-points it on all engines
        self.clock = clock
        # jit programs are cached per *geometry*, not per model name —
        # colocated instances of the same architecture share programs
        self.cfg_key = replace(cfg, name="")
        self.view = view
        self.pool = view.pool
        self.max_slots = max_slots
        self.max_blocks = max_blocks_per_seq
        # chunked prefill: attention families chunk against the pool;
        # pure-SSM models chunk via the mixer's state carry.  Hybrid
        # (zamba2) keeps whole-prompt prefill (mixed cache chunking is
        # a straightforward extension, not done here).
        self.chunk_tokens = None if cfg.family == "hybrid" else chunk_tokens
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.slot_seq: np.ndarray = np.full(max_slots, -1, np.int64)
        self.finished: List[Request] = []
        self.preempted: List[Request] = []      # evicted by stall escape
        self._prefilling: Dict[int, int] = {}   # slot → next prompt pos
        self._stall_ticks = 0
        self._rolled_rows: List[int] = []
        self._next_seq = 0
        self._rng = np.random.default_rng(rng_seed)
        # token-emission hook (serving/frontend.py): called as
        # ``emit(event, request, token)`` at every COMMITTED progress
        # point — "token" (an output token survived its reserve/validate
        # step; rolled-back tokens never emit), "finish" (request
        # finalized), "reset" (an eviction cleared the request's
        # progress; previously streamed tokens are void).  Installed by
        # ``MuxScheduler.set_emit`` (which re-applies it to engines
        # rebuilt by crash recovery); None = no streaming consumer.
        self.emit: Optional[Callable[[str, Request, int], None]] = None

        # SSM per-slot state
        if cfg.ssm:
            sc = cfg.ssm
            conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
            self.ssm_state = jnp.zeros(
                (cfg.n_layers, max_slots, cfg.n_ssm_heads, sc.head_dim,
                 sc.d_state), jnp.float32)
            self.conv_tail = jnp.zeros(
                (cfg.n_layers, max_slots, sc.conv_kernel - 1, conv_dim),
                jnp.bfloat16 if params["tok"]["embed"].dtype == jnp.bfloat16
                else params["tok"]["embed"].dtype)
        else:
            self.ssm_state = None
            self.conv_tail = None

        # zero-copy weights: the engine holds an M=1 *stacked* tree and
        # always runs the (stacked, model_index) step signature — when
        # a FusedGroup adopts this engine (``adopt_stacked``) the tree
        # is swapped for the group's shared stack and the private copy
        # is freed, with no change to any step path.
        self.params = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None],
                                             params)
        self.model_index = 0
        self._prefill_fn = jitted_step("prefill", self.cfg_key)
        self._decode_fn = jitted_step("decode", self.cfg_key)
        self._chunk_fn = jitted_step(
            "chunk_ssm" if cfg.family == "ssm" else "chunk", self.cfg_key)

    # ------------------------------------------------------------------
    def adopt_stacked(self, stacked, model_index: int) -> None:
        """Point this engine at a fused group's shared stacked tree.

        The private ``[1, ...]`` tree is dropped (freeing its buffers)
        and every step — prefill, chunked prefill, decode, the
        lone-engine fallback — runs off the group's buffers via the
        leading-axis model index.  This is the zero-copy contract:
        after adoption the group holds exactly ONE weight tree.
        """
        self.params = stacked
        self.model_index = model_index

    def materialize_private(self) -> None:
        """Inverse of ``adopt_stacked``: re-own a private ``[1, ...]``
        stacked copy of this engine's weights, sliced out of whatever
        tree it currently points at.  Live reconfiguration dissolves a
        fused group through this before the group's shared buffer is
        dropped — every step keeps the same (stacked, model_index)
        signature, only the tree narrows back to M=1."""
        m = self.model_index
        self.params = jax.tree_util.tree_map(lambda a: a[m:m + 1],
                                             self.params)
        self.model_index = 0

    def rebind_view(self, view: ModelCacheView) -> None:
        """Point the engine at a migrated cache view (and its pool).
        The view must carry this engine's live sequences — block
        tables and lengths are re-resolved from it on every step, so
        in-flight decodes continue without any engine-side fixup."""
        assert view.cfg.name == self.cfg.name
        self.view = view
        self.pool = view.pool

    def evict_prefilling(self) -> List[Request]:
        """Evict every in-flight (chunk-phase) prefill: free its cache,
        reset its progress and hand the requests back for requeueing.
        Migration uses drain-or-carry per request — decodes carry
        their KV to the destination pool, but a half-written prompt is
        cheaper to restart than to move (the chunk position would have
        to migrate too); greedy decoding makes the restart exact."""
        out: List[Request] = []
        for slot in sorted(self._prefilling):
            r = self.slots[slot]
            self.view.free_seq(int(self.slot_seq[slot]))
            self.slots[slot] = None
            self.slot_seq[slot] = -1
            r.output.clear()
            r.prefill_done = -1.0
            r.first_token = -1.0
            if self.emit is not None:
                self.emit("reset", r, -1)
            out.append(r)
        self._prefilling.clear()
        return out

    def evict_seqs(self, seq_ids) -> List[Request]:
        """Evict specific live sequences (prefilling OR decoding): free
        their cache, reset request progress and hand the requests back
        for requeueing.  The fault-handling twin of
        ``evict_prefilling`` — crash recovery evicts every live seq,
        block loss only the seqs whose pages sat in the lost arena
        tail.  Restart-from-scratch is exact for every family (greedy
        decoding; a fresh prefill rebuilds KV and SSM state alike)."""
        wanted = set(int(s) for s in seq_ids)
        out: List[Request] = []
        for slot in self.active_slots():
            sid = int(self.slot_seq[slot])
            if sid not in wanted:
                continue
            r = self.slots[slot]
            self.view.free_seq(sid)
            self.slots[slot] = None
            self.slot_seq[slot] = -1
            self._prefilling.pop(slot, None)
            r.output.clear()
            r.prefill_done = -1.0
            r.first_token = -1.0
            if self.emit is not None:
                self.emit("reset", r, -1)
            out.append(r)
        return out

    def live_seq_ids(self) -> List[int]:
        """Sequence ids of every occupied slot (prefilling included)."""
        return [int(self.slot_seq[s]) for s in self.active_slots()]

    # ------------------------------------------------------------------
    def _finish_slot(self, slot: int, r: Request) -> None:
        """Finalize a request: stamp ``finish``, free its cache and
        slot, hand it to ``finished`` (one definition shared by the
        decode path and the prefill-completes-the-request edge cases —
        ``max_new_tokens ≤ 1``)."""
        r.finish = self.clock()
        self.view.free_seq(int(self.slot_seq[slot]))
        self.slots[slot] = None
        self.slot_seq[slot] = -1
        self.finished.append(r)
        if self.emit is not None:
            self.emit("finish", r, -1)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def lifetime_blocks(self, req: Request) -> int:
        """Head-blocks this request needs over its whole lifetime
        (prompt + max_new tokens, plus SSM state pages)."""
        total = len(req.prompt) + req.max_new_tokens
        blocks = -(-total // BLOCK_TOKENS) * self.view.group_size
        if self.cfg.ssm:
            blocks += self.view._ssm_blocks_per_seq
        return blocks

    def can_admit(self, req: Request, pending_blocks: int = 0) -> bool:
        """Whether the request's whole-lifetime quota fits the current
        headroom.  ``pending_blocks``: lifetime blocks of requests
        already selected for the same batch but not yet reserved —
        batch admission must accumulate it, or every candidate is
        checked against the same un-decremented headroom and the batch
        overcommits the quota."""
        if not self.free_slots():
            return False
        # available_blocks counts evictable prefix-cache inventory —
        # cached blocks are disposable and must never starve admission
        return self.lifetime_blocks(req) + pending_blocks <= min(
            self.view.quota_headroom(),
            self.pool.available_blocks())

    # ------------------------------------------------------------------
    def prefill(self, reqs: List[Request]) -> int:
        """Run one prefill job for up to len(free_slots) requests.

        Returns number of prompt tokens processed (0 if nothing ran).
        With ``chunk_tokens`` set, admits the requests and advances all
        in-flight prefills by one chunk instead (call again next tick).
        """
        if self.chunk_tokens:
            return self._prefill_chunked(reqs)
        reqs = reqs[:len(self.free_slots())]
        admitted = []
        pending = 0
        for r in reqs:
            if self.can_admit(r, pending):
                admitted.append(r)
                pending += self.lifetime_blocks(r)
        if not admitted:
            return 0
        B = len(admitted)
        # shape buckets (DESIGN.md §5): rows to the next power of two,
        # prompt length to the next BLOCK_TOKENS multiple — the padded
        # rows carry −1 tables (KV writes drop) and zero lengths, so
        # steady state revisits a bounded set of compiled programs
        Bp = _next_pow2(B)
        S = _round_up(max(len(r.prompt) for r in admitted), BLOCK_TOKENS)
        toks = np.zeros((B, S), np.int32)
        lens = np.zeros((B,), np.int32)
        slot_ids = self.free_slots()[:B]
        seq_ids = []
        for i, r in enumerate(admitted):
            lens[i] = len(r.prompt)
            toks[i, :lens[i]] = r.prompt
            sid = self._next_seq
            self._next_seq += 1
            seq_ids.append(sid)
            ok = self.view.append_tokens(sid, int(lens[i]))
            assert ok, "admission check guaranteed quota"
            self.slots[slot_ids[i]] = r
            self.slot_seq[slot_ids[i]] = sid
            r._seq_id = sid

        toks, lens, table = _pad_rows(
            Bp, (toks, 0), (lens, 0),
            (self.view.block_table(seq_ids, self.max_blocks), -1))
        pool_k, pool_v, logits, new_ssm, new_tail = self._prefill_fn(
            self.params, self.model_index, jnp.asarray(toks),
            jnp.asarray(lens), self.pool.k, self.pool.v, jnp.asarray(table))
        self.pool.k, self.pool.v = pool_k, pool_v
        if self.cfg.ssm:
            sl = jnp.asarray(slot_ids)
            self.ssm_state = self.ssm_state.at[:, sl].set(new_ssm[:, :B])
            self.conv_tail = self.conv_tail.at[:, sl].set(
                new_tail[:, :B].astype(self.conv_tail.dtype))
        # sample first token
        nxt = np.asarray(jnp.argmax(logits[:B], axis=-1))
        for i, r in enumerate(admitted):
            if r.max_new_tokens <= 0:
                # degenerate prefill-only request: done at prompt end,
                # no output token to commit (first_token = finish so
                # downstream TTFT math stays finite)
                r.first_token = self.clock()
                self._finish_slot(slot_ids[i], r)
                continue
            # reserve BEFORE committing the token: on quota overcommit
            # (admission point-checks headroom per request) the token
            # is dropped and decode regenerates it at the same
            # position once blocks free up — never a silent desync
            if self.view.append_tokens(seq_ids[i], 1):
                r.output.append(int(nxt[i]))
                r.first_token = self.clock()
                if self.emit is not None:
                    self.emit("token", r, int(nxt[i]))
                if r.done:
                    # max_new_tokens == 1: the prefill-committed token
                    # IS the whole output — finalize here, or a decode
                    # tick would append a second token past max_new
                    # and bill a spurious decode step to the timeline
                    self._finish_slot(slot_ids[i], r)
        return int(lens.sum())

    # ------------------------------------------------------------------
    def _adopt_prefix(self, sid: int, r: Request) -> int:
        """Consult the per-LLM prefix index at admission (DESIGN.md
        §13).  On a hit the cached prefix blocks are adopted read-only
        via ``share_prefix`` and prefill resumes at the first uncached
        block.  Chunked engines only: the chunk machinery natively
        starts at any offset, whereas the whole-prompt path cannot
        resume mid-prompt.  Returns adopted tokens (0 = miss; always a
        BLOCK_TOKENS multiple ≤ len(prompt) − 1, so prefill still
        computes the logits the first generated token needs)."""
        idx = self.view.prefix_index
        if idx is None:
            return 0
        hit, bases = idx.lookup(r.prompt)
        if hit and self.view.share_prefix(sid, bases, hit):
            return hit
        return 0

    def admit_chunked(self, reqs: List[Request]) -> None:
        """Host-side admission for chunked prefill: reserve the prompt,
        bind a slot and mark it in-flight — no compute.  The chunk
        advance itself runs either serially (``run_chunk_job``) or as
        part of a fused group sweep (``FusedGroup.prefill``)."""
        # admission: same cumulative lifetime check as the unchunked
        # path; prompts reserve immediately, so only the not-yet-
        # reserved growth of earlier admits carries into ``pending``
        pending = 0
        for r in reqs[:len(self.free_slots())]:
            if not self.free_slots():
                break
            if not self.can_admit(r, pending):
                continue
            slot = self.free_slots()[0]
            sid = self._next_seq
            self._next_seq += 1
            used_before = self.view.used
            hit = self._adopt_prefix(sid, r)
            ok = self.view.append_tokens(sid, len(r.prompt) - hit)
            if not ok and hit:
                # adoption landed but the private remainder could not
                # be carved out — drop the shared refs and admit the
                # request unshared (the lifetime check covered it)
                self.view.free_seq(sid)
                hit = 0
                ok = self.view.append_tokens(sid, len(r.prompt))
            assert ok
            pending += self.lifetime_blocks(r) - (self.view.used
                                                  - used_before)
            self.slots[slot] = r
            self.slot_seq[slot] = sid
            r._seq_id = sid
            # prefill resumes at the first uncached token — a partial
            # hit leaves prefill_done/first_token stamping untouched
            # (they stamp at prompt completion, whenever that is)
            self._prefilling[slot] = hit

    def export_prefill_job(self) -> Optional[PrefillJob]:
        """Snapshot the in-flight chunk rows the fused prefill sweep
        (or the serial chunk step) needs from this engine.  Returns
        None when nothing is prefilling."""
        if not self._prefilling:
            return None
        C = self.chunk_tokens
        slots = sorted(self._prefilling)
        B = len(slots)
        toks = np.zeros((B, C), np.int32)
        offs = np.zeros((B,), np.int32)
        clens = np.zeros((B,), np.int32)
        for i, sl in enumerate(slots):
            r = self.slots[sl]
            pos = self._prefilling[sl]
            n = min(C, len(r.prompt) - pos)
            toks[i, :n] = r.prompt[pos:pos + n]
            offs[i] = pos
            clens[i] = n
        return PrefillJob(slots=slots, reqs=[self.slots[sl] for sl in slots],
                          seq_ids=[int(self.slot_seq[sl]) for sl in slots],
                          toks=toks, offs=offs, clens=clens)

    def apply_prefill_result(self, job: PrefillJob, nxt: np.ndarray) -> int:
        """Commit one chunk advance back into engine bookkeeping
        (shared by the serial and fused prefill paths).  ``nxt`` is the
        greedy next token per job row (used when a prompt completes)."""
        done_tokens = 0
        for i, sl in enumerate(job.slots):
            r = self.slots[sl]
            self._prefilling[sl] += int(job.clens[i])
            done_tokens += int(job.clens[i])
            if self._prefilling[sl] >= len(r.prompt):
                del self._prefilling[sl]
                # prompt complete → its full blocks are final (decode
                # appends strictly past the prompt): index them now so
                # later requests can adopt — before _finish_slot, so
                # even prefill-only requests populate the cache (the
                # index's own refs keep the blocks alive)
                idx = self.view.prefix_index
                if idx is not None:
                    idx.insert(r.prompt, self.view.seqs[r._seq_id].bases)
                if r.max_new_tokens <= 0:
                    # prefill-only request: finalize at prompt end
                    r.first_token = self.clock()
                    self._finish_slot(sl, r)
                    continue
                # first generated token — same reserve-then-commit as
                # the unchunked path (decode retries on overcommit)
                if self.view.append_tokens(r._seq_id, 1):
                    r.output.append(int(nxt[i]))
                    r.first_token = self.clock()
                    if self.emit is not None:
                        self.emit("token", r, int(nxt[i]))
                    if r.done:
                        # max_new_tokens == 1 completes at prefill
                        self._finish_slot(sl, r)
        return done_tokens

    def run_chunk_job(self, job: PrefillJob) -> int:
        """Advance one exported chunk job serially (attention families):
        one jitted step over a power-of-2 row bucket."""
        B = len(job)
        Bp = _next_pow2(B)
        toks, offs, clens, table = _pad_rows(
            Bp, (job.toks, 0), (job.offs, 0), (job.clens, 0),
            (self.view.block_table(job.seq_ids, self.max_blocks), -1))
        pool_k, pool_v, logits = self._chunk_fn(
            self.params, self.model_index, jnp.asarray(toks),
            jnp.asarray(offs), jnp.asarray(clens), self.pool.k, self.pool.v,
            jnp.asarray(table))
        self.pool.k, self.pool.v = pool_k, pool_v
        nxt = np.asarray(jnp.argmax(logits[:B], axis=-1))
        return self.apply_prefill_result(job, nxt)

    def _prefill_chunked(self, reqs: List[Request]) -> int:
        """Admit new requests, then advance every in-flight prefill by
        one ``chunk_tokens`` window (one jitted step for the batch)."""
        self.admit_chunked(reqs)
        if not self._prefilling:
            return 0
        if self.cfg.ssm:
            return self._run_chunk_ssm()
        return self.run_chunk_job(self.export_prefill_job())

    def _run_chunk_ssm(self) -> int:
        """Chunk advance for pure-SSM engines (state carry, no pool)."""
        job = self.export_prefill_job()
        sl_idx = jnp.asarray(np.array(job.slots))
        st = self.ssm_state[:, sl_idx]
        tail = self.conv_tail[:, sl_idx]
        # fresh sequences start from zero state
        fresh = jnp.asarray((job.offs == 0).astype(np.float32))
        st = st * (1.0 - fresh)[None, :, None, None, None]
        tail = tail * (1.0 - fresh[None, :, None, None]).astype(tail.dtype)
        logits, new_st, new_tail = self._chunk_fn(
            self.params, self.model_index, jnp.asarray(job.toks),
            jnp.asarray(job.clens), st, tail)
        self.ssm_state = self.ssm_state.at[:, sl_idx].set(new_st)
        self.conv_tail = self.conv_tail.at[:, sl_idx].set(
            new_tail.astype(self.conv_tail.dtype))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        return self.apply_prefill_result(job, nxt)

    # ------------------------------------------------------------------
    def export_decode_job(self) -> Optional[DecodeJob]:
        """Snapshot the tensors the fused multi-LLM tick needs from this
        engine: active decode rows (prefilling slots are excluded until
        their prompt completes) plus per-row sequence identity for
        block-table resolution against the pool.  Returns None when the
        engine has no decode work this tick."""
        act = [s for s in self.active_slots() if s not in self._prefilling]
        if not act:
            return None
        reqs = [self.slots[i] for i in act]
        last = np.array([r.output[-1] if r.output else r.prompt[-1]
                         for r in reqs], np.int32)
        return DecodeJob(slots=act, reqs=reqs,
                         seq_ids=[r._seq_id for r in reqs], last_tok=last)

    def apply_decode_result(self, job: DecodeJob, nxt: np.ndarray) -> int:
        """Commit one decode step's sampled tokens back into engine and
        pool bookkeeping (shared by the serial and fused paths).

        Rows that cannot reserve their next-token block are rolled back
        (indices recorded in ``self._rolled_rows`` for the caller to
        revert any non-idempotent per-step state, e.g. SSM carries).
        """
        done_tokens = 0
        self._rolled_rows = []
        for i, r in enumerate(job.reqs):
            r.output.append(int(nxt[i]))
            done_tokens += 1
            if r.done:
                if r.first_token < 0:
                    # prefill's first token rolled back on overcommit
                    # and decode regenerated it — TTFT ends here
                    r.first_token = self.clock()
                if self.emit is not None:
                    self.emit("token", r, int(nxt[i]))
                self._finish_slot(job.slots[i], r)
            else:
                ok = self.view.append_tokens(job.seq_ids[i], 1)
                if ok:
                    if r.first_token < 0:
                        r.first_token = self.clock()
                    # emit only AFTER the reserve validated: a token that
                    # rolls back below was never committed and must not
                    # reach a stream
                    if self.emit is not None:
                        self.emit("token", r, int(nxt[i]))
                if not ok:
                    # quota overcommit (admitted sequences' future
                    # growth is not reserved, and adapt_quotas may
                    # shrink the quota): a silent miss here would
                    # desync lens/pos and corrupt the sequence's KV on
                    # the next step.  Instead roll the token back and
                    # retry next tick — lens is unchanged, so the
                    # retry recomputes the same position (greedy ⇒ the
                    # same token) once another sequence frees blocks.
                    # The KV rewrite is idempotent; decode() reverts
                    # SSM state for rolled-back rows.
                    r.output.pop()
                    done_tokens -= 1
                    self._rolled_rows.append(i)
        # stall escape: if EVERY row rolled back and nothing finished,
        # no sequence can ever free blocks for the others — after two
        # such ticks, preempt the youngest sequence (evict its cache,
        # restart it from scratch via the scheduler queue; greedy ⇒ it
        # regenerates the same tokens) so the rest can proceed.
        rollbacks = len(self._rolled_rows)
        if rollbacks and rollbacks == len(job.reqs):
            self._stall_ticks += 1
            if self._stall_ticks >= 2:
                self._preempt_youngest()
                self._stall_ticks = 0
        else:
            self._stall_ticks = 0
        return done_tokens

    def _preempt_youngest(self) -> None:
        """Evict the most recently admitted sequence: free its cache,
        reset its progress, and hand the request back via
        ``self.preempted`` (the scheduler re-queues it; direct engine
        users resubmit through ``prefill``).  Restart-from-scratch is
        exact for every family — a fresh prefill rebuilds KV and SSM
        state alike."""
        act = [s for s in self.active_slots() if s not in self._prefilling]
        if not act:
            return
        slot = max(act, key=lambda s: self.slot_seq[s])
        r = self.slots[slot]
        self.view.free_seq(int(self.slot_seq[slot]))
        self.slots[slot] = None
        self.slot_seq[slot] = -1
        r.output.clear()
        r.prefill_done = -1.0
        r.first_token = -1.0
        if self.emit is not None:
            self.emit("reset", r, -1)
        self.preempted.append(r)

    def decode(self, job: Optional[DecodeJob] = None) -> int:
        """One decode step over all active slots.  Returns #tokens."""
        job = job or self.export_decode_job()
        if job is None:
            return 0
        B = len(job)
        lens = self.view.seq_lens(job.seq_ids)  # incl. reserved current token
        table = self.view.block_table(job.seq_ids, self.max_blocks)
        last_tok = job.last_tok
        if not self.cfg.ssm:
            # power-of-2 row bucket (padded rows: len 1, table −1 —
            # one masked garbage softmax, discarded below).  SSM/hybrid
            # keep exact rows: their per-slot state scatter must not
            # see duplicated padded slot indices.
            Bp = _next_pow2(B)
            if Bp != B:
                last_tok, lens, table = _pad_rows(
                    Bp, (job.last_tok, 0), (lens, 1), (table, -1))
        sl = jnp.asarray(np.array(job.slots))

        ssm_state = self.ssm_state[:, sl] if self.cfg.ssm else None
        conv_tail = self.conv_tail[:, sl] if self.cfg.ssm else None
        pool_k, pool_v, logits, new_ssm, new_tail = self._decode_fn(
            self.params, self.model_index, jnp.asarray(last_tok),
            jnp.asarray(lens), self.pool.k, self.pool.v, jnp.asarray(table),
            ssm_state, conv_tail)
        self.pool.k, self.pool.v = pool_k, pool_v
        if self.cfg.ssm:
            prev_ssm, prev_tail = self.ssm_state, self.conv_tail
            self.ssm_state = self.ssm_state.at[:, sl].set(new_ssm)
            self.conv_tail = self.conv_tail.at[:, sl].set(new_tail)
        nxt = np.asarray(jnp.argmax(logits[:B], axis=-1))
        toks = self.apply_decode_result(job, nxt)
        if self.cfg.ssm and self._rolled_rows:
            # rolled-back rows must retry from the PRE-step state: the
            # SSM carry is not idempotent (re-advancing it on retry
            # would silently change the eventually-committed token)
            rs = jnp.asarray(np.array([job.slots[i]
                                       for i in self._rolled_rows]))
            self.ssm_state = self.ssm_state.at[:, rs].set(prev_ssm[:, rs])
            self.conv_tail = self.conv_tail.at[:, rs].set(prev_tail[:, rs])
        return toks

    def has_decode_work(self) -> bool:
        return any(s not in self._prefilling for s in self.active_slots())

    def has_prefill_work(self) -> bool:
        return bool(self._prefilling)

    # ------------------------------------------------------------------
    def fusion_signature(self) -> Optional[tuple]:
        """Key under which this engine's decode step can be fused with
        other colocated engines (DESIGN.md §2): engines whose signature
        matches share one stacked-weights jitted step.  ``None`` marks
        the engine fusion-ineligible (SSM/hybrid keep their own scan;
        MoE keeps its own routed FFN) — the scheduler falls back to the
        serial per-engine tick for those.

        The signature pins everything that shapes the stacked param
        tree and the fused computation: layer geometry, head layout,
        projection extras, vocab padding, param dtype, the device
        block-table width and the chunked-prefill window (the fused
        prefill sweep needs one common chunk shape).
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "vlm", "audio") or cfg.ssm \
                or cfg.moe:
            return None
        return (cfg.family, cfg.n_layers, cfg.d_model, cfg.n_heads,
                cfg.n_kv_heads, cfg.hd, cfg.d_ff, cfg.vocab_size,
                cfg.qkv_bias, cfg.qk_norm, cfg.rope_theta, cfg.rms_eps,
                cfg.tie_embeddings, cfg.frontend_dim, cfg.n_prefix_tokens,
                str(self.params["tok"]["embed"].dtype), self.max_blocks,
                self.chunk_tokens)


# ---------------------------------------------------------------------------
# jitted step implementations (XLA reference path)
#
# Every impl takes a STACKED param tree ([M, ...] leading model axis)
# plus a model index; the per-model slice happens at trace time inside
# the program (``_select_model``), so fused-group members and the M=1
# singleton case run off the same buffers with zero weight copies.
# ---------------------------------------------------------------------------
def _prefill_chunk_impl(params, midx, toks, offs, clens, pool_k, pool_v,
                        table, *, cfg: ModelConfig):
    """One chunked-prefill step: process C prompt tokens per sequence at
    absolute positions offs+i, writing KV into the pool and attending
    against everything written so far.  Garbage KV at padded positions
    (i ≥ clens) lands on future decode slots, which decode overwrites
    before attending — harmless by construction."""
    _note_trace("prefill_chunk")
    p = _select_model(params, midx)
    B, C = toks.shape
    x = p["tok"]["embed"][toks]
    positions = offs[:, None] + jnp.arange(C)[None, :]
    lp = p["layers"]

    attn_li = 0
    for li in range(cfg.n_layers):
        h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp, li, cfg, positions)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k, v, table, offs, attn_li, cfg.n_kv_heads)
        o = cache_ops.paged_chunk_attention(
            q, pool_k, pool_v, table, offs, attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, C, -1) @ lp["wo"][li]
        attn_li += 1
        h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
        if cfg.family == "moe":
            out, _ = MoE.moe_ffn_dropless(h, lp, li, cfg)
            x = x + out
        else:
            x = x + mlp(h, lp, li)

    idx = jnp.maximum(clens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, p["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits


def _prefill_chunk_ssm_impl(params, midx, toks, clens, ssm_state, conv_tail,
                            *, cfg: ModelConfig):
    """Chunked prefill for pure-SSM models: the mixer's conv-tail +
    state carry IS the chunk boundary.  ``clens`` masks padded chunk
    positions (dt=0 ⇒ state frozen past the true chunk length)."""
    _note_trace("prefill_chunk_ssm")
    p = _select_model(params, midx)
    B, C = toks.shape
    x = p["tok"]["embed"][toks]
    mask = jnp.arange(C)[None, :] < clens[:, None]
    lp = p["layers"]
    new_ssm = ssm_state
    new_tail = conv_tail
    for li in range(cfg.n_layers):
        h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
        out, st, tail = M2.mamba2_mixer(
            h, lp, li, cfg, conv_tail=conv_tail[li],
            ssm_state=ssm_state[li], return_cache=True, length_mask=mask)
        x = x + out
        new_ssm = new_ssm.at[li].set(st)
        new_tail = new_tail.at[li].set(tail.astype(new_tail.dtype))
    idx = jnp.maximum(clens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, p["tok"], cfg)[..., :cfg.vocab_size]
    return logits, new_ssm, new_tail


def _prefill_impl(params, midx, toks, lens, pool_k, pool_v, table, *,
                  cfg: ModelConfig):
    """Prefill: full causal forward, write KV/state caches, last logits."""
    _note_trace("prefill")
    p = _select_model(params, midx)
    B, S = toks.shape
    x = p["tok"]["embed"][toks]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    lp = p["layers"]

    new_ssm = None
    new_tail = None
    if cfg.ssm:
        sc = cfg.ssm
        conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
        new_ssm = jnp.zeros((cfg.n_layers, B, cfg.n_ssm_heads, sc.head_dim,
                             sc.d_state), jnp.float32)
        new_tail = jnp.zeros((cfg.n_layers, B, sc.conv_kernel - 1, conv_dim),
                             x.dtype)

    def attn_layer(x, li, attn_li, lp_attn, pool_k, pool_v):
        h = rms_norm(x, lp_attn["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h, lp_attn, li, cfg, positions)
        o = causal_attention(q, k, v)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k, v, table, jnp.zeros((B,), jnp.int32),
            attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, S, -1) @ lp_attn["wo"][li]
        return x, pool_k, pool_v

    # NOTE: python loop over layers (engine path is CPU small-model;
    # lowering cost is acceptable and lets attn-layer cache indices be
    # static).
    attn_li = 0
    for li in range(cfg.n_layers):
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            x, pool_k, pool_v = attn_layer(x, li, attn_li, lp, pool_k, pool_v)
            attn_li += 1
            h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
            if cfg.family == "moe":
                out, _ = MoE.moe_ffn_dropless(h, lp, li, cfg)
                x = x + out
            else:
                x = x + mlp(h, lp, li)
        else:  # ssm / hybrid
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, fstate, tail = M2.mamba2_mixer(
                h, lp, li, cfg, return_cache=True,
                length_mask=positions < lens[:, None])
            x = x + out
            new_ssm = new_ssm.at[li].set(fstate)
            new_tail = new_tail.at[li].set(tail.astype(x.dtype))
            if cfg.family == "hybrid" and (li + 1) % cfg.attn_every == 0:
                sa = p["shared_attn"]
                x, pool_k, pool_v = attn_layer(x, 0, attn_li, sa,
                                               pool_k, pool_v)
                attn_li += 1
                h2 = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h2, sa, 0)

    # logits at the true last prompt token
    idx = jnp.maximum(lens - 1, 0)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
    logits = lm_logits(x_last, p["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits, new_ssm, new_tail


def _decode_impl(params, midx, last_tok, lens, pool_k, pool_v, table,
                 ssm_state, conv_tail, *, cfg: ModelConfig):
    """One decode step: write KV of current token, attend, next logits.

    ``lens`` includes the current token (its slot is already reserved);
    its position is lens-1.
    """
    _note_trace("decode")
    p = _select_model(params, midx)
    B = last_tok.shape[0]
    x = p["tok"]["embed"][last_tok]                         # [B,d]
    pos = (lens - 1).astype(jnp.int32)
    lp = p["layers"]

    new_ssm = ssm_state
    new_tail = conv_tail

    def attn_layer(x, li, attn_li, lp_attn, pool_k, pool_v):
        h = rms_norm(x, lp_attn["ln1"][li], cfg.rms_eps)
        q, k, v = attn_qkv(h[:, None, :], lp_attn, li, cfg, pos[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # [B,H,hd]
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k[:, None], v[:, None], table, pos,
            attn_li, cfg.n_kv_heads)
        o = cache_ops.paged_decode_attention(
            q, pool_k, pool_v, table, lens, attn_li, cfg.n_kv_heads)
        x = x + o.reshape(B, -1) @ lp_attn["wo"][li]
        return x, pool_k, pool_v

    attn_li = 0
    for li in range(cfg.n_layers):
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            x, pool_k, pool_v = attn_layer(x, li, attn_li, lp, pool_k, pool_v)
            attn_li += 1
            h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
            if cfg.family == "moe":
                out, _ = MoE.moe_ffn_dropless(h[:, None, :], lp, li, cfg)
                x = x + out[:, 0]
            else:
                x = x + mlp(h, lp, li)
        else:
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, tail_i, st_i = M2.mamba2_decode_step(
                h, lp, li, cfg, conv_tail[li], ssm_state[li])
            x = x + out
            new_ssm = new_ssm.at[li].set(st_i)
            new_tail = new_tail.at[li].set(tail_i)
            if cfg.family == "hybrid" and (li + 1) % cfg.attn_every == 0:
                sa = p["shared_attn"]
                x, pool_k, pool_v = attn_layer(x, 0, attn_li, sa,
                                               pool_k, pool_v)
                attn_li += 1
                h2 = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h2, sa, 0)

    logits = lm_logits(x, p["tok"], cfg)[..., :cfg.vocab_size]
    return pool_k, pool_v, logits, new_ssm, new_tail


def _fused_decode_impl(params, toks, lens, pool_k, pool_v, tables, *,
                       cfg: ModelConfig):
    """Fused multi-LLM decode step (DESIGN.md §2).

    One jitted sweep advances every colocated same-architecture engine
    by one token: model-private matmuls run as batched contractions over
    the stacked weight axis M, while KV writes and paged attention
    flatten all M×R rows into a single pool operation — the per-row
    block tables already resolve each row to its own model's physical
    head-blocks, so the shared arena needs no per-model dispatch.

    params: engine param trees stacked on a leading [M] axis
    toks: [M, R] int32 last tokens (padded rows are masked by the
        caller; their table entries are −1 so their KV writes drop)
    lens: [M, R] lengths incl. the current token (1 on padded rows)
    tables: [M, R, W] int32 group bases (−1 padded)
    Returns (pool_k, pool_v, logits [M, R, vocab]).
    """
    _note_trace("fused_decode")
    M, R = toks.shape
    W = tables.shape[2]
    lp = params["layers"]
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    x = jax.vmap(lambda e, t: e[t])(params["tok"]["embed"], toks)  # [M,R,d]
    pos = (lens - 1).astype(jnp.int32)                             # [M,R]
    flat_table = tables.reshape(M * R, W)
    flat_pos = pos.reshape(M * R)
    flat_lens = lens.reshape(M * R)

    # per-layer semantics (projections, bias, qk_norm, rope, SwiGLU,
    # final logits) come from the SAME helpers the serial path uses,
    # vmapped over the stacked model axis — the fused path cannot
    # drift from models/layers.py
    for li in range(cfg.n_layers):
        def qkv_m(xm, lpm, posm, li=li):
            h = rms_norm(xm, lpm["ln1"][li], cfg.rms_eps)
            q, k, v = attn_qkv(h[:, None, :], lpm, li, cfg, posm[:, None])
            return q[:, 0], k[:, 0], v[:, 0]                  # [R,{H,KV},hd]

        def post_m(xm, om, lpm, li=li):
            xm = xm + om.reshape(om.shape[0], -1) @ lpm["wo"][li]
            h = rms_norm(xm, lpm["ln2"][li], cfg.rms_eps)
            return xm + mlp(h, lpm, li)

        q, k, v = jax.vmap(qkv_m)(x, lp, pos)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k.reshape(M * R, 1, n_kv, hd),
            v.reshape(M * R, 1, n_kv, hd), flat_table, flat_pos, li, n_kv)
        phys = cache_ops.resolve_physical_blocks(flat_table, li, n_kv)
        o = cache_ops.fused_paged_decode_attention(
            q.reshape(M * R, n_h, hd), pool_k, pool_v, phys, flat_lens)
        x = jax.vmap(post_m)(x, o.reshape(M, R, n_h, hd), lp)

    logits = jax.vmap(lambda xm, tokm: lm_logits(xm, tokm, cfg))(
        x, params["tok"])
    return pool_k, pool_v, logits[..., :cfg.vocab_size]


def _fused_prefill_chunk_impl(params, toks, offs, clens, pool_k, pool_v,
                              tables, *, cfg: ModelConfig):
    """Fused multi-LLM chunked-prefill sweep (DESIGN.md §2).

    One jitted step advances every in-flight prompt chunk of every
    colocated same-architecture engine: projections/MLP are batched
    contractions over the stacked model axis M, while KV writes and
    chunk attention flatten all M×R rows over per-row-resolved physical
    block ids — the prefill-phase mirror of ``_fused_decode_impl``.

    params: engine param trees stacked on a leading [M] axis
    toks: [M, R, C] int32 chunk tokens (zero on padded rows)
    offs: [M, R] absolute chunk start positions (0 on padded rows)
    clens: [M, R] true chunk lengths (0 on padded rows)
    tables: [M, R, W] int32 group bases (−1 on padded rows, so their
        KV writes drop; their attention reads are discarded host-side)
    Returns (pool_k, pool_v, logits [M, R, vocab]).
    """
    _note_trace("fused_prefill_chunk")
    M, R, C = toks.shape
    W = tables.shape[2]
    lp = params["layers"]
    n_h, n_kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    x = jax.vmap(lambda e, t: e[t])(params["tok"]["embed"], toks)  # [M,R,C,d]
    positions = offs[..., None] + jnp.arange(C)[None, None, :]     # [M,R,C]
    flat_table = tables.reshape(M * R, W)
    flat_offs = offs.reshape(M * R)

    for li in range(cfg.n_layers):
        def qkv_m(xm, lpm, posm, li=li):
            h = rms_norm(xm, lpm["ln1"][li], cfg.rms_eps)
            return attn_qkv(h, lpm, li, cfg, posm)       # [R,C,{H,KV},hd]

        def post_m(xm, om, lpm, li=li):
            xm = xm + om.reshape(R, C, -1) @ lpm["wo"][li]
            h = rms_norm(xm, lpm["ln2"][li], cfg.rms_eps)
            return xm + mlp(h, lpm, li)

        q, k, v = jax.vmap(qkv_m)(x, lp, positions)
        pool_k, pool_v = cache_ops.write_tokens(
            pool_k, pool_v, k.reshape(M * R, C, n_kv, hd),
            v.reshape(M * R, C, n_kv, hd), flat_table, flat_offs, li, n_kv)
        phys = cache_ops.resolve_physical_blocks(flat_table, li, n_kv)
        o = cache_ops.fused_paged_chunk_attention(
            q.reshape(M * R, C, n_h, hd), pool_k, pool_v, phys, flat_offs)
        x = jax.vmap(post_m)(x, o.reshape(M, R, C, n_h, hd), lp)

    idx = jnp.maximum(clens - 1, 0)                                # [M,R]
    x_last = jnp.take_along_axis(x, idx[..., None, None], axis=2)[:, :, 0]
    logits = jax.vmap(lambda xm, tokm: lm_logits(xm, tokm, cfg))(
        x_last, params["tok"])
    return pool_k, pool_v, logits[..., :cfg.vocab_size]


# ---------------------------------------------------------------------------
# shared jit cache
# ---------------------------------------------------------------------------
# (impl, donated arg positions).  Donated buffers are the pool arena
# (or the SSM carry for the ssm chunk step) — consumed and returned.
_IMPL_TABLE = {
    "prefill": (_prefill_impl, (4, 5)),
    "decode": (_decode_impl, (4, 5)),
    "chunk": (_prefill_chunk_impl, (5, 6)),
    "chunk_ssm": (_prefill_chunk_ssm_impl, (4, 5)),
    "fused_decode": (_fused_decode_impl, (3, 4)),
    "fused_prefill_chunk": (_fused_prefill_chunk_impl, (4, 5)),
}


@lru_cache(maxsize=None)
def jitted_step(kind: str, cfg_key: ModelConfig):
    """Memoized jitted step, shared by every engine with the same
    *geometry* (``Engine.cfg_key`` strips the model name).  Without
    this cache each engine owns a private ``jax.jit`` wrapper and
    colocated instances of one architecture recompile identical
    programs N times."""
    impl, donate = _IMPL_TABLE[kind]
    return jax.jit(partial(impl, cfg=cfg_key), donate_argnums=donate)
