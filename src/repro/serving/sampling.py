"""Token sampling: greedy / temperature / top-k / top-p, pure JAX."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0       # 0 → greedy
    top_k: int = 0                 # 0 → off
    top_p: float = 1.0             # 1 → off


def sample(logits: jnp.ndarray, key, cfg: SamplingConfig) -> jnp.ndarray:
    """logits: [B, V] → tokens [B] int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set whose mass ≥ top_p: keep entries with cum−p < top_p
        keep_mask = cum - probs < cfg.top_p
        thresh = jnp.min(jnp.where(keep_mask, sorted_l, jnp.inf), axis=-1)
        logits = jnp.where(logits < thresh[:, None], -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
