"""Device-side ops on the unified head-wise KV pool (pure jnp).

These are the XLA reference semantics for ``kernels/paged_attention``
and are used directly by the CPU engine and the dry-run lowering.

Physical head-block id for (token-block base b, layer l, kv head h) of a
model with KV kv-heads: ``b + l*KV + h`` (groups are contiguous —
see serving/kvcache.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.paging import (fused_paged_decode_attention,
                          paged_decode_attention,
                          resolve_physical_blocks)

__all__ = ["write_tokens", "resolve_physical_blocks", "copy_block_groups",
           "fused_paged_decode_attention", "paged_decode_attention",
           "fused_paged_chunk_attention", "paged_chunk_attention",
           "windowed_decode_attention", "write_window"]


def copy_block_groups(pool_k, pool_v, src_bases, dst_bases, n_kv, n_layers,
                      src_k=None, src_v=None):
    """Device-side page copy between block groups — one gather/scatter
    over every (layer, kv-head) page of each group.

    Logical group bases are resolved to physical head-block ids through
    ``paging.resolve_physical_blocks`` — the SAME resolution every
    kernel uses, so the copy can never disagree with the pool layout.
    Source and destination index lists are elementwise aligned, making
    this an exact page copy.  Powers copy-on-write divergence of a
    shared prefix block (same-pool: ``src_k/src_v`` default to the
    destination arrays) and cross-pool KV migration (pass the source
    pool's arrays).

    pool_k/pool_v: destination arena [N, BT, hd]
    src_bases/dst_bases: group base per token-block (host lists)
    Returns updated (pool_k, pool_v).
    """
    if src_k is None:
        src_k, src_v = pool_k, pool_v
    st = jnp.asarray(src_bases, jnp.int32)[None, :]
    dt = jnp.asarray(dst_bases, jnp.int32)[None, :]
    sp = jnp.concatenate([resolve_physical_blocks(st, li, n_kv)
                          for li in range(n_layers)], axis=1).reshape(-1)
    dp = jnp.concatenate([resolve_physical_blocks(dt, li, n_kv)
                          for li in range(n_layers)], axis=1).reshape(-1)
    return pool_k.at[dp].set(src_k[sp]), pool_v.at[dp].set(src_v[sp])


def write_tokens(pool_k, pool_v, k_new, v_new, table, start_pos, layer, n_kv):
    """Scatter new KV into the pool.

    pool_k/v: [N, BT, hd]
    k_new/v_new: [B, S, KV, hd] — S new tokens starting at start_pos[b]
    table: [B, max_blocks] int32 group bases (−1 padded)
    start_pos: [B] int32 — position of the first new token
    Returns updated (pool_k, pool_v).
    """
    B, S, KV, hd = k_new.shape
    BT = pool_k.shape[1]
    pos = start_pos[:, None] + jnp.arange(S)[None, :]          # [B,S]
    blk = pos // BT                                            # [B,S]
    off = pos % BT
    base = jnp.take_along_axis(table, blk, axis=1)             # [B,S]
    valid = base >= 0
    phys = (jnp.maximum(base, 0)[:, :, None]
            + layer * n_kv + jnp.arange(KV)[None, None, :])    # [B,S,KV]
    off_b = jnp.broadcast_to(off[:, :, None], phys.shape)
    # invalid slots → OOB index, dropped by scatter mode="drop"
    phys = jnp.where(valid[:, :, None], phys, pool_k.shape[0])
    pool_k = pool_k.at[phys.reshape(-1), off_b.reshape(-1)].set(
        k_new.reshape(-1, hd), mode="drop")
    pool_v = pool_v.at[phys.reshape(-1), off_b.reshape(-1)].set(
        v_new.reshape(-1, hd), mode="drop")
    return pool_k, pool_v


def fused_paged_chunk_attention(q, pool_k, pool_v, phys, q_offset):
    """Multi-sequence chunk attention over pre-resolved physical blocks.

    Prefill-phase mirror of ``fused_paged_decode_attention``: the fused
    multi-LLM prefill sweep (DESIGN.md §2) flattens every in-flight
    prompt chunk of every colocated same-architecture engine into one
    batch; each row's ``phys`` entries already encode (model, layer) →
    physical id, so the chunk attention itself is model-agnostic.

    q: [B, C, H, hd] (post-RoPE, absolute positions q_offset+i; rows
        may belong to different models)
    pool_k/v: [N, BT, hd]
    phys: [B, n_kv, max_blocks] int32 physical head-block ids
    q_offset: [B] int32 absolute position of each row's first query
    Returns [B, C, H, hd].
    """
    B, C, H, hd = q.shape
    BT = pool_k.shape[1]
    n_kv, max_blocks = phys.shape[1], phys.shape[2]
    group = H // n_kv
    scale = 1.0 / math.sqrt(hd)

    k = pool_k[phys].reshape(B, n_kv, max_blocks * BT, hd)
    v = pool_v[phys].reshape(B, n_kv, max_blocks * BT, hd)

    qh = q.reshape(B, C, n_kv, group, hd)
    scores = jnp.einsum("bckgd,bktd->bkgct", qh, k).astype(jnp.float32) \
        * scale
    t_pos = jnp.arange(max_blocks * BT)[None, None, None, None, :]
    q_pos = (q_offset[:, None] + jnp.arange(C))[:, None, None, :, None]
    mask = t_pos <= q_pos
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgct,bktd->bckgd", probs, v)
    return out.reshape(B, C, H, hd)


def paged_chunk_attention(q, pool_k, pool_v, table, q_offset, layer, n_kv):
    """Chunked-prefill attention: a chunk of C query tokens per sequence
    attends causally against the pool (earlier chunks + this chunk's
    already-written KV).  Single-model view: resolves the group-base
    table, then delegates to the fused multi-sequence path so the
    serial and fused prefill sweeps share one set of semantics.

    q: [B, C, H, hd] (post-RoPE, absolute positions q_offset+i)
    pool_k/v: [N, BT, hd]; table: [B, max_blocks]; q_offset: [B]
    Returns [B, C, H, hd].
    """
    phys = resolve_physical_blocks(table, layer, n_kv)       # [B,KV,nb]
    return fused_paged_chunk_attention(q, pool_k, pool_v, phys, q_offset)


def windowed_decode_attention(q, win_k, win_v, seq_lens, window):
    """Decode attention over a ring-buffer sliding-window cache.

    q: [B,H,hd]; win_k/v: [B, KV, W, hd] ring buffers; seq_lens: [B]
    (length including current token).  Slot for position p is p % W.
    """
    B, H, hd = q.shape
    KV, W = win_k.shape[1], win_k.shape[2]
    group = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, group, hd)
    scores = jnp.einsum("bkgd,bkwd->bkgw", qh, win_k).astype(jnp.float32) * scale
    # valid slots: positions in [seq_len - min(seq_len, W), seq_len)
    slot = jnp.arange(W)[None, :]
    cur = seq_lens[:, None]                                    # [B,1]
    # position stored in slot s: the largest p < cur with p % W == s
    p_in_slot = cur - 1 - ((cur - 1 - slot) % W)
    valid = (p_in_slot >= 0) & (p_in_slot >= cur - W)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgw,bkwd->bkgd", probs, win_v).reshape(B, H, hd)


def write_window(win_k, win_v, k_new, v_new, pos):
    """Write one token's KV into the ring buffer at slot pos % W.

    win_k/v: [B,KV,W,hd]; k_new/v_new: [B,KV,hd]; pos: [B]."""
    W = win_k.shape[2]
    slot = pos % W
    b_idx = jnp.arange(win_k.shape[0])
    win_k = win_k.at[b_idx, :, slot].set(k_new)
    win_v = win_v.at[b_idx, :, slot].set(v_new)
    return win_k, win_v
