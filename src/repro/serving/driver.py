"""Closed-loop SLO-attainment serving driver over REAL engines.

This is the layer that lets the runtime be measured the way the paper
measures MuxServe — goodput and SLO attainment under bursty,
popularity-skewed arrivals — instead of raw tokens/s on a hand-rolled
request list.  It closes three loops at once:

  * **workload → runtime**: the same ``core/workload.py`` generator
    that feeds the discrete-event simulator produces the arrival trace
    (Poisson per LLM, power-law rates, ShareGPT-shaped lengths), so
    runtime SLO numbers are directly comparable to simulator
    predictions for the same trace;
  * **placement → runtime**: a ``core/placement.py`` plan (or its JSON
    serialization) instantiates real colocated units —
    ``units_from_placement`` builds one ``MuxScheduler`` per mesh with
    quota split ∝ arrival rate, fused where same-architecture — so the
    optimizer's output actually runs;
  * **runtime → SLO report**: per-request TTFT/TPOT/E2E timelines
    (``Request`` timestamps) roll up into per-LLM and aggregate
    p50/p99, goodput and SLO attainment at configurable scale factors
    (DESIGN.md §9 defines the conventions, shared with the simulator).

Two time domains, one code path:

  * **realtime** — a wall clock rebased to serving start; SLO
    references are calibrated per engine by timing solo probe requests
    (``calibrate_slo_refs``).  This is live serving
    (``launch/serve.py``).
  * **deterministic** — a logical clock the loop itself advances by a
    per-tick cost (``TickCostModel``: base dispatch cost + per-token
    prefill/decode costs).  Engines still run their real jitted
    compute and produce real tokens; only *time* is modeled, so the
    measured scheduling behavior (queueing, convoys, quota pressure)
    is exact and reproducible across machines.  Tests and the CI
    benchmark (``benchmarks/slo_attainment.py``) run this mode.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import BLOCK_TOKENS, replace
from repro.core.placement import Placement
from repro.core.workload import Workload
from repro.models.transformer import init_params
from repro.serving.engine import Engine, Request
from repro.serving.faults import FaultInjector, RecoveryCostModel
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler
from repro.serving.reconfig import ReconfigController, WorkloadMonitor
from repro.serving.sanitize import SessionSanitizer, sanitize_enabled

# same default ladder as core/simulator.simulate — keep in sync, the
# reports are meant to be compared side by side
DEFAULT_SLO_SCALES: Tuple[float, ...] = (2.0, 4.0, 6.0, 8.0, 12.0, 16.0)

# ServeReport.to_json format version (DESIGN.md §14): bump on shape
# changes so downstream tooling can diff runs across PRs
SERVE_REPORT_SCHEMA_VERSION = 2


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------
class WallClock:
    """Wall time rebased to construction, so every ``Request``
    timestamp and trace arrival shares one origin (t=0 = serving
    start)."""

    def __init__(self):
        self.t0 = time.perf_counter()

    def __call__(self) -> float:
        return time.perf_counter() - self.t0


class LogicalClock:
    """Deterministic clock advanced explicitly by the serving loop."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        assert dt >= 0
        self.t += dt


@dataclass(frozen=True)
class TickCostModel:
    """Logical seconds one scheduler tick costs in deterministic mode.

    ``dt = base + prefill_tokens·prefill_tok + decode_tokens·decode_tok``

    ``base`` is the per-tick dispatch cost (paid even by an idle
    policy branch — an fcfs tick that serves nothing is cheap but not
    free), the per-token terms are the compute cost.  The same
    constants define the solo SLO reference, so attainment is
    self-consistent: a request's reference is what IT would take on an
    otherwise idle unit under this very cost model.

    **Share awareness** (DESIGN.md §11).  ``dt`` is the legacy
    *temporal* accounting: every token is charged as if its job held
    the whole mesh, so colocated jobs serialize.  ``tick_dt`` is the
    *spatial-temporal* accounting for units that enforce placement
    compute shares (``MuxScheduler.enforce_shares``): each phase is
    charged ``tokens·per_tok·max(rho/effective_share, 1)/devices`` —
    the same roofline shape as ``core/costmodel.py`` (compute scales
    with the share, HBM bandwidth does not), with ``rho`` the phase's
    compute intensity.  Decode (memory-bound, ``rho_decode`` small) is
    flat in its share until the share dips below ``rho_decode``;
    prefill (compute-bound, ``rho_prefill`` ≈ 1) scales ≈ 1/share —
    paper Fig. 3, re-derived for the logical clock.
    """
    base: float = 4e-3
    prefill_tok: float = 2e-4
    decode_tok: float = 2e-3
    # phase compute intensities: the fraction of the full-share
    # per-token cost that is compute-limited (rest is HBM traffic,
    # which MPS-style share partitioning does not divide)
    rho_prefill: float = 0.9
    rho_decode: float = 0.25
    # no job ever runs below this effective share (MPS floors tiny
    # percentages; also guards the 1/share scaling)
    share_floor: float = 0.05

    def dt(self, prefill_tokens: int, decode_tokens: int,
           devices: int = 1) -> float:
        """``devices`` scales the per-token (compute) cost: a mesh of
        N devices moves tokens N× faster, while the per-tick dispatch
        ``base`` stays fixed.  The solo SLO reference stays at
        ``devices=1`` — the paper's reference is single-DEVICE
        execution latency, independent of where the placement put the
        model — so attainment rewards giving a hot LLM a bigger mesh
        (live reconfiguration's whole point) instead of silently
        re-normalizing it away."""
        return (self.base + (prefill_tokens * self.prefill_tok
                             + decode_tokens * self.decode_tok)
                / max(devices, 1))

    def phase_time(self, tokens: int, per_tok: float, rho: float,
                   share: float, devices: int = 1) -> float:
        """Roofline time of one phase at an effective compute share:
        ``tokens·per_tok·max(rho/share, 1)/devices`` — flat in the
        share while the phase stays memory-bound, 1/share beyond."""
        e = max(share, self.share_floor)
        return tokens * per_tok * max(rho / e, 1.0) / max(devices, 1)

    def tick_dt(self, prefill_by: Dict[str, int],
                decode_by: Dict[str, int], shares: Dict[str, float],
                devices: int = 1) -> float:
        """Share-aware tick cost for a unit that enforces ``sm_frac``
        (the deterministic twin of MPS SM assignment — DESIGN.md §11).

        Decode jobs of the colocated LLMs run *concurrently*, each at
        its planned share (Eq. 3's ``max_m t_d^m``); shares that
        oversubscribe the mesh (Σf > 1) slow every decode job
        proportionally.  Prefill is charged as the better of the two
        dispatches a flexible scheduler can pick:

          * **serial** — prefill takes the whole mesh after the decode
            phase (the simulator's Eq. 3: ``Σ t_p + max t_d``);
          * **spatial** — prefill fills the residual share
            ``1 − Σ_decoding f_m`` concurrently with the decode phase
            (Fig. 4's dispatch), with oversubscription contention when
            the residual is floored.

        A solo full-share engine therefore charges exactly the legacy
        ``dt`` (serial wins), while planned small decode shares let
        prefill overlap — which is where the paper's spatial-temporal
        gain lives.
        """
        def f_of(name: str) -> float:
            return min(max(shares.get(name, 1.0), 0.0), 1.0)

        dec = {n: t for n, t in decode_by.items() if t > 0}
        pre_tokens = sum(prefill_by.values())
        demand = sum(f_of(n) for n in dec)

        def t_decode(over: float) -> float:
            return max((self.phase_time(t, self.decode_tok,
                                        self.rho_decode,
                                        f_of(n) / over, devices)
                        for n, t in dec.items()), default=0.0)

        t_d = t_decode(max(demand, 1.0))
        if not pre_tokens:
            return self.base + t_d
        t_serial = self.phase_time(pre_tokens, self.prefill_tok,
                                   self.rho_prefill, 1.0, devices) + t_d
        resid = max(1.0 - demand, self.share_floor)
        over = max(demand + resid, 1.0)
        t_spatial = max(self.phase_time(pre_tokens, self.prefill_tok,
                                        self.rho_prefill, resid / over,
                                        devices),
                        t_decode(over))
        return self.base + min(t_serial, t_spatial)

    def solo_reference(self, prompt_len: int, output_len: int,
                       chunk_tokens: Optional[int] = None,
                       devices: int = 1) -> float:
        """Ideal single-request E2E on an idle unit: prefill runs as
        one tick (or ceil(prompt/chunk) chunk ticks) and every further
        output token as one decode tick.  The first output token is
        committed by the prefill tick itself and billed in neither
        phase's token count — mirroring exactly how the serving loop
        meters ``MuxStats`` tokens, so the reference is what the
        request would cost under this very clock.

        ``devices`` divides the per-token terms exactly like ``dt``
        does.  The DETERMINISTIC reference convention stays
        ``devices=1`` (the paper's single-device solo latency —
        attainment rewards giving a hot LLM a bigger mesh); the
        analytic wall-clock references used under live reconfiguration
        pass the owning mesh's size instead, because there the
        reference stands in for a solo probe on the engine's CURRENT
        hardware (DESIGN.md §14)."""
        n_prefill_ticks = (1 if not chunk_tokens
                           else -(-prompt_len // chunk_tokens))
        n_decode_ticks = max(output_len - 1, 0)   # first token ∈ prefill
        return ((n_prefill_ticks + n_decode_ticks) * self.base
                + (prompt_len * self.prefill_tok
                   + n_decode_ticks * self.decode_tok) / max(devices, 1))


# ---------------------------------------------------------------------------
# SLO references (DESIGN.md §9)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SLORef:
    """Per-model ideal-latency model: the runtime analogue of the
    simulator's ``_slo_reference_latency`` (single-job, dedicated
    hardware).  A request is SLO-attained at scale s iff
    ``E2E ≤ s × reference(prompt_len, output_len)``."""
    prefill_per_token: float
    decode_per_token: float
    base: float = 0.0

    def reference(self, prompt_len: int, output_len: int) -> float:
        return (self.base + prompt_len * self.prefill_per_token
                + output_len * self.decode_per_token)


def calibrate_slo_refs(engines: Dict[str, Engine], probe_prompt: int = 16,
                       probe_decode: int = 6, seed: int = 1234
                       ) -> Dict[str, SLORef]:
    """Measure each engine's solo per-token costs (realtime mode).

    Runs one warm-up probe (compiles the shape buckets) and one
    measured probe per engine — a single request on the otherwise-idle
    engine, which is exactly the paper's 'single device execution
    latency' reference, profiled instead of cost-modeled.  Probes
    finish and free their cache, so pool state is untouched; the probe
    doubles as jit warm-up for serving.
    """
    rng = np.random.default_rng(seed)
    refs: Dict[str, SLORef] = {}
    for name, eng in engines.items():
        for _attempt in range(2):                 # warm-up, then measure
            req = Request(-1, name,
                          list(rng.integers(1, eng.cfg.vocab_size,
                                            probe_prompt)),
                          probe_decode + 1)
            t0 = time.perf_counter()          # muxlint: ok[clock] solo-speed probe measures real wall time by design
            eng.prefill([req])
            while eng.has_prefill_work():         # chunked engines
                eng.prefill([])
            t_prefill = time.perf_counter() - t0  # muxlint: ok[clock] solo-speed probe measures real wall time by design
            t0 = time.perf_counter()          # muxlint: ok[clock] solo-speed probe measures real wall time by design
            while not req.done and eng.has_decode_work():
                eng.decode()
            t_decode = time.perf_counter() - t0   # muxlint: ok[clock] solo-speed probe measures real wall time by design
            eng.finished.clear()
        refs[name] = SLORef(
            prefill_per_token=t_prefill / probe_prompt,
            decode_per_token=t_decode / max(probe_decode, 1))
    return refs


def tick_cost_refs(engines: Dict[str, Engine], cost: TickCostModel
                   ) -> Callable[[str, int, int], float]:
    """Deterministic-mode reference: analytic solo latency under the
    SAME cost model the clock uses (per-engine chunk window applied)."""
    chunk = {name: eng.chunk_tokens for name, eng in engines.items()}

    def ref(model: str, prompt_len: int, output_len: int) -> float:
        return cost.solo_reference(prompt_len, output_len, chunk[model])
    return ref


# ---------------------------------------------------------------------------
# workload → runtime requests
# ---------------------------------------------------------------------------
def requests_from_workload(wl: Workload, engines: Dict[str, Engine],
                           seed: int = 0, max_new_cap: int = 0
                           ) -> List[Request]:
    """Materialize a ``core/workload.py`` trace as engine requests.

    Length specs are clipped to each engine's sequence envelope
    (``max_blocks × BLOCK_TOKENS`` tokens for prompt + output + the
    reserved next-token slot); ``max_new_cap`` optionally caps output
    lengths (CPU-scale runs).  Token ids are drawn uniformly from the
    target model's vocab — content is irrelevant to scheduling, only
    lengths and arrivals matter — UNLESS the spec carries explicit
    ``prompt_tokens`` (shared-prefix traces): those are mapped into
    the model's vocab with a fixed modular map, which preserves
    cross-request prefix equality, the one content property the
    prefix cache keys on.  The rng is consumed identically either
    way, so a token-carrying trace and its plain twin materialize
    the same lengths and arrivals.
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for rid, spec in enumerate(r for r in wl.requests
                               if r.model in engines):
        eng = engines[spec.model]
        envelope = eng.max_blocks * BLOCK_TOKENS
        out_len = max(1, min(spec.output_len,
                             max_new_cap or spec.output_len,
                             envelope // 2))
        plen = max(1, min(spec.prompt_len, envelope - out_len - 1))
        drawn = rng.integers(1, eng.cfg.vocab_size, plen)
        if spec.prompt_tokens is not None:
            vocab = eng.cfg.vocab_size
            prompt = [int(t) % (vocab - 1) + 1
                      for t in spec.prompt_tokens[:plen]]
            prompt += [int(t) for t in drawn[len(prompt):]]
        else:
            prompt = list(drawn)
        reqs.append(Request(rid, spec.model, prompt, out_len,
                            arrival=spec.arrival))
    return reqs


# ---------------------------------------------------------------------------
# placement → runtime bridge
# ---------------------------------------------------------------------------
def build_unit_from_specs(specs: Sequence[Tuple[str, str, float]],
                          pool_blocks: int = 200_000, max_slots: int = 4,
                          chunk_tokens: int = 0, seed: int = 0,
                          policy: str = "adbs", fused: bool = False,
                          reduced: bool = True,
                          sm_fracs: Optional[Dict[str, float]] = None,
                          max_queue: Optional[int] = None,
                          shed_policy: str = "none",
                          prefix_cache: bool = False
                          ) -> MuxScheduler:
    """Instantiate one real colocated unit from ``(name, arch, rate)``
    triples: one engine per spec over a shared ``UnifiedKVPool``, with
    the initial head-block quota split ∝ arrival rate — the same
    popularity-proportional initial grant the simulator uses
    (``UnitSim.__init__``); ADBS adapts it from there.

    ``sm_fracs`` (name → planned compute share) turns ON share
    enforcement for the unit: the scheduler dispatches decode under
    the shares and the deterministic clock charges phases by effective
    share (``TickCostModel.tick_dt``).  ``None`` keeps the legacy
    temporal accounting — the pure-temporal baseline.

    ``prefix_cache`` arms per-LLM prefix indexes on the unit's pool
    (DESIGN.md §13): repeated prompt prefixes are adopted from cache
    and skip their prefill chunks.  Needs ``chunk_tokens`` — the
    whole-prompt prefill path cannot resume mid-prompt.
    """
    assert specs, "a unit needs at least one (name, arch, rate) spec"
    assert not (prefix_cache and not chunk_tokens),\
        "prefix_cache requires chunked prefill (chunk_tokens > 0)"
    pool = UnifiedKVPool(pool_blocks, 64, dtype=jnp.float32,
                         prefix_cache=prefix_cache)
    rate_sum = sum(max(r, 0.0) for _, _, r in specs)
    min_quota = max(pool_blocks // (8 * len(specs)), 1)
    engines: Dict[str, Engine] = {}
    for i, (name, arch, rate) in enumerate(specs):
        cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
        cfg = replace(cfg, name=name)
        params = init_params(jax.random.PRNGKey(seed + i), cfg, jnp.float32)
        if policy == "fcfs":
            # the temporal baseline has no quotas (paper Fig. 9; the
            # simulator grants fcfs views the full capacity too) — the
            # arena's free-block count is the only admission bound
            quota = pool_blocks
        else:
            # all-zero rates degrade to an equal split
            share = (max(rate, 0.0) / rate_sum) if rate_sum\
                else 1 / len(specs)
            quota = max(int(pool_blocks * share), min_quota)
        view = pool.register_model(cfg, quota)
        engines[name] = Engine(cfg, params, view, max_slots=max_slots,
                               chunk_tokens=chunk_tokens or None)
    return MuxScheduler(engines, pool, policy=policy, fused=fused,
                        sm_frac=sm_fracs, max_queue=max_queue,
                        shed_policy=shed_policy)


def units_from_placement(pl: Placement, pool_blocks: int = 200_000,
                         max_slots: int = 4, chunk_tokens: int = 0,
                         seed: int = 0, policy: str = "adbs",
                         fused: bool = False,
                         enforce_shares: bool = True,
                         max_queue: Optional[int] = None,
                         shed_policy: str = "none",
                         prefix_cache: bool = False
                         ) -> List[MuxScheduler]:
    """The placement → runtime bridge: one real unit per non-empty mesh
    of an optimizer plan (group membership = the mesh's LLM set, fused
    where architectures match), REDUCED model variants so the plan runs
    at CPU scale.  Pool blocks are split across meshes ∝ mesh size —
    the runtime stand-in for per-mesh HBM.

    Each spec's planned ``sm_frac`` is threaded into its unit (the
    runtime previously dropped it on the floor — a hand-edited plan
    file served with shares it never used): the scheduler enforces the
    shares and the deterministic clock charges phases by them
    (DESIGN.md §11).  ``enforce_shares=False`` builds the same units
    with legacy temporal accounting — the pure-temporal baseline arm
    of ``benchmarks/spatial_mux.py``."""
    total_dev = sum(m.n_devices for m in pl.meshes if m.specs) or 1
    units: List[MuxScheduler] = []
    for m in pl.meshes:
        if not m.specs:
            continue
        blocks = max(int(pool_blocks * m.n_devices / total_dev), 4096)
        unit_specs = [(s.name, s.arch_id, s.rate) for s in m.specs]
        sm = {s.name: float(s.sm_frac) for s in m.specs}
        u = build_unit_from_specs(
            unit_specs, pool_blocks=blocks, max_slots=max_slots,
            chunk_tokens=chunk_tokens, seed=seed + m.mesh_id,
            policy=policy, fused=fused,
            sm_fracs=(sm if enforce_shares else None),
            max_queue=max_queue, shed_policy=shed_policy,
            prefix_cache=prefix_cache)
        # mesh identity for the reconfiguration subsystem + mesh size
        # for the deterministic clock's per-unit tick scaling
        u.mesh_id = m.mesh_id
        u.n_devices = m.n_devices
        units.append(u)
    assert units, "placement has no populated mesh"
    return units


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------
@dataclass
class LatencyStats:
    p50: float = float("nan")
    p99: float = float("nan")
    mean: float = float("nan")

    @classmethod
    def of(cls, xs: List[float]) -> "LatencyStats":
        if not xs:
            return cls()
        a = np.asarray(xs, np.float64)
        return cls(float(np.percentile(a, 50)), float(np.percentile(a, 99)),
                   float(a.mean()))

    def to_json(self) -> dict:
        return {"p50": self.p50, "p99": self.p99, "mean": self.mean}


@dataclass
class LLMReport:
    """SLO accounting for one LLM (or the aggregate): latency
    percentiles over finished requests, attainment and goodput per SLO
    scale over ALL submitted requests (an unfinished request is a
    miss at every scale — dropping it would flatter the tail)."""
    name: str
    submitted: int
    finished: int
    throughput: float                        # finished req/s
    ttft: LatencyStats
    tpot: LatencyStats
    e2e: LatencyStats
    attainment: Dict[float, float] = field(default_factory=dict)
    goodput: Dict[float, float] = field(default_factory=dict)
    # degradation dispositions (DESIGN.md §12), visible in EVERY run:
    #   shed      — deliberately dropped (backpressure, deadline,
    #               requeue budget, watchdog); SLO-missed, never silent
    #   retried   — survived ≥1 fault/recovery teardown and requeue
    #   recovered — retried AND still finished
    shed: int = 0
    retried: int = 0
    recovered: int = 0
    shed_reasons: Dict[str, int] = field(default_factory=dict)
    # client abandonments (DESIGN.md §14) — NOT sheds: the client
    # walked away, the server stayed healthy.  Cancelled requests keep
    # counting in the attainment denominator (submitted), preserving
    # submitted = finished + shed + cancelled at drain.
    cancelled: int = 0

    def to_json(self) -> dict:
        return {"name": self.name, "submitted": self.submitted,
                "finished": self.finished, "throughput": self.throughput,
                "ttft": self.ttft.to_json(), "tpot": self.tpot.to_json(),
                "e2e": self.e2e.to_json(),
                "attainment": {str(k): v for k, v in self.attainment.items()},
                "goodput": {str(k): v for k, v in self.goodput.items()},
                "shed": self.shed, "retried": self.retried,
                "recovered": self.recovered,
                "cancelled": self.cancelled,
                "shed_reasons": dict(self.shed_reasons)}


@dataclass
class ReconfigSummary:
    """Reconfiguration-events section of a ``ServeReport``: how often
    the control plane fired, what it moved, and what it cost
    (``serving/reconfig.py``; DESIGN.md §10)."""
    events: int = 0
    moves: int = 0
    migrated_blocks: int = 0
    requeued: int = 0
    quota_moved: int = 0
    share_moved: float = 0.0
    stall_ticks: int = 0
    dt_charged: float = 0.0
    log: List[dict] = field(default_factory=list)

    @classmethod
    def of(cls, events) -> "ReconfigSummary":
        return cls(events=len(events),
                   moves=sum(len(e.moves) for e in events),
                   migrated_blocks=sum(e.migrated_blocks for e in events),
                   requeued=sum(e.requeued for e in events),
                   quota_moved=sum(e.quota_moved for e in events),
                   share_moved=sum(e.share_moved for e in events),
                   stall_ticks=sum(e.stall_ticks for e in events),
                   dt_charged=sum(e.dt_charged for e in events),
                   log=[e.to_json() for e in events])

    def to_json(self) -> dict:
        return {"events": self.events, "moves": self.moves,
                "migrated_blocks": self.migrated_blocks,
                "requeued": self.requeued,
                "quota_moved": self.quota_moved,
                "share_moved": self.share_moved,
                "stall_ticks": self.stall_ticks,
                "dt_charged": self.dt_charged, "log": self.log}


@dataclass
class FaultSummary:
    """Fault-injection/degradation section of a ``ServeReport``
    (serving/faults.py; DESIGN.md §12): what the plan fired, what the
    runtime did to survive it, and what the recoveries cost on the
    deterministic clock."""
    injected: int = 0            # plan events that fired
    unfired: int = 0             # plan events that never fired
    recoveries: int = 0          # engine rebuilds (crash + escalation)
    block_losses: int = 0
    migration_aborts: int = 0
    watchdog_trips: int = 0
    requeued: int = 0            # requests torn down and requeued
    blocks_lost: int = 0         # arena head-blocks lost to block_loss
    dt_charged: float = 0.0      # modeled recovery stall (logical s)
    log: List[dict] = field(default_factory=list)

    def to_json(self) -> dict:
        return {"injected": self.injected, "unfired": self.unfired,
                "recoveries": self.recoveries,
                "block_losses": self.block_losses,
                "migration_aborts": self.migration_aborts,
                "watchdog_trips": self.watchdog_trips,
                "requeued": self.requeued,
                "blocks_lost": self.blocks_lost,
                "dt_charged": self.dt_charged, "log": self.log}


@dataclass
class ServeReport:
    horizon: float                           # clock time at last finish
    wall_s: float                            # real wall time (diagnostic)
    ticks: int
    deterministic: bool
    slo_scales: Tuple[float, ...]
    per_llm: Dict[str, LLMReport]
    aggregate: LLMReport
    # drift visibility (always populated when planned rates are known,
    # reconfig enabled or not): the workload monitor's final per-LLM
    # EWMA arrival-rate estimates next to the planned rates
    planned_rates: Dict[str, float] = field(default_factory=dict)
    rate_estimates: Dict[str, float] = field(default_factory=dict)
    # per-LLM enforced compute shares (empty when no unit enforces
    # sm_frac): the plan's shares as the runtime actually ran them
    sm_frac: Dict[str, float] = field(default_factory=dict)
    reconfig: Optional[ReconfigSummary] = None
    faults: Optional[FaultSummary] = None
    # per-LLM prefix-cache counters (PrefixIndex.stats(); empty when
    # --prefix-cache is off), gathered from the units' CURRENT pool
    # views at report time — crash recovery replaces views, so any
    # engine map captured at start would be stale
    prefix: Dict[str, dict] = field(default_factory=dict)
    # report-format version so downstream tooling can diff runs across
    # PRs: bumped whenever to_json's shape changes.  v2 added
    # schema_version itself, per-LLM `cancelled` and the embedded
    # final metrics snapshot.
    schema_version: int = SERVE_REPORT_SCHEMA_VERSION
    # final ServingMetrics snapshot (serving/metrics.py), embedded when
    # the run was served with a metrics registry; None otherwise
    metrics: Optional[dict] = None

    def summary(self) -> str:
        a = self.aggregate
        att = ", ".join(f"{s:g}×:{a.attainment[s]:.0%}"
                        for s in self.slo_scales)
        lines = [f"aggregate: {a.finished}/{a.submitted} finished in "
                 f"{self.horizon:.2f}s ({'logical' if self.deterministic else 'wall'}) "
                 f"→ {a.throughput:.2f} req/s | SLO[{att}]",
                 f"aggregate: TTFT p50={a.ttft.p50:.3f}s "
                 f"p99={a.ttft.p99:.3f}s | TPOT p50={a.tpot.p50 * 1e3:.1f}ms "
                 f"p99={a.tpot.p99 * 1e3:.1f}ms | E2E p50={a.e2e.p50:.2f}s "
                 f"p99={a.e2e.p99:.2f}s"]
        lines.append(f"aggregate: shed={a.shed} retried={a.retried} "
                     f"recovered={a.recovered}"
                     + (f" cancelled={a.cancelled}" if a.cancelled else "")
                     + (" (shed by: "
                        + ", ".join(f"{k}={v}" for k, v
                                    in sorted(a.shed_reasons.items()))
                        + ")" if a.shed_reasons else ""))
        for name, r in self.per_llm.items():
            att = ", ".join(f"{s:g}×:{r.attainment[s]:.0%}"
                            for s in self.slo_scales)
            lines.append(f"{name}: {r.finished}/{r.submitted} "
                         f"ttft_p99={r.ttft.p99:.3f}s "
                         f"tpot_p99={r.tpot.p99 * 1e3:.1f}ms "
                         f"e2e_p99={r.e2e.p99:.2f}s | SLO[{att}] | "
                         f"shed={r.shed} retried={r.retried} "
                         f"recovered={r.recovered}")
        if self.rate_estimates:
            pairs = ", ".join(
                f"{n}:{self.rate_estimates[n]:.2f}"
                f"(plan {self.planned_rates.get(n, 0.0):.2f})"
                for n in self.rate_estimates)
            lines.append(f"rates est(plan) req/s: {pairs}")
        if self.sm_frac:
            lines.append("compute shares (sm_frac): "
                         + ", ".join(f"{n}:{f:.2f}"
                                     for n, f in self.sm_frac.items()))
        if self.reconfig is not None:
            r = self.reconfig
            lines.append(
                f"reconfig: {r.events} events, {r.moves} moves, "
                f"{r.migrated_blocks} KV head-blocks migrated, "
                f"{r.requeued} prefills requeued, "
                f"Σ|Δsm_frac|={r.share_moved:.2f}, "
                f"{r.stall_ticks} stall ticks "
                f"({r.dt_charged * 1e3:.1f}ms charged)")
        if self.prefix:
            lines.append("prefix cache: " + ", ".join(
                f"{n}: {p['hits']}/{p['lookups']} hits "
                f"({p['hit_rate']:.0%}, {p['hit_tokens']} tok adopted, "
                f"{p['entries']} cached)"
                for n, p in self.prefix.items()))
        if self.faults is not None:
            f = self.faults
            lines.append(
                f"faults: {f.injected} injected ({f.unfired} unfired) → "
                f"{f.recoveries} engine recoveries, "
                f"{f.block_losses} block losses "
                f"({f.blocks_lost} head-blocks), "
                f"{f.migration_aborts} migration aborts, "
                f"{f.watchdog_trips} watchdog trips | "
                f"{f.requeued} requeued "
                f"({f.dt_charged * 1e3:.1f}ms charged)")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"schema_version": self.schema_version,
                "horizon": self.horizon, "wall_s": self.wall_s,
                "ticks": self.ticks, "deterministic": self.deterministic,
                "slo_scales": list(self.slo_scales),
                "aggregate": self.aggregate.to_json(),
                "per_llm": {k: v.to_json() for k, v in self.per_llm.items()},
                "planned_rates": dict(self.planned_rates),
                "rate_estimates": dict(self.rate_estimates),
                "sm_frac": dict(self.sm_frac),
                "reconfig": (self.reconfig.to_json()
                             if self.reconfig else None),
                "faults": (self.faults.to_json()
                           if self.faults else None),
                "prefix": {k: dict(v) for k, v in self.prefix.items()},
                "metrics": self.metrics}


def _roll_up(name: str, reqs: List[Request], horizon: float,
             scales: Sequence[float],
             ref: Callable[[str, int, int], float]) -> LLMReport:
    fin = [r for r in reqs if r.finish >= 0]
    ttfts = [r.first_token - r.arrival for r in fin]
    tpots = [(r.finish - r.first_token) / max(len(r.output) - 1, 1)
             for r in fin]
    e2es = [r.finish - r.arrival for r in fin]
    att: Dict[float, float] = {}
    goodput: Dict[float, float] = {}
    for s in scales:
        ok = sum(1 for r in fin
                 if (r.finish - r.arrival)
                 <= s * ref(r.model, len(r.prompt), r.max_new_tokens))
        att[s] = ok / max(len(reqs), 1)
        goodput[s] = ok / max(horizon, 1e-9)
    shed_reasons: Dict[str, int] = {}
    for r in reqs:
        if r.shed:
            shed_reasons[r.shed_reason] =\
                shed_reasons.get(r.shed_reason, 0) + 1
    retried = [r for r in reqs if r.requeues > 0]
    return LLMReport(name=name, submitted=len(reqs), finished=len(fin),
                     throughput=len(fin) / max(horizon, 1e-9),
                     ttft=LatencyStats.of(ttfts), tpot=LatencyStats.of(tpots),
                     e2e=LatencyStats.of(e2es), attainment=att,
                     goodput=goodput,
                     shed=sum(1 for r in reqs if r.shed),
                     retried=len(retried),
                     recovered=sum(1 for r in retried if r.finish >= 0),
                     cancelled=sum(1 for r in reqs if r.cancelled),
                     shed_reasons=shed_reasons)


# ---------------------------------------------------------------------------
# the serving loop
# ---------------------------------------------------------------------------
def _warmup_drain(units: Sequence[MuxScheduler],
                  owner: Dict[str, MuxScheduler],
                  requests: List[Request], max_ticks: int = 50_000) -> None:
    """Compile the shape buckets live serving will hit BEFORE the wall
    clock starts (DESIGN.md §5 defines the bucket set, §9 why warm-up
    matters for wall-clock SLO numbers).

    Two passes: (1) per engine, one solo drain per (row-bucket ×
    prompt-bucket) combination present in the trace — the serial
    prefill/decode programs a trickle of arrivals will request; (2) a
    flat-out replay of the trace through the schedulers, which
    compiles the fused sweeps (fixed group rows) and exercises the
    multi-engine paths.  Warm-up uses the same engines serving will
    use, so the programs land in the shared ``jitted_step`` cache."""
    rng = np.random.default_rng(0)
    by_model: Dict[str, List[Request]] = {}
    for r in requests:
        by_model.setdefault(r.model, []).append(r)
    for u in units:
        for name, eng in u.engines.items():
            plens = sorted({-(-len(r.prompt) // BLOCK_TOKENS) * BLOCK_TOKENS
                            for r in by_model.get(name, [])})
            if not plens:
                continue
            # SSM decode keeps exact rows (no pow2 bucket) — warm every
            # batch size; attention rows only the pow2 buckets
            rows = (range(1, eng.max_slots + 1) if eng.cfg.ssm else
                    sorted({1 << k for k in range((eng.max_slots - 1)
                                                  .bit_length() + 1)
                            if 1 << k <= eng.max_slots} | {1}))
            for b in rows:
                for plen in plens:
                    probe = [Request(-1, name,
                                     list(rng.integers(
                                         1, eng.cfg.vocab_size, plen)), 2)
                             for _ in range(b)]
                    eng.prefill(probe)
                    while eng.has_prefill_work():
                        eng.prefill([])
                    while eng.has_decode_work():
                        eng.decode()
                    eng.finished.clear()
    warm = [Request(-1 - i, r.model, r.prompt, r.max_new_tokens)
            for i, r in enumerate(requests)]
    for r in warm:
        owner[r.model].submit(r)
    t = 0
    while any(u.pending() for u in units) and t < max_ticks:
        for u in units:
            if u.pending():
                u.tick()
        t += 1
    for u in units:
        u.stats.finished.clear()


class ServeSession:
    """One serving run, decomposed into explicit steps.

    The closed-loop driver (``serve_requests``) and the async front
    end (``serving/frontend.py``) drive the SAME stepper: ``__init__``
    does all setup (ownership map, clock install, SLO references,
    injector threading, deadline stamping, drift monitor), ``step()``
    runs exactly one loop iteration (submit due arrivals → tick busy
    units or account an idle gap → drain fault events → watchdog →
    reconfig/monitor), and ``report()`` rolls the timelines up.
    Because the front end replays the identical iteration, open-loop
    streamed serving is bit-identical to the closed-loop driver under
    the deterministic clock by construction (asserted in
    tests/test_frontend.py).

    Front-end extensions (all default-off, None = closed-loop driver
    semantics unchanged):

    * ``route_fn(request) -> engine_name`` — cross-LLM routing
      (serving/router.py), applied when a request is SUBMITTED (not at
      trace build), so load-aware strategies see the live queue/pool
      state at arrival time.  The request's ``model`` is rewritten to
      the chosen engine.
    * ``metrics`` — a ``ServingMetrics`` bundle (serving/metrics.py);
      the session records the full taxonomy (lifecycle counters,
      latency histograms, queue/pool gauges, reconfig/fault events)
      and embeds the final snapshot in the report.
    * ``on_topology_change()`` — called after a reconfiguration moves
      engines across units, so a router can refresh its view.
    * ``cancel(request)`` — client abandonment: frees the request's
      queue position or slot + KV + prefix refs immediately, counted
      as ``cancelled`` (DESIGN.md §14).

    Wall-clock + reconfig (previously rejected): realtime SLO
    references were calibrated ONCE at startup by solo probes, which
    go stale when a migration moves an engine across meshes — and
    re-probing mid-serving would splice probe compute into live
    batches.  Instead of rejecting the combination, the session now
    computes ANALYTIC references from a ``TickCostModel``
    (``ref_cost``, default constants) with ``devices = the owning
    mesh's size at evaluation time``: after a migration the reference
    follows the engine to its new mesh with no probe traffic.  The
    deterministic path is unchanged (devices=1 solo convention,
    DESIGN.md §9).
    """

    def __init__(self, units: Sequence[MuxScheduler],
                 requests: List[Request],
                 slo_scales: Sequence[float] = DEFAULT_SLO_SCALES,
                 cost: Optional[TickCostModel] = None,
                 refs: Optional[Dict[str, SLORef]] = None,
                 warm: bool = True,
                 max_ticks: int = 500_000,
                 planned_rates: Optional[Dict[str, float]] = None,
                 reconfig: Optional[ReconfigController] = None,
                 faults=None,
                 recovery_cost: Optional[RecoveryCostModel] = None,
                 watchdog_ticks: int = 1000,
                 shed_scale: Optional[float] = None,
                 ref_cost: Optional[TickCostModel] = None,
                 metrics=None,
                 route_fn: Optional[Callable[[Request], str]] = None,
                 on_topology_change: Optional[Callable[[], None]] = None,
                 sanitize: bool = False):
        self.units = list(units)
        self.owner: Dict[str, MuxScheduler] = {}
        self.engines: Dict[str, Engine] = {}
        for u in self.units:
            for name, eng in u.engines.items():
                assert name not in self.owner,\
                    f"duplicate model {name} across units"
                self.owner[name] = u
                self.engines[name] = eng

        self.cost = cost
        self.deterministic = cost is not None
        self.reconfig = reconfig
        self.max_ticks = max_ticks
        self.watchdog_ticks = watchdog_ticks
        self.slo_scales = tuple(slo_scales)
        self.metrics = metrics
        self.route_fn = route_fn
        self.on_topology_change = on_topology_change

        if self.deterministic:
            self.clock: Callable[[], float] = LogicalClock()
            self.ref_fn = tick_cost_refs(self.engines, cost)
        else:
            if warm:
                _warmup_drain(self.units, self.owner, requests)
            if reconfig is not None:
                # analytic wall-clock references (see class docstring):
                # solo latency under ref_cost at the CURRENT owner's
                # mesh size, so references follow migrated engines
                rc = ref_cost if ref_cost is not None else TickCostModel()
                chunk = {n: e.chunk_tokens
                         for n, e in self.engines.items()}
                owner = self.owner          # updated in place on moves

                def ref_fn(model, plen, olen):
                    u = owner.get(model)
                    return rc.solo_reference(
                        plen, olen, chunk.get(model),
                        devices=(u.n_devices if u is not None else 1))
                self.ref_fn = ref_fn
            else:
                slo = (refs if refs is not None
                       else calibrate_slo_refs(self.engines))

                def ref_fn(model, plen, olen, _slo=slo):
                    return _slo[model].reference(plen, olen)
                self.ref_fn = ref_fn
            self.clock = WallClock()
        for u in self.units:
            u.clock = self.clock
            for eng in u.engines.values():
                eng.clock = self.clock

        # fault injection: one injector serves every unit and the
        # migration executor; recovery stalls are priced like any tick
        self.injector: Optional[FaultInjector] = None
        if faults is not None:
            self.injector = (faults if isinstance(faults, FaultInjector)
                             else FaultInjector(faults))
            for u in self.units:
                u.injector = self.injector
            if reconfig is not None:
                reconfig.executor.injector = self.injector
        self.recovery_cost = (recovery_cost if recovery_cost is not None
                              else RecoveryCostModel())

        # deadline stamping for deadline-shedding units: the latest
        # admission instant that still meets the scaled TTFT target at
        # solo speed (ref with output_len 0 IS the solo TTFT reference,
        # in both time domains).  Requests that will only resolve to an
        # engine at submit time (family-routed) are stamped then, with
        # the same formula.
        self._deadline_models = {
            n for u in self.units
            if getattr(u, "shed_policy", "none") == "deadline"
            for n in u.engines}
        s = shed_scale if shed_scale is not None else max(self.slo_scales)
        self._deadline_slack = max(s - 1.0, 0.0)
        if self._deadline_models:
            for r in requests:
                if r.model in self._deadline_models:
                    r.deadline = r.arrival + self._deadline_slack *\
                        self.ref_fn(r.model, len(r.prompt), 0)

        # drift monitor: the controller's when reconfiguring, a
        # standalone one when only planned rates are known (drift stays
        # visible in every report), none otherwise
        self.monitor: Optional[WorkloadMonitor] = None
        if reconfig is not None:
            self.monitor = reconfig.monitor
        elif planned_rates is not None:
            self.monitor = WorkloadMonitor(planned_rates)
        self.planned0 = dict(self.monitor.planned) if self.monitor else {}

        self.requests = sorted(requests, key=lambda r: r.arrival)
        self.idx, self.ticks = 0, 0
        self.fault_log: List[dict] = []
        self.fault_dt = 0.0
        self.watchdog_trips = 0
        self._stall_run, self._last_progress = 0, -1
        self._submitted: set = set()             # id(request)
        self._done = False
        self._report: Optional[ServeReport] = None
        # per-unit indexes into stats.finished / stats.shed, so metrics
        # observation sees each disposition exactly once
        self._fin_idx = [0] * len(self.units)
        self._shed_idx = [0] * len(self.units)
        self._wall0 = time.perf_counter()  # muxlint: ok[clock] report bookkeeping: real elapsed wall seconds, never scheduling

        # runtime invariant sanitizer (serving/sanitize.py, DESIGN.md
        # §15): a pure reader re-validating pool/scheduler/disposition
        # laws after every busy tick.  Armed by the flag or by
        # MUXSERVE_SANITIZE=1 in the environment.
        self.sanitizer = None
        if sanitize or sanitize_enabled():
            self.sanitizer = SessionSanitizer(self)

    # -- one loop iteration ---------------------------------------------
    def step(self) -> Tuple[str, float]:
        """Run ONE serving-loop iteration.  Returns ``(status, wait)``:

        * ``("tick", 0.0)`` — at least one unit was busy and ticked;
        * ``("idle", gap)`` — nothing pending until the next arrival.
          Deterministic mode has already advanced the logical clock
          over the gap (wait = 0); realtime callers should sleep up to
          ``wait`` wall seconds (the driver naps ≤ 5 ms so arrivals
          stay responsive) before stepping again;
        * ``("done", 0.0)`` — trace drained (or ``max_ticks`` hit);
          call ``report()``.
        """
        if self._done or (self.idx >= len(self.requests)
                          and not any(u.pending() for u in self.units)):
            if not self._done and self.sanitizer is not None:
                self.sanitizer.check("drain")
            self._done = True
            return ("done", 0.0)
        now = self.clock()
        while (self.idx < len(self.requests)
               and self.requests[self.idx].arrival <= now):
            self._submit(self.requests[self.idx])
            self.idx += 1
        busy = [u for u in self.units if u.pending()]
        status, wait = "tick", 0.0
        if busy:
            dt = 0.0
            for u in busy:
                p0, d0 = u.stats.prefill_tokens, u.stats.decode_tokens
                u.tick()
                if self.deterministic:
                    if getattr(u, "enforce_shares", False):
                        # spatial-temporal accounting: the tick's phase
                        # meters + the unit's planned shares
                        step = self.cost.tick_dt(u.tick_prefill_by,
                                                 u.tick_decode_by,
                                                 u.sm_frac,
                                                 devices=u.n_devices)
                    else:
                        # legacy temporal accounting (no shares): every
                        # job charged as if it held the whole mesh
                        step = self.cost.dt(u.stats.prefill_tokens - p0,
                                            u.stats.decode_tokens - d0,
                                            devices=u.n_devices)
                    dt = max(dt, step)
            if self.deterministic:
                self.clock.advance(dt)
            self.ticks += 1
            # recovery events recorded by this round's ticks: charge
            # their modeled stall (deterministic mode — realtime pays
            # the real teardown wall time) and fold them into the
            # fault log
            for u in busy:
                for rec in u.fault_events:
                    if self.deterministic:
                        dt_r = self.recovery_cost.dt(
                            rec.get("requeued", 0), rec.get("blocks", 0))
                        self.clock.advance(dt_r)
                        self.fault_dt += dt_r
                        rec["dt_charged"] = dt_r
                    self.fault_log.append(rec)
                    if self.metrics is not None:
                        self._observe_fault(rec)
                u.fault_events.clear()
            # watchdog: zero progress (no tokens moved, nothing
            # finished or shed) across watchdog_ticks consecutive busy
            # ticks means no recovery path is going to unwedge this —
            # shed everything still pending so the run terminates with
            # submitted = finished + shed (+ cancelled), and record
            # the trip
            progress = sum(u.stats.prefill_tokens + u.stats.decode_tokens
                           + len(u.stats.finished) + len(u.stats.shed)
                           for u in self.units)
            if progress == self._last_progress:
                self._stall_run += 1
                if self.watchdog_ticks\
                        and self._stall_run >= self.watchdog_ticks:
                    shed_n = sum(u.shed_all("watchdog")
                                 for u in self.units)
                    self.watchdog_trips += 1
                    self.fault_log.append(
                        {"kind": "watchdog", "t": self.clock(),
                         "shed": shed_n,
                         "stalled_ticks": self._stall_run})
                    if self.metrics is not None:
                        self.metrics.watchdog_trips.inc()
                        self.metrics.fault_events.inc(kind="watchdog")
                    self._stall_run = 0
            else:
                self._stall_run = 0
            self._last_progress = progress
            if self.metrics is not None:
                self._observe_tick(busy)
            if self.sanitizer is not None:
                self.sanitizer.check(f"tick {self.ticks}")
            if self.ticks >= self.max_ticks:
                self._done = True
                return ("tick", 0.0)
        elif self.idx < len(self.requests):
            # idle until the next arrival
            gap = max(self.requests[self.idx].arrival - now, 0.0)
            if self.deterministic:
                self.clock.advance(gap)
                status, wait = "idle", 0.0
            else:
                status, wait = "idle", gap
        if self.reconfig is not None:
            ev = self.reconfig.step(self.clock())
            if ev is not None:
                if self.deterministic:
                    # the migration's modeled stall hits every queued
                    # and in-flight request, like any other tick cost
                    self.clock.advance(ev.dt_charged)
                if self.metrics is not None:
                    self._observe_reconfig(ev)
                if ev.moves:
                    self.owner.update(self.reconfig.owner_map())
                    if self.on_topology_change is not None:
                        self.on_topology_change()
        elif self.monitor is not None:
            self.monitor.advance(self.clock())
        return (status, wait)

    # -- submission / cancellation ---------------------------------------
    def _submit(self, r: Request) -> None:
        if r.cancelled:
            # cancelled before its arrival: never enters a unit, still
            # counted (submitted = finished + shed + cancelled)
            return
        if self.route_fn is not None:
            target = self.route_fn(r)
            if target != r.model:
                r.model = target
            if (r.model in self._deadline_models
                    and r.deadline == float("inf")):
                r.deadline = r.arrival + self._deadline_slack *\
                    self.ref_fn(r.model, len(r.prompt), 0)
        self.owner[r.model].submit(r)
        self._submitted.add(id(r))
        if self.monitor is not None:
            self.monitor.observe(r.model, len(r.prompt) + r.max_new_tokens)
        if self.metrics is not None:
            self.metrics.requests_submitted.inc(llm=r.model)
            self.metrics.log.emit(self.clock(), "submit", r.req_id,
                                  llm=r.model, prompt_len=len(r.prompt),
                                  max_new=r.max_new_tokens)

    def cancel(self, req: Request) -> bool:
        """Client abandonment: free the request's resources NOW (queue
        position, or slot + KV blocks + prefix refs via the owning
        unit's ``cancel``).  A request cancelled before its arrival is
        simply never submitted.  Returns True iff the disposition
        changed to ``cancelled``."""
        if req.finish >= 0 or req.shed or req.cancelled:
            return False
        if id(req) in self._submitted:
            u = self.owner.get(req.model)
            ok = bool(u is not None and u.cancel(req))
        else:
            req.cancelled = True
            ok = True
        if ok and self.metrics is not None:
            self.metrics.requests_cancelled.inc(llm=req.model)
            self.metrics.log.emit(self.clock(), "cancel", req.req_id,
                                  llm=req.model)
        return ok

    # -- metrics observation (pure readers; never mutate serving state) --
    def _observe_tick(self, busy: List[MuxScheduler]) -> None:
        m = self.metrics
        now = self.clock()
        for u in busy:
            for name, t in u.tick_prefill_by.items():
                m.tokens_total.inc(t, llm=name, phase="prefill")
            for name, t in u.tick_decode_by.items():
                m.tokens_total.inc(t, llm=name, phase="decode")
        for ui, u in enumerate(self.units):
            fin = u.stats.finished
            for r in fin[self._fin_idx[ui]:]:
                m.requests_finished.inc(llm=r.model)
                m.ttft_seconds.observe(r.first_token - r.arrival,
                                       llm=r.model)
                m.tpot_seconds.observe(
                    (r.finish - r.first_token)
                    / max(len(r.output) - 1, 1), llm=r.model)
                m.e2e_seconds.observe(r.finish - r.arrival, llm=r.model)
                m.log.emit(now, "finish", r.req_id, llm=r.model,
                           tokens=len(r.output),
                           ttft=r.first_token - r.arrival,
                           e2e=r.finish - r.arrival)
            self._fin_idx[ui] = len(fin)
            shed = u.stats.shed
            for r in shed[self._shed_idx[ui]:]:
                m.requests_shed.inc(llm=r.model, reason=r.shed_reason)
                m.log.emit(now, "shed", r.req_id, llm=r.model,
                           reason=r.shed_reason)
            self._shed_idx[ui] = len(shed)
            for name, eng in u.engines.items():
                m.queue_depth.set(len(u.queues[name]), llm=name)
                m.running_seqs.set(len(eng.active_slots()), llm=name)
                m.pool_used_blocks.set(eng.view.used, llm=name)
            m.pool_available_blocks.set(u.pool.available_blocks(),
                                        unit=f"mesh{u.mesh_id}")
        if now > 1e-9:
            for name in self.owner:
                m.llm_qps.set(
                    m.requests_submitted.value(llm=name) / now, llm=name)

    def _observe_fault(self, rec: dict) -> None:
        m = self.metrics
        m.fault_events.inc(kind=rec.get("kind", "unknown"))
        if rec.get("kind") == "engine_crash":
            m.recoveries.inc(llm=rec.get("target") or "")
        if rec.get("requeued"):
            m.requests_retried.inc(rec["requeued"],
                                   llm=rec.get("target") or "pool")
        m.log.emit(self.clock(), "fault", "-",
                   kind=rec.get("kind"), target=rec.get("target"),
                   requeued=rec.get("requeued", 0))

    def _observe_reconfig(self, ev) -> None:
        m = self.metrics
        m.reconfig_events.inc(kind="event")
        if ev.moves:
            m.reconfig_events.inc(len(ev.moves), kind="move")
        if ev.migrated_blocks:
            m.migrated_blocks.inc(ev.migrated_blocks)
        m.log.emit(self.clock(), "reconfig", "-", moves=len(ev.moves),
                   migrated_blocks=ev.migrated_blocks,
                   requeued=ev.requeued)

    # -- roll-up ----------------------------------------------------------
    def report(self) -> ServeReport:
        if self._report is not None:
            return self._report
        wall_s = time.perf_counter() - self._wall0  # muxlint: ok[clock] report bookkeeping: real elapsed wall seconds, never scheduling
        if self.monitor is not None:
            self.monitor.advance(self.clock())  # close trailing windows

        horizon = max([self.clock()]
                      + [r.finish for r in self.requests if r.finish >= 0])
        by_model: Dict[str, List[Request]] = {n: [] for n in self.engines}
        for r in self.requests:
            # family-named requests cancelled before routing keep their
            # family name — give them their own row rather than losing
            # them from the per-LLM accounting
            by_model.setdefault(r.model, []).append(r)
        per_llm = {n: _roll_up(n, rs, horizon, self.slo_scales, self.ref_fn)
                   for n, rs in by_model.items()}
        agg = _roll_up("aggregate", self.requests, horizon,
                       self.slo_scales, self.ref_fn)
        shares: Dict[str, float] = {}
        prefix_stats: Dict[str, dict] = {}
        for u in self.units:
            if getattr(u, "enforce_shares", False):
                shares.update({n: u.sm_frac.get(n, 1.0)
                               for n in u.engines})
            prefix_stats.update(u.prefix_stats())
        injector, fault_log = self.injector, self.fault_log
        fsum: Optional[FaultSummary] = None
        if injector is not None or fault_log:
            aborts = 0
            if injector is not None:
                aborts = sum(1 for rec in injector.records
                             if rec.get("kind") == "migration_abort")
            fsum = FaultSummary(
                injected=(len(injector.records) if injector else 0),
                unfired=(len(injector.unfired()) if injector else 0),
                recoveries=sum(1 for rec in fault_log
                               if rec["kind"] == "engine_crash"),
                block_losses=sum(1 for rec in fault_log
                                 if rec["kind"] == "block_loss"),
                migration_aborts=aborts,
                watchdog_trips=self.watchdog_trips,
                requeued=sum(rec.get("requeued", 0) for rec in fault_log),
                blocks_lost=sum(rec.get("blocks", 0) for rec in fault_log
                                if rec["kind"] == "block_loss"),
                dt_charged=self.fault_dt,
                log=fault_log)
        self._report = ServeReport(
            horizon=horizon, wall_s=wall_s, ticks=self.ticks,
            deterministic=self.deterministic, slo_scales=self.slo_scales,
            per_llm=per_llm, aggregate=agg,
            planned_rates=self.planned0,
            rate_estimates=(dict(self.monitor.rate_ewma)
                            if self.monitor else {}),
            sm_frac=shares,
            reconfig=(ReconfigSummary.of(self.reconfig.events)
                      if self.reconfig is not None else None),
            faults=fsum, prefix=prefix_stats,
            metrics=(self.metrics.snapshot()
                     if self.metrics is not None else None))
        return self._report


def serve_requests(units: Sequence[MuxScheduler], requests: List[Request],
                   slo_scales: Sequence[float] = DEFAULT_SLO_SCALES,
                   cost: Optional[TickCostModel] = None,
                   refs: Optional[Dict[str, SLORef]] = None,
                   warm: bool = True,
                   max_ticks: int = 500_000,
                   planned_rates: Optional[Dict[str, float]] = None,
                   reconfig: Optional[ReconfigController] = None,
                   faults=None,
                   recovery_cost: Optional[RecoveryCostModel] = None,
                   watchdog_ticks: int = 1000,
                   shed_scale: Optional[float] = None,
                   ref_cost: Optional[TickCostModel] = None,
                   metrics=None,
                   sanitize: bool = False
                   ) -> ServeReport:
    """Drive real units through an arrival-ordered request list and
    roll the ``Request`` timelines up into a ``ServeReport`` — the
    closed-loop driver, now a thin synchronous wrapper over
    ``ServeSession`` (the async front end drives the same stepper).

    ``cost`` set → deterministic mode: a ``LogicalClock`` advances by
    the max per-unit tick cost each iteration (units are parallel
    hardware; the slowest unit's tick bounds the round) and SLO
    references are analytic under the same constants.  ``cost`` unset
    → realtime: wall clock, per-engine calibrated references (``refs``
    overrides calibration), and — unless ``warm=False`` — a warm-up
    replay of the trace so jit compilation lands outside the measured
    window (steady-state serving, not cold start).

    ``planned_rates`` (per-LLM req/s, e.g. a plan's or trace's rates)
    enables the drift monitor: the report then carries final EWMA
    arrival-rate estimates next to the plan, whether or not
    reconfiguration is on.  ``reconfig`` plugs in a live
    ``ReconfigController`` (serving/reconfig.py): the loop reports
    arrivals, calls ``step`` each iteration, charges executed events'
    modeled stall to the logical clock (deterministic mode) and
    refreshes request routing after engine moves.  Wall-clock +
    reconfig is supported: SLO references are then computed
    analytically from ``ref_cost`` (default ``TickCostModel()``) at
    the owning mesh's CURRENT size — they follow migrated engines
    instead of going stale like startup solo probes would (``refs``
    is ignored in that combination; see ``ServeSession``).

    Graceful degradation (DESIGN.md §12).  ``faults`` (a ``FaultPlan``
    or ``FaultInjector``) arms fault injection: the injector is
    threaded onto every unit (polled at each tick) and onto the
    reconfig executor (asked before each page copy).  Units record
    their recovery events in ``MuxScheduler.fault_events``; the loop
    drains them each iteration and — in deterministic mode — charges
    ``recovery_cost.dt(requeued, blocks)`` to the logical clock, the
    fault-handling twin of reconfig's ``dt_charged``.  When a unit
    runs ``shed_policy="deadline"``, every request it owns is stamped
    with its admission deadline ``arrival + (s − 1)·ttft_ref`` (s =
    ``shed_scale``, default ``max(slo_scales)``; ``ttft_ref`` = the
    solo TTFT reference, i.e. ``ref(model, prompt_len, 0)``): past
    that instant even immediate solo-speed prefill misses the s-scaled
    TTFT target, so carrying the request could only add misses.  The
    watchdog converts a would-be infinite stall (``watchdog_ticks``
    consecutive busy ticks with zero progress — no tokens, finishes or
    sheds) into a recorded degradation event: every queued and
    in-flight request is shed, so the loop terminates with
    ``submitted = finished + shed + cancelled`` instead of hanging.
    ``watchdog_ticks=0`` disables it.

    ``metrics`` (a ``ServingMetrics``) arms the observability layer:
    lifecycle counters, latency histograms, queue/pool gauges and
    reconfig/fault event counters are recorded live and the final
    snapshot is embedded in the report (``ServeReport.metrics``).

    CAVEAT (realtime + multiple units): units are ticked sequentially
    on one host thread under ONE wall clock, so each mesh's latencies
    absorb the other meshes' compute — realtime numbers understate a
    multi-mesh placement.  Use deterministic mode to compare
    placements with different mesh counts; it models units as
    parallel.
    """
    session = ServeSession(
        units, requests, slo_scales=slo_scales, cost=cost, refs=refs,
        warm=warm, max_ticks=max_ticks, planned_rates=planned_rates,
        reconfig=reconfig, faults=faults, recovery_cost=recovery_cost,
        watchdog_ticks=watchdog_ticks, shed_scale=shed_scale,
        ref_cost=ref_cost, metrics=metrics, sanitize=sanitize)
    while True:
        status, wait = session.step()
        if status == "done":
            break
        if status == "idle" and not session.deterministic:
            time.sleep(min(wait, 0.005))
    return session.report()


def serve_workload(units: Sequence[MuxScheduler], wl: Workload,
                   seed: int = 0, max_new_cap: int = 0,
                   slo_scales: Sequence[float] = DEFAULT_SLO_SCALES,
                   cost: Optional[TickCostModel] = None,
                   refs: Optional[Dict[str, SLORef]] = None,
                   max_ticks: int = 500_000,
                   reconfig: Optional[ReconfigController] = None,
                   faults=None,
                   recovery_cost: Optional[RecoveryCostModel] = None,
                   watchdog_ticks: int = 1000,
                   shed_scale: Optional[float] = None,
                   ref_cost: Optional[TickCostModel] = None,
                   metrics=None,
                   sanitize: bool = False
                   ) -> ServeReport:
    """``serve_requests`` over a ``core/workload.py`` trace (the shared
    simulator/runtime arrival process).  The trace's per-LLM rates
    feed the drift monitor as the planned baseline."""
    engines: Dict[str, Engine] = {}
    for u in units:
        engines.update(u.engines)
    reqs = requests_from_workload(wl, engines, seed=seed,
                                  max_new_cap=max_new_cap)
    return serve_requests(units, reqs, slo_scales=slo_scales, cost=cost,
                          refs=refs, max_ticks=max_ticks,
                          planned_rates=dict(wl.rates), reconfig=reconfig,
                          faults=faults, recovery_cost=recovery_cost,
                          watchdog_ticks=watchdog_ticks,
                          shed_scale=shed_scale, ref_cost=ref_cost,
                          metrics=metrics, sanitize=sanitize)
