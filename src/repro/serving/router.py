"""Cross-LLM request routing for the live serving front end.

Engines hosted by :class:`~repro.serving.mux.MuxScheduler` units are
addressed by exact name (``"llm-a@0"``).  A live client usually doesn't
know — or care — which replica serves it: it names a *model family*
(``"llm-a"``) and the router picks an engine.  By convention a replica
name is ``<family>@<k>``; a name without ``@`` is its own family, so
single-replica deployments route transparently.

Strategies (strategy pattern, one ``choose`` method each):

- :class:`ExplicitTarget` — requests must name an exact engine; family
  names only resolve when the family has exactly one replica.
- :class:`RoundRobin` — static per-family rotation, ignores load.  The
  baseline the benchmark gate measures against.
- :class:`WeightedByRate` — deterministic smooth weighted round-robin
  (nginx's algorithm) over planned per-engine rates, so the long-run
  split matches the placement optimizer's traffic plan.
- :class:`LeastLoaded` — picks the replica with the lowest instantaneous
  load score: admission-queue depth + resident sequences + KV pool
  pressure (used/quota).  Name-order tie-break keeps it deterministic.

The router's view (engine → unit, family → replicas) is rebuilt by
:meth:`Router.refresh` — the serving session calls it after every
reconfiguration event so routing follows migrated engines, and after
crash recovery so a recovered engine is immediately routable again.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.serving.metrics import ServingMetrics
    from repro.serving.mux import MuxScheduler

__all__ = [
    "family_of",
    "RoutingStrategy",
    "ExplicitTarget",
    "RoundRobin",
    "WeightedByRate",
    "LeastLoaded",
    "Router",
    "make_strategy",
    "ROUTER_STRATEGIES",
]


def family_of(name: str) -> str:
    """``"llm-a@1"`` → ``"llm-a"``; a name without ``@`` is its own family."""
    return name.split("@", 1)[0]


class RoutingStrategy:
    """Picks one engine name out of a family's replica set."""

    name = "base"

    def choose(self, family: str, candidates: List[str], router: "Router") -> str:
        raise NotImplementedError


class ExplicitTarget(RoutingStrategy):
    """Clients must address engines directly; no replica fan-out."""

    name = "explicit"

    def choose(self, family: str, candidates: List[str], router: "Router") -> str:
        if len(candidates) == 1:
            return candidates[0]
        raise KeyError(
            f"explicit routing: '{family}' names {len(candidates)} replicas "
            f"({candidates}); address one directly"
        )


class RoundRobin(RoutingStrategy):
    """Static rotation per family, blind to load — the routing baseline."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next: Dict[str, int] = {}

    def choose(self, family: str, candidates: List[str], router: "Router") -> str:
        i = self._next.get(family, 0) % len(candidates)
        self._next[family] = i + 1
        return candidates[i]


class WeightedByRate(RoutingStrategy):
    """Smooth weighted round-robin over planned per-engine rates.

    Each pick adds every candidate's weight to its running current-weight,
    selects the max, then subtracts the weight total from the winner —
    nginx's interleaving variant, deterministic and starvation-free.
    Unknown engines weigh 1.0 so a fresh replica still receives traffic.
    """

    name = "weighted"

    def __init__(self, planned_rates: Optional[Dict[str, float]] = None):
        self.planned_rates = dict(planned_rates or {})
        self._current: Dict[str, float] = {}

    def weight(self, name: str) -> float:
        w = self.planned_rates.get(name)
        if w is None:
            w = self.planned_rates.get(family_of(name))
        return max(float(w), 1e-9) if w is not None else 1.0

    def choose(self, family: str, candidates: List[str], router: "Router") -> str:
        total = 0.0
        best: Optional[str] = None
        for name in candidates:
            w = self.weight(name)
            total += w
            self._current[name] = self._current.get(name, 0.0) + w
            if best is None or self._current[name] > self._current[best]:
                best = name
        assert best is not None
        self._current[best] -= total
        return best


class LeastLoaded(RoutingStrategy):
    """Route to the replica with the lowest instantaneous load score."""

    name = "least_loaded"

    def choose(self, family: str, candidates: List[str], router: "Router") -> str:
        return min(candidates, key=lambda n: (router.load_score(n), n))


class Router:
    """Maps request model names to engines across one or more units."""

    def __init__(
        self,
        units: Sequence["MuxScheduler"],
        strategy: Optional[RoutingStrategy] = None,
        metrics: Optional["ServingMetrics"] = None,
    ):
        self.units = list(units)
        self.strategy = strategy if strategy is not None else RoundRobin()
        self.metrics = metrics
        self.engine_unit: Dict[str, "MuxScheduler"] = {}
        self.families: Dict[str, List[str]] = {}
        self.refresh()

    def refresh(self) -> None:
        """Rebuild the engine→unit and family→replicas view.

        Cheap (a dict walk over hosted engines); called after reconfig
        moves and crash recoveries so routing follows the live topology.
        """
        engine_unit: Dict[str, "MuxScheduler"] = {}
        families: Dict[str, List[str]] = {}
        for u in self.units:
            for name in u.engines:
                if name in engine_unit:
                    raise ValueError(f"engine '{name}' hosted by two units")
                engine_unit[name] = u
                families.setdefault(family_of(name), []).append(name)
        for reps in families.values():
            reps.sort()
        self.engine_unit = engine_unit
        self.families = families

    # -- load inspection (used by LeastLoaded, exposed for metrics) --------

    def queue_depth(self, name: str) -> int:
        u = self.engine_unit[name]
        return len(u.queues[name])

    def load_score(self, name: str) -> float:
        """Queue depth + resident sequences + KV pool pressure.

        Queue/slot occupancy dominates; pool pressure (0..1) breaks ties
        between equally-queued replicas toward the one with KV headroom.
        """
        u = self.engine_unit[name]
        eng = u.engines[name]
        depth = len(u.queues[name])
        resident = len(eng.active_slots())
        view = eng.view
        pressure = view.used / max(float(view.quota), 1.0)
        return depth + resident + pressure

    # -- resolution --------------------------------------------------------

    def resolve(self, model: str) -> str:
        """Return the engine name that should serve ``model``.

        Exact engine names short-circuit (explicit target always wins);
        family names go through the strategy.  Unknown names raise
        ``KeyError`` so the front end can reject before submit.
        """
        if model in self.engine_unit:
            chosen = model
        else:
            candidates = self.families.get(model)
            if not candidates:
                raise KeyError(f"no engine or family named '{model}'")
            chosen = self.strategy.choose(model, list(candidates), self)
        if self.metrics is not None:
            self.metrics.router_decisions.inc(
                strategy=self.strategy.name, llm=chosen
            )
        return chosen

    def unit_for(self, name: str) -> "MuxScheduler":
        return self.engine_unit[name]


ROUTER_STRATEGIES = ("explicit", "round_robin", "weighted", "least_loaded")


def make_strategy(
    name: str, planned_rates: Optional[Dict[str, float]] = None
) -> RoutingStrategy:
    """CLI-facing factory: strategy name → instance."""
    if name == "explicit":
        return ExplicitTarget()
    if name == "round_robin":
        return RoundRobin()
    if name == "weighted":
        return WeightedByRate(planned_rates)
    if name == "least_loaded":
        return LeastLoaded()
    raise ValueError(f"unknown router strategy '{name}' (choose from {ROUTER_STRATEGIES})")
