"""Live reconfiguration — drift detection, online re-placement, and
zero-downtime engine/KV migration (DESIGN.md §10).

MuxServe's core premise is that LLM popularity *varies* (paper §2.1,
Fig. 6), yet a placement solved once at startup freezes the spatial
layout: a popularity flip mid-trace strands quota, pool blocks and
mesh capacity on yesterday's hot model.  This module is the control
plane that closes the loop at runtime:

  * **WorkloadMonitor** — EWMA per-LLM arrival/token-rate estimates
    over fixed windows of the serving clock, with a hysteresis
    trigger: re-plan only when estimated rates diverge from the
    planned rates by more than a configurable ratio for ``sustain``
    consecutive windows (one bursty window must not thrash the
    placement).
  * **Online re-planner** — re-runs the placement optimizer's greedy
    assignment (``core/placement.place_onto_meshes`` — Alg. 1's inner
    loop over the FIXED physical meshes) on the live estimates, then
    diffs old vs new plans into a minimal migration schedule: engine
    moves between meshes, fused-group membership changes (implied by
    the moves), and per-unit quota rebalances + compute-share
    (``sm_frac``) re-assignments — the latter two execute even when
    the move schedule is empty (a share-only re-plan is a real
    reconfiguration, applied in place by the executor).
  * **MigrationExecutor** — executes the schedule without dropping a
    single request: in-flight decodes *carry* their KV (logical
    blocks exported, pages copied into the destination pool, block
    tables remapped through ``paging.resolve_physical_blocks`` — see
    ``kvcache.migrate_view``), prefill-phase requests are evicted and
    requeued (restart is exact under greedy decoding), queued
    requests simply change queues.  Fused groups dissolve and rebuild
    through ``MuxScheduler.remove_engine`` / ``add_engine`` (the
    zero-copy ``adopt_stacked`` path), and the dissolved group's pool
    grant is returned via ``UnifiedKVPool.shrink`` before the new
    group re-grows it.

Time never enters this module on its own: the serving loop pushes its
clock into ``ReconfigController.step(now)``, so under the
deterministic ``LogicalClock`` the whole control plane — window
boundaries, triggers, migration costs (``MigrationCostModel``) — is
bit-reproducible, and ``benchmarks/reconfig_shift.py`` can gate CI on
*attainment orderings* (live reconfig must beat a frozen placement
after a regime shift) instead of wall-clock noise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import A100, Hardware
from repro.core.placement import Placement, place_onto_meshes
from repro.serving.kvcache import migrate_view
from repro.serving.mux import MuxScheduler


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------
class WorkloadMonitor:
    """EWMA per-LLM arrival/token-rate estimator with hysteresis.

    Observation is push-based: the serving loop reports every arrival
    (``observe``) and closes windows against its own clock
    (``advance(now)``) — the monitor never reads time itself, so
    deterministic runs stay bit-reproducible.  Each closed
    ``interval``-second window folds the windowed rates into EWMAs:

        r̂ ← (1−α)·r̂ + α·(count / interval)

    Drift for one LLM is ``max(r̂/plan, plan/r̂)`` (symmetric — a model
    going cold strands resources exactly like a model going hot
    starves), smoothed by ``eps`` — an additive req/s floor on both
    sides of the ratio, so sparse-Poisson noise around near-zero
    rates (a 0.5 req/s LLM sees mostly empty windows) cannot arm the
    trigger; only drifts that matter at the ``eps`` scale register.
    The trigger arms only after ``sustain`` consecutive windows whose
    max drift exceeds ``threshold``; ``rebase`` adopts a new plan's
    rates as the baseline and disarms.
    """

    def __init__(self, planned_rates: Dict[str, float],
                 interval: float = 1.0, alpha: float = 0.5,
                 threshold: float = 2.0, sustain: int = 2,
                 eps: float = 1.0):
        assert interval > 0 and 0 < alpha <= 1 and threshold >= 1
        self.planned = dict(planned_rates)
        self.interval = float(interval)
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.sustain = int(sustain)
        self.eps = float(eps)
        # EWMAs start AT the plan: an undisturbed workload shows zero
        # drift from the first window instead of a cold-start spike
        self.rate_ewma: Dict[str, float] = dict(planned_rates)
        self.token_ewma: Dict[str, float] = {m: 0.0 for m in planned_rates}
        self._counts: Dict[str, int] = {m: 0 for m in planned_rates}
        self._tokens: Dict[str, int] = {m: 0 for m in planned_rates}
        self._window_end = self.interval
        self._above = 0
        self.windows_closed = 0

    def observe(self, model: str, tokens: int = 0) -> None:
        """Record one arrival (and its lifetime token count) in the
        current window."""
        if model not in self._counts:
            self._counts[model] = 0
            self._tokens[model] = 0
            self.rate_ewma.setdefault(model, 0.0)
            self.token_ewma.setdefault(model, 0.0)
            self.planned.setdefault(model, 0.0)
        self._counts[model] += 1
        self._tokens[model] += int(tokens)

    def advance(self, now: float) -> int:
        """Close every window that ends at or before ``now``; returns
        the number closed (0 = still inside the current window).

        A window with NO arrivals at all is closed but FROZEN — no
        EWMA fold, no trigger evaluation.  Totally-idle windows mean a
        trace gap or the end-of-trace drain, and letting every EWMA
        decay toward zero there would arm the trigger and fire a
        migration with no future arrivals to benefit, stalling exactly
        the in-flight tail the subsystem protects.  A single LLM going
        cold while others still arrive DOES decay — that is real
        drift.
        """
        closed = 0
        while now >= self._window_end:
            if any(self._counts.values()):
                a = self.alpha
                for m in self._counts:
                    self.rate_ewma[m] = (
                        (1 - a) * self.rate_ewma[m]
                        + a * self._counts[m] / self.interval)
                    self.token_ewma[m] = (
                        (1 - a) * self.token_ewma[m]
                        + a * self._tokens[m] / self.interval)
                    self._counts[m] = 0
                    self._tokens[m] = 0
                self._above = (self._above + 1
                               if self.max_drift() >= self.threshold
                               else 0)
            self._window_end += self.interval
            self.windows_closed += 1
            closed += 1
        return closed

    def drift(self, model: str) -> float:
        est = self.rate_ewma.get(model, 0.0) + self.eps
        plan = self.planned.get(model, 0.0) + self.eps
        return max(est / plan, plan / est)

    def max_drift(self) -> float:
        return max((self.drift(m) for m in self.rate_ewma), default=1.0)

    def triggered(self) -> bool:
        return self._above >= self.sustain

    def rebase(self, planned_rates: Dict[str, float]) -> None:
        """Adopt new planned rates as the drift baseline and disarm
        the trigger (called after a reconfiguration lands)."""
        self.planned.update(planned_rates)
        self._above = 0


# ---------------------------------------------------------------------------
# migration cost (deterministic clock)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MigrationCostModel:
    """Logical seconds one reconfiguration charges in deterministic
    mode — the modeled stall of a real migration, priced like
    ``TickCostModel`` prices a tick:

        dt = base + migrated_head_blocks · per_block

    ``base`` is the control-plane cost (re-plan, group rebuild, table
    swap), ``per_block`` the page-copy cost.  Requeued prefills charge
    nothing here — their cost reappears naturally as recomputation
    ticks.  Realtime runs skip this model: the copy's wall time is
    real and already on the clock.
    """
    base: float = 20e-3
    per_block: float = 5e-6

    def dt(self, migrated_blocks: int) -> float:
        return self.base + migrated_blocks * self.per_block


# ---------------------------------------------------------------------------
# plan diffing
# ---------------------------------------------------------------------------
def assignment_of(pl: Placement) -> Dict[str, int]:
    """LLM name → mesh_id of its unit."""
    return {s.name: m.mesh_id for m in pl.meshes for s in m.specs}


def _return_spec(pl: Placement, name: str, mesh_id: int) -> None:
    """Move ``name``'s spec back onto ``mesh_id`` inside ``pl`` (a
    skipped migration must keep the stored plan matching reality)."""
    spec = None
    for m in pl.meshes:
        for s in list(m.specs):
            if s.name == name:
                m.specs.remove(s)
                spec = s
    for m in pl.meshes:
        if m.mesh_id == mesh_id and spec is not None:
            m.specs.append(spec)


def shares_of(pl: Placement) -> Dict[str, float]:
    """LLM name → planned compute share (sm_frac)."""
    return {s.name: float(s.sm_frac) for m in pl.meshes for s in m.specs}


def diff_placements(old: Placement, new: Placement
                    ) -> List[Tuple[str, int, int]]:
    """Minimal migration schedule between two plans over the same
    meshes: one ``(name, src_mesh, dst_mesh)`` move per LLM whose
    assignment changed.  A re-plan that changes only quotas and/or
    ``sm_frac`` diffs to an EMPTY move schedule — that is not a no-op:
    the executor's ``execute`` pass always rebalances every unit's
    quotas (∝ the new rates) and applies the new compute shares
    (``apply_shares``), and the controller records a ``ReconfigEvent``
    whenever either actually changed, so share-only re-plans execute
    instead of being silently dropped.  Fused-group membership changes
    stay implied by the moves."""
    a0, a1 = assignment_of(old), assignment_of(new)
    return [(n, a0[n], a1[n])
            for n in a0 if n in a1 and a1[n] != a0[n]]


# ---------------------------------------------------------------------------
# reconfiguration events (report section)
# ---------------------------------------------------------------------------
@dataclass
class ReconfigEvent:
    """One executed reconfiguration, as recorded in ``ServeReport``."""
    t: float                               # clock time of execution
    drift: float                           # max drift that triggered it
    moves: List[Tuple[str, int, int]]      # (llm, src_mesh, dst_mesh)
    migrated_blocks: int                   # KV head-blocks copied
    requeued: int                          # prefill-phase restarts
    quota_moved: int                       # |Δquota| summed over views
    shrunk_blocks: int                     # pool blocks returned by
                                           # dissolved groups' grants
    dt_charged: float                      # modeled stall (logical s)
    stall_ticks: int                       # dt in base-tick units
    share_moved: float = 0.0               # Σ|Δsm_frac| applied
    rate_estimates: Dict[str, float] = field(default_factory=dict)
    token_estimates: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"t": self.t, "drift": self.drift,
                "moves": [list(m) for m in self.moves],
                "migrated_blocks": self.migrated_blocks,
                "requeued": self.requeued,
                "quota_moved": self.quota_moved,
                "share_moved": self.share_moved,
                "shrunk_blocks": self.shrunk_blocks,
                "dt_charged": self.dt_charged,
                "stall_ticks": self.stall_ticks,
                "rate_estimates": dict(self.rate_estimates),
                "token_estimates": dict(self.token_estimates)}


# ---------------------------------------------------------------------------
# migration execution
# ---------------------------------------------------------------------------
class MigrationExecutor:
    """Executes a migration schedule against live units without
    dropping requests (drain-or-carry per request):

      * **decode-phase** sequences carry their KV — pages are copied
        into the destination pool and the engine continues
        bit-identically (``kvcache.migrate_view``);
      * **prefill-phase** requests are evicted and requeued at the
        destination (``Engine.evict_prefilling``; restart is exact
        under greedy decoding, and half-written prompts are cheaper
        to recompute than to move);
      * **queued** requests change queues with their engine.

    Fused-group membership changes ride on ``remove_engine`` /
    ``add_engine`` (dissolve → ``pool.shrink`` the old zero-copy
    grant → re-stack → ``pool.grow`` the new one).
    """

    def __init__(self, units: Dict[int, MuxScheduler]):
        self.units = units
        # fault injection (serving/faults.py): when the serving driver
        # threads an injector through, every scheduled move asks it for
        # a due ``migration_abort`` before the page copy
        self.injector = None

    def execute(self, moves: Sequence[Tuple[str, int, int]],
                new_pl: Placement, now: float = 0.0) -> Dict[str, object]:
        """Apply the schedule.  A move whose destination pool cannot
        hold the live KV (too few free blocks, or no contiguous run
        under fragmentation) is SKIPPED, never half-applied: the
        capacity pre-check runs before the engine detaches, and a
        fragmentation abort inside ``migrate_view`` leaves the source
        intact so the engine is re-homed where it was.  Skipped moves
        are reflected back into ``new_pl`` (the spec returns to its
        source mesh), so the stored plan keeps matching reality and a
        later window can retry once space frees."""
        migrated = requeued = shrunk = 0
        new_shares = shares_of(new_pl)
        executed: List[Tuple[str, int, int]] = []
        skipped: List[Tuple[str, int, int]] = []
        for name, src_id, dst_id in moves:
            src, dst = self.units[src_id], self.units[dst_id]
            eng = src.engines[name]
            # physical need counts DISTINCT block groups — a prefix
            # block shared by several sequences migrates as one copy
            # (migrate_view keeps the sharing structure), so summing
            # per-seq tables would over-count and skip feasible moves
            uniq = {b for sc in eng.view.seqs.values() for b in sc.bases}
            need = len(uniq) * eng.view.group_size
            # available_blocks, not free_blocks: the destination's
            # prefix-cache inventory is evictable on demand and must
            # not veto a move (migrate_view reclaims it as needed)
            if need > dst.pool.available_blocks():
                skipped.append((name, src_id, dst_id))
                _return_spec(new_pl, name, src_id)
                continue
            blocks_before = src.pool.n_head_blocks
            eng, queued = src.remove_engine(name)
            shrunk += max(blocks_before - src.pool.n_head_blocks, 0)
            evicted = eng.evict_prefilling()
            carried = list(evicted) + list(queued)
            if self.injector is not None \
                    and self.injector.take_migration_abort(now):
                # injected mid-copy abort: the destination holds
                # nothing yet and the source view is untouched, so the
                # same re-home path a fragmentation abort takes leaves
                # every request accounted for (prefill evictions are
                # requeued with the carried queue)
                for r in evicted:
                    r.requeues += 1
                src.add_engine(name, eng, carried)
                skipped.append((name, src_id, dst_id))
                _return_spec(new_pl, name, src_id)
                continue
            try:
                # quota starts at live usage; the rebalance pass below
                # sets the popularity-proportional target
                view, blocks = migrate_view(eng.view, dst.pool,
                                            quota=eng.view.used)
            except RuntimeError:
                # fragmentation abort: source view untouched — re-home
                # the engine (and its carried queue) where it was
                src.add_engine(name, eng, carried)
                skipped.append((name, src_id, dst_id))
                _return_spec(new_pl, name, src_id)
                continue
            eng.rebind_view(view)
            # the share travels with the engine; apply_shares below
            # overwrites it with the new plan's candidate
            dst.add_engine(name, eng, carried,
                           sm_frac=new_shares.get(name, 1.0))
            executed.append((name, src_id, dst_id))
            migrated += blocks
            requeued += len(evicted)
        quota_moved = self.rebalance_quotas(new_pl)
        share_moved = self.apply_shares(new_pl)
        return {"migrated_blocks": migrated, "requeued": requeued,
                "quota_moved": quota_moved, "share_moved": share_moved,
                "shrunk_blocks": shrunk,
                "executed": executed, "skipped": skipped}

    def rebalance_quotas(self, pl: Placement) -> int:
        """Re-split every unit's head-block quota ∝ the new plan's
        arrival rates (the same popularity-proportional grant
        ``build_unit_from_specs`` makes at startup), clamped so no
        view drops below its live usage.  fcfs units keep their
        full-capacity quota (they have none to split).  Returns the
        total |Δquota| applied."""
        moved = 0
        for m in pl.meshes:
            unit = self.units.get(m.mesh_id)
            if unit is None or not m.specs or unit.policy == "fcfs":
                continue
            specs = [s for s in m.specs if s.name in unit.engines]
            if not specs:
                continue
            rate_sum = sum(max(s.rate, 0.0) for s in specs)
            n_blocks = unit.pool.n_head_blocks
            min_quota = max(n_blocks // (8 * len(specs)), 1)
            for s in specs:
                share = (max(s.rate, 0.0) / rate_sum) if rate_sum \
                    else 1 / len(specs)
                view = unit.engines[s.name].view
                target = max(int(n_blocks * share), min_quota, view.used)
                moved += abs(target - view.quota)
                view.quota = target
        return moved

    def apply_shares(self, pl: Placement) -> float:
        """Apply the new plan's per-LLM compute shares (``sm_frac``) to
        every share-enforcing unit.  The share is scheduler state — no
        engine or KV moves — so a re-plan that changes ONLY shares
        executes right here; before this pass existed, such re-plans
        diffed to an empty move schedule and the 'implied' rebalance
        silently never happened.  Units built without enforcement
        (legacy temporal accounting) are left untouched: flipping their
        charging model mid-run would split one serving run across two
        cost semantics.  Returns Σ|Δsm_frac| applied."""
        moved = 0.0
        for m in pl.meshes:
            unit = self.units.get(m.mesh_id)
            if unit is None or not getattr(unit, "enforce_shares", False):
                continue
            for s in m.specs:
                if s.name not in unit.engines:
                    continue
                old = unit.sm_frac.get(s.name, 1.0)
                if abs(float(s.sm_frac) - old) > 1e-12:
                    moved += abs(float(s.sm_frac) - old)
                    unit.sm_frac[s.name] = float(s.sm_frac)
        return moved


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------
class ReconfigController:
    """Monitor → trigger → re-plan → diff → migrate, driven by the
    serving loop (``serving/driver.serve_requests(reconfig=...)``).

    The loop reports arrivals (``observe_arrival``) and calls
    ``step(now)`` once per iteration; everything else — window
    bookkeeping, hysteresis, cooldown, plan diffing, migration — is
    internal.  ``step`` returns the executed ``ReconfigEvent`` (or
    None); in deterministic mode the driver charges the event's
    ``dt_charged`` to the logical clock, so reconfiguration stalls
    show up in every downstream latency like any other cost.
    """

    def __init__(self, placement: Placement,
                 units: Sequence[MuxScheduler],
                 interval: float = 1.0, drift_threshold: float = 2.0,
                 sustain: int = 2, ewma_alpha: float = 0.5,
                 cooldown: Optional[float] = None,
                 hw: Hardware = A100,
                 migration_cost: Optional[MigrationCostModel] = None,
                 tick_base: float = 4e-3):
        self.placement = placement
        self.units: Dict[int, MuxScheduler] = {}
        for i, u in enumerate(units):
            mid = u.mesh_id if u.mesh_id >= 0 else i
            u.mesh_id = mid
            assert mid not in self.units, "duplicate mesh_id across units"
            self.units[mid] = u
        planned = {s.name: s.rate for m in placement.meshes
                   for s in m.specs}
        self.monitor = WorkloadMonitor(planned, interval=interval,
                                       alpha=ewma_alpha,
                                       threshold=drift_threshold,
                                       sustain=sustain)
        self.executor = MigrationExecutor(self.units)
        self.migration_cost = (migration_cost if migration_cost
                               is not None else MigrationCostModel())
        self.cooldown = (2 * interval) if cooldown is None else cooldown
        self.hw = hw
        self.tick_base = tick_base
        self.events: List[ReconfigEvent] = []
        self._last_t = -math.inf

    def replan(self, rates: Dict[str, float]) -> Placement:
        """Re-run the placement optimizer's greedy assignment on the
        live rate estimates, over the FIXED physical meshes (mesh
        re-partitioning would mean cross-node weight reloads — the
        online move set is LLM↔mesh assignment, sm_frac/tp and
        quotas)."""
        specs = [s for m in self.placement.meshes for s in m.specs]
        assert specs, "cannot replan an empty placement"
        models = [(s.cfg, max(rates.get(s.name, s.rate), 1e-6))
                  for s in specs]
        archs = {s.name: s.arch_id for s in specs}
        mesh_sizes = [(m.mesh_id, m.n_devices)
                      for m in self.placement.meshes]
        return place_onto_meshes(models, mesh_sizes, hw=self.hw,
                                 mean_prompt=specs[0].mean_prompt,
                                 mean_output=specs[0].mean_output,
                                 archs=archs)

    def step(self, now: float) -> Optional[ReconfigEvent]:
        """Advance monitor windows to ``now``; when the hysteresis
        trigger is armed (and the cooldown has passed), re-plan on the
        EWMA estimates, diff, migrate, and return the event."""
        if not self.monitor.advance(now):
            return None
        if not self.monitor.triggered():
            return None
        if now - self._last_t < self.cooldown:
            return None
        drift = self.monitor.max_drift()
        est = dict(self.monitor.rate_ewma)
        try:
            new_pl = self.replan(est)
        except AssertionError:
            # the greedy assignment found no feasible layout for the
            # estimates (online replanning has no group backtracking)
            # — keep the current placement this window; the cooldown
            # stamp below stops a hot retry loop
            self._last_t = now
            return None
        moves = diff_placements(self.placement, new_pl)
        stats = self.executor.execute(moves, new_pl, now=now)
        self.placement = new_pl
        self.monitor.rebase(est)
        self._last_t = now
        if not stats["executed"] and stats["quota_moved"] == 0 \
                and stats["share_moved"] < 1e-9:
            # the live estimates re-derive the current layout (or every
            # move was skipped for lack of destination space) — the
            # rebase above absorbs the drift, nothing executed.  A
            # share-only or quota-only rebalance (empty move schedule)
            # IS an execution and records an event below.
            return None
        dt = self.migration_cost.dt(stats["migrated_blocks"])
        ev = ReconfigEvent(
            t=now, drift=drift, moves=list(stats["executed"]),
            migrated_blocks=stats["migrated_blocks"],
            requeued=stats["requeued"],
            quota_moved=stats["quota_moved"],
            share_moved=stats["share_moved"],
            shrunk_blocks=stats["shrunk_blocks"],
            dt_charged=dt,
            stall_ticks=int(math.ceil(dt / max(self.tick_base, 1e-9))),
            rate_estimates=est,
            token_estimates=dict(self.monitor.token_ewma))
        self.events.append(ev)
        return ev

    def owner_map(self) -> Dict[str, MuxScheduler]:
        """Current LLM → unit routing (changes after moves; the driver
        refreshes its submit table from this after every event)."""
        return {name: u for u in self.units.values()
                for name in u.engines}
