"""Live serving front end: async ingestion + per-request token streams.

``ServingFrontend`` wraps a :class:`~repro.serving.driver.ServeSession`
in an asyncio loop.  Clients hold a :class:`TokenStream` per request and
consume tokens as the engines commit them; the frontend drives the SAME
session stepper as the closed-loop driver (``serve_requests``), so under
the deterministic clock the streamed token sequences are bit-identical
to the driver's ``Request.output`` timelines *by construction* — there
is one scheduling loop, not a reimplementation (asserted in
tests/test_frontend.py).

Token events originate at the engines' COMMIT points (the emit hook
installed via ``MuxScheduler.set_emit``): a token is pushed only after
its KV reservation validated, so a rolled-back overcommit never reaches
a stream.  Preemption/eviction pushes a ``reset`` event — previously
streamed tokens for that request are void and ``collect`` drops them,
mirroring the engine clearing the request's progress.  Backpressure is
surfaced, not hidden: a request shed by a bounded admission queue (or
deadline/watchdog policy) terminates its stream with :class:`StreamShed`
carrying the shed reason, and client cancellation terminates it with
:class:`StreamCancelled` after the session frees the request's slot, KV
blocks and prefix refs.

Cross-LLM routing (serving/router.py) plugs in as the session's
``route_fn``: family-named requests resolve to an engine at SUBMIT time,
so load-aware strategies see live queue/pool state, and the router's
view refreshes after every reconfiguration move.

Everything here is stdlib asyncio — no server framework.  The metrics
HTTP endpoint lives in serving/metrics.py.
"""

from __future__ import annotations

import asyncio
from typing import Dict, List, Optional, Sequence, Union

from repro.serving.driver import ServeSession
from repro.serving.engine import Request
from repro.serving.metrics import ServingMetrics
from repro.serving.mux import MuxScheduler
from repro.serving.router import Router, RoutingStrategy

__all__ = [
    "StreamError",
    "StreamShed",
    "StreamCancelled",
    "TokenStream",
    "ServingFrontend",
    "serve_and_collect",
]


class StreamError(RuntimeError):
    """A token stream terminated without finishing."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class StreamShed(StreamError):
    """The request was shed (backpressure / deadline / watchdog) — the
    bounded-queue drop surfaces to the client instead of hanging."""


class StreamCancelled(StreamError):
    """The client cancelled the request; resources were freed."""


# terminal stream events and the exception each raises from ``collect``
_TERMINAL = {"shed": StreamShed, "cancelled": StreamCancelled,
             "error": StreamError}


class TokenStream:
    """Per-request async stream of committed tokens.

    ``events()`` iterates raw ``(kind, payload)`` pairs — kinds are
    ``token`` (payload = token id), ``reset`` (drop accumulated tokens),
    and the terminals ``finish`` / ``shed`` (payload = reason) /
    ``cancelled``.  ``collect()`` folds that protocol for the common
    client: accumulate tokens, restart on reset, return the final token
    list on finish, raise :class:`StreamShed` / :class:`StreamCancelled`
    on the error terminals.  Async-iterating the stream yields tokens
    and raises the same errors (resets clear nothing visible mid-flight,
    so iteration is only lossless for requests that are never evicted —
    use ``collect`` when preemption is possible).
    """

    def __init__(self, req: Request):
        self.req = req
        self._q: asyncio.Queue = asyncio.Queue()
        self._closed = False

    def _push(self, kind: str, payload) -> None:
        if self._closed:
            return          # late duplicate terminal (e.g. cancel race)
        if kind in _TERMINAL or kind == "finish":
            self._closed = True
        self._q.put_nowait((kind, payload))

    async def events(self):
        """Yield raw ``(kind, payload)`` events through the terminal."""
        while True:
            kind, payload = await self._q.get()
            yield kind, payload
            if kind == "finish" or kind in _TERMINAL:
                return

    async def collect(self) -> List[int]:
        """Consume the stream to its terminal; return the token list."""
        toks: List[int] = []
        async for kind, payload in self.events():
            if kind == "token":
                toks.append(payload)
            elif kind == "reset":
                toks.clear()
            elif kind == "finish":
                return toks
            else:
                raise _TERMINAL[kind](str(payload))
        raise StreamError("stream closed without terminal event")

    def __aiter__(self):
        return self._tokens()

    async def _tokens(self):
        async for kind, payload in self.events():
            if kind == "token":
                yield payload
            elif kind in _TERMINAL:
                raise _TERMINAL[kind](str(payload))


class ServingFrontend:
    """Async serving loop over a ``ServeSession`` with token streaming.

    ``strategy`` (a :class:`~repro.serving.router.RoutingStrategy` or a
    name from ``ROUTER_STRATEGIES``) arms cross-LLM routing: requests
    may then name a model *family* and the router picks the replica at
    submit time.  Without it, requests must name exact engines — the
    closed-loop driver's convention.

    The frontend owns the emit hook on every unit: engine/scheduler
    commit points fan out to the registered per-request streams.
    Requests without a registered stream serve normally (streaming is
    opt-in per request).  All session keyword arguments pass through,
    so open-loop streamed serving supports the full feature surface —
    deterministic or wall clock, reconfig, faults, shedding, metrics.
    """

    def __init__(self, units: Sequence[MuxScheduler],
                 requests: List[Request],
                 strategy: Optional[Union[str, RoutingStrategy]] = None,
                 metrics: Optional[ServingMetrics] = None,
                 planned_rates: Optional[Dict[str, float]] = None,
                 **session_kwargs):
        self.metrics = metrics
        self.router: Optional[Router] = None
        route_fn = None
        on_topology_change = None
        if strategy is not None:
            if isinstance(strategy, str):
                from repro.serving.router import make_strategy
                strategy = make_strategy(strategy, planned_rates)
            self.router = Router(units, strategy=strategy, metrics=metrics)
            route_fn = lambda r: self.router.resolve(r.model)
            on_topology_change = self.router.refresh
        self.session = ServeSession(
            units, requests, metrics=metrics, route_fn=route_fn,
            planned_rates=planned_rates,
            on_topology_change=on_topology_change, **session_kwargs)
        self._streams: Dict[int, TokenStream] = {}
        for u in units:
            u.set_emit(self._on_emit)

    # -- streaming ------------------------------------------------------
    def stream(self, req: Request) -> TokenStream:
        """Register (or fetch) the token stream for ``req``."""
        s = self._streams.get(id(req))
        if s is None:
            s = self._streams[id(req)] = TokenStream(req)
        return s

    def _on_emit(self, kind: str, req: Request, tok: int) -> None:
        s = self._streams.get(id(req))
        if kind == "shed" and self.metrics is not None:
            self.metrics.stream_errors.inc(
                reason=req.shed_reason or "shed")
        if s is None:
            return
        if kind == "token":
            s._push("token", tok)
        elif kind == "shed":
            s._push("shed", req.shed_reason or "shed")
        else:                       # finish / reset / cancelled
            s._push(kind, None)

    def cancel(self, req: Request) -> bool:
        """Client abandonment: free the request's resources now and
        terminate its stream.  Safe between ``step`` calls (i.e. from
        any task on the serving loop's thread)."""
        ok = self.session.cancel(req)
        if ok:
            s = self._streams.get(id(req))
            if s is not None:
                # pre-submit cancels never reach a unit, so no emit
                # fired; _push drops the duplicate otherwise
                s._push("cancelled", None)
        return ok

    # -- the serving loop ----------------------------------------------
    async def serve(self):
        """Drive the session to completion, yielding to stream
        consumers after every tick.  Returns the ``ServeReport``."""
        session = self.session
        while True:
            status, wait = session.step()
            if status == "done":
                break
            if status == "idle" and not session.deterministic:
                # nap until the next arrival (≤ 5 ms so ad-hoc
                # cancellations stay responsive), like the driver
                await asyncio.sleep(min(wait, 0.005))
            else:
                # cooperative yield: consumers drain the tokens this
                # tick committed before the next tick runs
                await asyncio.sleep(0)
        # terminate any stream whose request never reached a unit
        # (e.g. cancelled before arrival): collectors must not hang
        for s in self._streams.values():
            if not s._closed:
                r = s.req
                if r.cancelled:
                    s._push("cancelled", None)
                elif r.shed:
                    s._push("shed", r.shed_reason or "shed")
                elif r.finish >= 0:
                    s._push("finish", None)
                else:
                    # still pending at loop exit (max_ticks): close the
                    # stream with an explicit error, never hang clients
                    s._push("error", "serving loop ended before "
                                     "request completed")
        return session.report()

    def report(self):
        return self.session.report()


def serve_and_collect(frontend: ServingFrontend,
                      requests: Optional[List[Request]] = None):
    """Synchronous convenience: stream every request, run the loop,
    return ``(report, outputs)`` where ``outputs[req_id]`` is the
    collected token list or the terminal :class:`StreamError`.

    This is the bit-reproducibility harness: under the deterministic
    clock the collected streams must equal the closed-loop driver's
    ``Request.output`` exactly (tests/test_frontend.py) — and it is
    also how the benchmark gate replays a trace through the router.
    """
    reqs = requests if requests is not None else frontend.session.requests

    async def _main():
        streams = [frontend.stream(r) for r in reqs]
        serve_task = asyncio.ensure_future(frontend.serve())
        outs = await asyncio.gather(*(s.collect() for s in streams),
                                    return_exceptions=True)
        report = await serve_task
        for o in outs:
            if isinstance(o, Exception) and not isinstance(o, StreamError):
                raise o
        return report, {r.req_id: o for r, o in zip(reqs, outs)}

    return asyncio.run(_main())
