"""Dependency-free Prometheus-style metrics for the serving stack.

The live front end (``serving/frontend.py``) and the closed-loop driver
(``serving/driver.py``) both need the same observability surface: per-LLM
throughput, latency histograms, queue/pool gauges, and labeled event
counters for sheds, faults, recoveries and reconfigurations.  This module
provides that surface with zero third-party dependencies:

- :class:`Counter`, :class:`Gauge`, :class:`Histogram` — labeled metric
  families with Prometheus text exposition (``render()``) and a JSON-able
  snapshot (``snapshot()``).
- :class:`MetricsRegistry` — ordered collection of families; one registry
  per serving session.
- :class:`ServingMetrics` — the concrete metric taxonomy wired through
  engine/scheduler/driver/reconfig/faults, so call sites share one schema.
- :class:`StructuredLog` — request-ID-correlated event records (bounded
  ring) for tracing a single request across submit/route/stream/finish.
- :class:`MetricsServer` — optional stdlib-only HTTP endpoint serving the
  text exposition at ``/metrics``, the JSON snapshot at ``/metrics.json``,
  and a server-sent-events stream of structured-log records at ``/events``.

Determinism note: metric *values* are derived from the serving clock and
request outcomes, so under the deterministic tick-cost clock two runs of
the same trace produce identical snapshots.  Only the HTTP server (a
daemon thread) touches wall time, and it is opt-in.
"""

from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ServingMetrics",
    "StructuredLog",
    "MetricsServer",
    "DEFAULT_LATENCY_BUCKETS",
]

LabelKey = Tuple[str, ...]

# Seconds; spans sub-tick latencies in the deterministic clock up to long
# wall-clock E2E times.  Mirrors the default Prometheus client buckets with
# a couple of fine low-end bins for the virtual clock's small dt values.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 25.0, 60.0, 120.0,
)


def _fmt_value(v: float) -> str:
    """Prometheus-style number formatting: integers without a trailing .0."""
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def _label_str(names: Sequence[str], values: LabelKey, extra: str = "") -> str:
    parts = [f'{n}="{v}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return ("{" + ",".join(parts) + "}") if parts else ""


class _Family:
    """Base class: a named metric family with a fixed label schema."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: Dict[str, str]) -> LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got {tuple(labels)}"
            )
        return tuple(str(labels[n]) for n in self.labelnames)

    def header(self) -> List[str]:
        return [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]

    def render(self) -> List[str]:  # pragma: no cover - overridden
        raise NotImplementedError

    def snapshot(self) -> Dict[str, object]:  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Family):
    """Monotonically increasing labeled counter."""

    kind = "counter"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up (got {amount})")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            for key in sorted(self._values):
                out.append(
                    f"{self.name}{_label_str(self.labelnames, key)} "
                    f"{_fmt_value(self._values[key])}"
                )
        return out

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            series = [
                {"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._values.items())
            ]
        return {"name": self.name, "type": self.kind, "series": series}


class Gauge(_Family):
    """Labeled gauge: set to the latest sampled value."""

    kind = "gauge"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        super().__init__(name, help, labelnames)
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: str) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            for key in sorted(self._values):
                out.append(
                    f"{self.name}{_label_str(self.labelnames, key)} "
                    f"{_fmt_value(self._values[key])}"
                )
        return out

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            series = [
                {"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._values.items())
            ]
        return {"name": self.name, "type": self.kind, "series": series}


@dataclass
class _HistSeries:
    buckets: List[float]
    sum: float = 0.0
    count: int = 0


class Histogram(_Family):
    """Labeled histogram with cumulative buckets, Prometheus semantics."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
    ):
        super().__init__(name, help, labelnames)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"{self.name}: need at least one bucket")
        self.buckets = tuple(bs)
        self._series: Dict[LabelKey, _HistSeries] = {}

    def observe(self, value: float, **labels: str) -> None:
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries(buckets=[0.0] * len(self.buckets))
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    s.buckets[i] += 1
            s.sum += float(value)
            s.count += 1

    def count(self, **labels: str) -> int:
        with self._lock:
            s = self._series.get(self._key(labels))
            return s.count if s else 0

    def sum(self, **labels: str) -> float:
        with self._lock:
            s = self._series.get(self._key(labels))
            return s.sum if s else 0.0

    def render(self) -> List[str]:
        out = self.header()
        with self._lock:
            for key in sorted(self._series):
                s = self._series[key]
                for ub, cum in zip(self.buckets, s.buckets):
                    le = _label_str(self.labelnames, key, f'le="{_fmt_value(ub)}"')
                    out.append(f"{self.name}_bucket{le} {_fmt_value(cum)}")
                le_inf = _label_str(self.labelnames, key, 'le="+Inf"')
                out.append(f"{self.name}_bucket{le_inf} {_fmt_value(s.count)}")
                lab = _label_str(self.labelnames, key)
                out.append(f"{self.name}_sum{lab} {_fmt_value(s.sum)}")
                out.append(f"{self.name}_count{lab} {_fmt_value(s.count)}")
        return out

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            series = []
            for key in sorted(self._series):
                s = self._series[key]
                series.append(
                    {
                        "labels": dict(zip(self.labelnames, key)),
                        "buckets": dict(
                            zip((_fmt_value(b) for b in self.buckets), s.buckets)
                        ),
                        "sum": s.sum,
                        "count": s.count,
                    }
                )
        return {
            "name": self.name,
            "type": self.kind,
            "bucket_bounds": list(self.buckets),
            "series": series,
        }


class MetricsRegistry:
    """Ordered collection of metric families with shared exposition."""

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def register(self, fam: _Family) -> _Family:
        with self._lock:
            if fam.name in self._families:
                raise ValueError(f"duplicate metric family: {fam.name}")
            self._families[fam.name] = fam
        return fam

    def counter(self, name: str, help: str, labelnames: Sequence[str] = ()) -> Counter:
        return self.register(Counter(name, help, labelnames))  # type: ignore[return-value]

    def gauge(self, name: str, help: str, labelnames: Sequence[str] = ()) -> Gauge:
        return self.register(Gauge(name, help, labelnames))  # type: ignore[return-value]

    def histogram(
        self,
        name: str,
        help: str,
        labelnames: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
    ) -> Histogram:
        return self.register(Histogram(name, help, labelnames, buckets))  # type: ignore[return-value]

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    def families(self) -> List[_Family]:
        with self._lock:
            return list(self._families.values())

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        for fam in self.families():
            lines.extend(fam.render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, object]:
        """JSON-able snapshot of every family."""
        return {"families": [f.snapshot() for f in self.families()]}


@dataclass
class LogRecord:
    """One structured, request-correlated event."""

    ts: float
    event: str
    req_id: str
    fields: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        d = {"ts": self.ts, "event": self.event, "req_id": self.req_id}
        d.update(self.fields)
        return d


class StructuredLog:
    """Bounded ring of request-ID-correlated structured events.

    Call sites log with ``log.emit(now, "route", req_id, llm="a@0")``;
    readers filter by request with :meth:`for_request` or drain for the
    SSE endpoint with :meth:`tail`.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._records: Deque[LogRecord] = deque(maxlen=self.capacity)
        self._seq = 0
        self._lock = threading.Lock()

    def emit(self, ts: float, event: str, req_id: str, **fields: object) -> LogRecord:
        rec = LogRecord(ts=float(ts), event=event, req_id=str(req_id), fields=fields)
        with self._lock:
            self._records.append(rec)
            self._seq += 1
        return rec

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    @property
    def seq(self) -> int:
        """Total records ever emitted (monotonic, survives ring eviction)."""
        with self._lock:
            return self._seq

    def tail(self, n: int = 100) -> List[LogRecord]:
        with self._lock:
            return list(self._records)[-n:]

    def for_request(self, req_id: str) -> List[LogRecord]:
        with self._lock:
            return [r for r in self._records if r.req_id == str(req_id)]

    def to_jsonl(self, n: Optional[int] = None) -> str:
        recs = self.tail(n) if n is not None else self.tail(self.capacity)
        return "\n".join(json.dumps(r.to_dict(), sort_keys=True) for r in recs)


class ServingMetrics:
    """The serving stack's concrete metric taxonomy.

    One instance per session; every layer (frontend, router, scheduler,
    driver, reconfig controller, fault injector) records into the same
    registry so a single exposition covers the whole request lifecycle.
    """

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        latency_buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
    ):
        r = registry if registry is not None else MetricsRegistry()
        self.registry = r
        self.log = StructuredLog()

        # Request lifecycle counters (labels: llm = engine/unit name).
        self.requests_submitted = r.counter(
            "mux_requests_submitted_total", "Requests submitted to a unit", ("llm",)
        )
        self.requests_finished = r.counter(
            "mux_requests_finished_total", "Requests finished", ("llm",)
        )
        self.requests_shed = r.counter(
            "mux_requests_shed_total", "Requests shed", ("llm", "reason")
        )
        self.requests_cancelled = r.counter(
            "mux_requests_cancelled_total", "Requests cancelled by the client", ("llm",)
        )
        self.requests_retried = r.counter(
            "mux_requests_retried_total", "Requeues after crash recovery", ("llm",)
        )
        self.tokens_total = r.counter(
            "mux_tokens_total", "Tokens processed per phase", ("llm", "phase")
        )

        # Latency histograms (seconds on the session clock).
        self.ttft_seconds = r.histogram(
            "mux_ttft_seconds", "Time to first token", ("llm",), latency_buckets
        )
        self.tpot_seconds = r.histogram(
            "mux_tpot_seconds", "Time per output token", ("llm",), latency_buckets
        )
        self.e2e_seconds = r.histogram(
            "mux_e2e_seconds", "End-to-end request latency", ("llm",), latency_buckets
        )

        # Live state gauges.
        self.llm_qps = r.gauge(
            "mux_llm_qps", "Arrival rate over the session so far", ("llm",)
        )
        self.queue_depth = r.gauge(
            "mux_queue_depth", "Admission queue depth", ("llm",)
        )
        self.running_seqs = r.gauge(
            "mux_running_seqs", "Sequences resident in engine slots", ("llm",)
        )
        self.pool_used_blocks = r.gauge(
            "mux_pool_used_blocks", "KV blocks charged to the LLM", ("llm",)
        )
        self.pool_available_blocks = r.gauge(
            "mux_pool_available_blocks", "Free blocks in the unified pool", ("unit",)
        )

        # Events (reconfig / faults / degradation).
        self.reconfig_events = r.counter(
            "mux_reconfig_events_total", "Reconfiguration events", ("kind",)
        )
        self.migrated_blocks = r.counter(
            "mux_migrated_blocks_total", "KV blocks moved by migrations"
        )
        self.fault_events = r.counter(
            "mux_fault_events_total", "Injected fault events", ("kind",)
        )
        self.recoveries = r.counter(
            "mux_recoveries_total", "Engine crash recoveries", ("llm",)
        )
        self.watchdog_trips = r.counter(
            "mux_watchdog_trips_total", "Serving-loop watchdog trips"
        )

        # Router decisions (labels: strategy + chosen engine).
        self.router_decisions = r.counter(
            "mux_router_decisions_total", "Routing decisions", ("strategy", "llm")
        )
        self.stream_errors = r.counter(
            "mux_stream_errors_total", "Streams terminated with an error", ("reason",)
        )

    def render(self) -> str:
        return self.registry.render()

    def snapshot(self) -> Dict[str, object]:
        return self.registry.snapshot()


class MetricsServer:
    """Stdlib-only HTTP endpoint for a :class:`ServingMetrics` instance.

    Routes:
      - ``GET /metrics``       Prometheus text exposition
      - ``GET /metrics.json``  JSON snapshot
      - ``GET /events``        last structured-log records as SSE frames

    Runs a ``ThreadingHTTPServer`` on a daemon thread; ``port=0`` binds an
    ephemeral port (read it back from :attr:`port`).  This is the only
    wall-clock-touching component in the module and is opt-in.
    """

    def __init__(self, metrics: ServingMetrics, host: str = "127.0.0.1", port: int = 0):
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        metrics_ref = metrics

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a: object) -> None:  # silence stderr
                pass

            def _send(self, code: int, ctype: str, body: bytes) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self) -> None:  # noqa: N802 (http.server API)
                path = self.path.split("?", 1)[0]
                if path == "/metrics":
                    self._send(
                        200,
                        "text/plain; version=0.0.4; charset=utf-8",
                        metrics_ref.render().encode(),
                    )
                elif path == "/metrics.json":
                    self._send(
                        200,
                        "application/json",
                        json.dumps(metrics_ref.snapshot(), sort_keys=True).encode(),
                    )
                elif path == "/events":
                    frames = [
                        f"data: {json.dumps(rec.to_dict(), sort_keys=True)}\n\n"
                        for rec in metrics_ref.log.tail(200)
                    ]
                    self._send(
                        200, "text/event-stream", "".join(frames).encode()
                    )
                else:
                    self._send(404, "text/plain", b"not found\n")

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http", daemon=True
        )

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        # shutdown() blocks on serve_forever's acknowledgement, which
        # never comes if start() was never called — guard on the thread
        if self._thread.is_alive():
            self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=2.0)


def percentile_from_histogram(
    hist: Histogram, q: float, **labels: str
) -> Optional[float]:
    """Estimate a quantile from cumulative buckets (upper-bound estimate).

    Used by dashboards / the benchmark for coarse checks; exact latency
    percentiles still come from the driver's LatencyStats.
    """
    with hist._lock:
        s = hist._series.get(hist._key(labels))
        if s is None or s.count == 0:
            return None
        rank = q * s.count
        for ub, cum in zip(hist.buckets, s.buckets):
            if cum >= rank:
                return ub
        return float("inf")
