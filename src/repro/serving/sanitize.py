"""Runtime invariant sanitizer (DESIGN.md §15).

The correctness story of the unified pool and the serving loop rests
on conservation laws the test suite can only spot-check at chosen
moments.  This module turns them into an always-on checker: enable it
(``serve.py --sanitize`` or ``MUXSERVE_SANITIZE=1``) and every serving
tick re-validates, raising ``SanitizeError`` with the first violated
law *at the tick that broke it* instead of letting corruption surface
hundreds of ticks later as a wrong result.

Checked laws, bottom-up:

* allocator — every live head-block has refcount ≥ 1; ``used`` equals
  the refcount-weighted sum over live blocks; ``physical_used``
  counts distinct live blocks; the free list is sorted, coalesced,
  in-bounds, and disjoint from the live set; free + live covers the
  arena exactly.
* pool/views — each view's ``used`` equals the recomputed charge of
  its sequences (group blocks + SSM state units + shared-prefix full
  charge); ``pool.used_by`` mirrors it; the allocator's ``used``
  equals the sum of all holders (sequence charges + prefix-index
  refs); every sequence base and every prefix-index entry points at a
  live group; the device arrays match the arena size.
* scheduler — the zero-copy grant algebra: ``n_head_blocks == base +
  Σ granted + debt`` (``MuxScheduler._grant_debt``), with ``base``
  adjusted when a block-loss fault shrinks the arena
  (``note_blocks_lost`` — wired in ``MuxScheduler._lose_blocks``);
  engine slots and pool views agree on the live sequence set.
* session — the disposition law: every submitted request is, at every
  tick, in exactly ONE of {finished, shed, cancelled, held} and a
  held request is actually findable in a queue, a slot, or a preempt
  buffer — ``submitted = finished + shed + cancelled`` at drain is
  the t→∞ corollary.

The sanitizer is a pure reader: a sanitized run is bit-identical to an
unsanitized one (asserted by the chaos CI gate at severity 0).
"""
from __future__ import annotations

import bisect
import os
from typing import Dict, List

__all__ = ["SanitizeError", "PoolSanitizer", "SchedulerSanitizer",
           "SessionSanitizer", "allocator_errors", "pool_errors",
           "sanitize_enabled"]


class SanitizeError(AssertionError):
    """A runtime invariant was violated.  The message lists every law
    broken at the failing check point, with the numbers that broke it."""


def sanitize_enabled() -> bool:
    """Environment override: ``MUXSERVE_SANITIZE=1`` arms the sanitizer
    in any driver entry point without touching call sites."""
    return os.environ.get("MUXSERVE_SANITIZE", "") not in ("", "0")


# ---------------------------------------------------------------------------
# allocator / pool (kvcache.py)
# ---------------------------------------------------------------------------
def allocator_errors(alloc) -> List[str]:
    """Conservation laws of one ``BlockAllocator``."""
    errs: List[str] = []
    refs = alloc.refcounts()
    bad = {b: r for b, r in refs.items() if r < 1}
    if bad:
        errs.append(f"live blocks with refcount < 1: {bad}")
    if alloc.physical_used != len(refs):
        errs.append(f"physical_used={alloc.physical_used} != "
                    f"{len(refs)} distinct live blocks")
    weighted = sum(refs.values())
    if alloc.used != weighted:
        errs.append(f"used={alloc.used} != refcount-weighted sum "
                    f"{weighted} over live blocks")
    free = alloc.free_ranges()
    prev_end = -1
    covered = 0
    for s, e in free:
        if not (0 <= s < e <= alloc.n_blocks):
            errs.append(f"free range [{s},{e}) out of arena "
                        f"[0,{alloc.n_blocks})")
        if s <= prev_end:
            errs.append(f"free list unsorted/uncoalesced at [{s},{e}) "
                        f"after end {prev_end}")
        prev_end = e
        covered += e - s
    # disjointness: walk the LIVE blocks (few) against the sorted free
    # ranges, not the free space (arena-sized) against the live set
    starts = [s for s, _ in free]
    overlap = []
    for b in refs:
        i = bisect.bisect_right(starts, b) - 1
        if i >= 0 and free[i][0] <= b < free[i][1]:
            overlap.append(b)
            if len(overlap) > 8:
                break
    if overlap:
        errs.append(f"blocks both free and live: {sorted(overlap)[:8]}"
                    f"{'…' if len(overlap) > 8 else ''}")
    if covered != alloc.n_blocks - len(refs):
        errs.append(f"free list covers {covered} blocks, expected "
                    f"{alloc.n_blocks - len(refs)} "
                    f"(arena {alloc.n_blocks} − live {len(refs)}) — "
                    f"blocks leaked or minted")
    return errs


def _view_charge(view) -> int:
    """Recompute what the view's sequences should be charged: group
    blocks per token-block (shared prefixes at FULL charge — the
    DESIGN.md §13 COW policy) plus the SSM state footprint."""
    charge = sum(len(sc.bases) for sc in view.seqs.values())\
        * view.group_size
    if view.cfg.ssm:
        started = sum(1 for sid in view.seqs if sid in view._started)
        charge += started * view._ssm_blocks_per_seq
    return charge


def pool_errors(pool) -> List[str]:
    """Conservation laws of one ``UnifiedKVPool`` and its views."""
    errs = [f"allocator: {e}" for e in allocator_errors(pool.allocator)]
    if pool.allocator.n_blocks != pool.n_head_blocks:
        errs.append(f"allocator arena {pool.allocator.n_blocks} != "
                    f"pool.n_head_blocks {pool.n_head_blocks}")
    if pool.k.shape[0] != pool.n_head_blocks\
            or pool.v.shape[0] != pool.n_head_blocks:
        errs.append(f"device arrays k[{pool.k.shape[0]}]/"
                    f"v[{pool.v.shape[0]}] != arena "
                    f"{pool.n_head_blocks}")
    refs = pool.allocator.refcounts()
    holders = 0
    for name, view in pool.views.items():
        charge = _view_charge(view)
        if view.used != charge:
            errs.append(f"view {name}: used={view.used} != recomputed "
                        f"sequence charge {charge}")
        if pool.used_by.get(name) != view.used:
            errs.append(f"view {name}: pool.used_by="
                        f"{pool.used_by.get(name)} != view.used "
                        f"{view.used}")
        if view.quota < 0:
            errs.append(f"view {name}: negative quota {view.quota}")
        for sid, sc in view.seqs.items():
            for base in sc.bases:
                dead = [b for b in range(base, base + view.group_size)
                        if b not in refs]
                if dead:
                    errs.append(f"view {name} seq {sid}: base {base} "
                                f"group holds dead blocks {dead[:4]}")
                    break
        # arena holders: token-block bases (SSM state units live in
        # the separate state arena, not the head-block allocator)
        holders += sum(len(sc.bases) for sc in view.seqs.values())\
            * view.group_size
        if view.prefix_index is not None:
            holders += view.prefix_index.held_blocks
            for _h, (base, _blk) in view.prefix_index.entries():
                if refs.get(base, 0) < 1:
                    errs.append(f"view {name}: prefix-index entry at "
                                f"base {base} holds a dead block "
                                f"(refcount "
                                f"{refs.get(base, 0)})")
    if pool.allocator.used != holders:
        errs.append(f"allocator.used={pool.allocator.used} != "
                    f"{holders} summed over holders (sequence charges "
                    f"+ prefix-index refs) — a holder was dropped or "
                    f"double-counted")
    return errs


class PoolSanitizer:
    """Per-tick checker for one pool (usable standalone in tests)."""

    def __init__(self, pool):
        self.pool = pool
        self.checks = 0

    def check(self, where: str = "") -> None:
        self.checks += 1
        errs = pool_errors(self.pool)
        if errs:
            raise SanitizeError(_fmt("pool", where, errs))


# ---------------------------------------------------------------------------
# scheduler (mux.py)
# ---------------------------------------------------------------------------
class SchedulerSanitizer:
    """Grant-algebra and slot/view coherence for one ``MuxScheduler``.

    Attaching installs itself as ``unit.sanitizer`` so the block-loss
    fault path can report arena shrinks that legitimately change the
    base size (``MuxScheduler._lose_blocks`` →
    ``note_blocks_lost``)."""

    def __init__(self, unit):
        self.unit = unit
        self.pool = PoolSanitizer(unit.pool)
        granted = sum(g.granted_blocks for g in unit.fused_groups)
        self.base = unit.pool.n_head_blocks - granted - unit._grant_debt
        self.checks = 0
        unit.sanitizer = self

    def note_blocks_lost(self, n: int) -> None:
        """A block-loss fault shrank the arena outside the grant
        algebra: the base size itself changed."""
        self.base -= n

    def errors(self) -> List[str]:
        u = self.unit
        errs: List[str] = []
        granted = sum(g.granted_blocks for g in u.fused_groups)
        debt = u._grant_debt
        if debt < 0:
            errs.append(f"negative grant debt {debt}")
        if u.pool.n_head_blocks != self.base + granted + debt:
            errs.append(
                f"grant algebra broken: n_head_blocks="
                f"{u.pool.n_head_blocks} != base {self.base} + granted "
                f"{granted} + debt {debt}")
        for name, eng in u.engines.items():
            live = set(eng.live_seq_ids())
            in_view = set(eng.view.seqs)
            if live != in_view:
                errs.append(
                    f"engine {name}: live slots {sorted(live)} != view "
                    f"sequences {sorted(in_view)} — a slot or a cache "
                    f"entry leaked")
            if eng.view.cfg.name != name:
                errs.append(f"engine {name} bound to view "
                            f"{eng.view.cfg.name}")
        return errs

    def check(self, where: str = "") -> None:
        self.checks += 1
        errs = pool_errors(self.unit.pool) + self.errors()
        if errs:
            raise SanitizeError(_fmt("scheduler", where, errs))


# ---------------------------------------------------------------------------
# session (driver.py)
# ---------------------------------------------------------------------------
class SessionSanitizer:
    """Disposition law + per-unit invariants for a ``ServeSession``.

    ``check`` runs after every busy tick (and once at drain): each
    submitted request must be in exactly one disposition state, and a
    request in none of them must be *held* — findable in a queue, an
    engine slot, or a preempt buffer.  A request that is nowhere is
    the bug class the law exists to catch (silently lost work)."""

    def __init__(self, session):
        self.session = session
        self.units = [SchedulerSanitizer(u) for u in session.units]
        self.checks = 0

    # -- helpers ---------------------------------------------------------
    def _held_ids(self) -> set:
        held = set()
        for u in self.session.units:
            for q in u.queues.values():
                held.update(id(r) for r in q)
            for eng in u.engines.values():
                held.update(id(r) for r in eng.slots if r is not None)
                held.update(id(r) for r in eng.preempted)
                held.update(id(r) for r in eng.finished)
        return held

    def errors(self) -> List[str]:
        s = self.session
        errs: List[str] = []
        held = self._held_ids()
        per: Dict[str, List[int]] = {}
        for r in s.requests[:s.idx]:
            fin = 1 if r.finish >= 0 else 0
            shd = 1 if r.shed else 0
            can = 1 if r.cancelled else 0
            if fin + shd + can > 1:
                errs.append(
                    f"request {r.req_id} ({r.model}) has multiple "
                    f"dispositions: finish={r.finish:.4g} "
                    f"shed={r.shed} cancelled={r.cancelled}")
            if fin + shd + can == 0 and id(r) not in held:
                errs.append(
                    f"request {r.req_id} ({r.model}) is SILENTLY LOST: "
                    f"submitted, not finished/shed/cancelled, and held "
                    f"by no queue, slot, or preempt buffer")
            c = per.setdefault(r.model, [0, 0, 0, 0, 0])
            c[0] += 1
            c[1] += fin
            c[2] += shd
            c[3] += can
            c[4] += 1 - min(fin + shd + can, 1)
        for name, (sub, fin, shd, can, out) in sorted(per.items()):
            if sub != fin + shd + can + out:
                errs.append(
                    f"disposition law broken for {name}: submitted "
                    f"{sub} != finished {fin} + shed {shd} + cancelled "
                    f"{can} + outstanding {out}")
        # stats lists must agree with request flags (each disposition
        # recorded exactly once)
        for u in s.units:
            fin_ids = [id(r) for r in u.stats.finished]
            if len(fin_ids) != len(set(fin_ids)):
                errs.append("a request appears twice in stats.finished")
            bad = [r.req_id for r in u.stats.finished if r.finish < 0]
            if bad:
                errs.append(f"requests in stats.finished without a "
                            f"finish stamp: {bad[:8]}")
            bad = [r.req_id for r in u.stats.shed if not r.shed]
            if bad:
                errs.append(f"requests in stats.shed without the shed "
                            f"flag: {bad[:8]}")
        return errs

    def check(self, where: str = "") -> None:
        self.checks += 1
        errs: List[str] = []
        for us in self.units:
            errs.extend(pool_errors(us.unit.pool))
            errs.extend(us.errors())
        errs.extend(self.errors())
        if errs:
            raise SanitizeError(_fmt("session", where, errs))


def _fmt(scope: str, where: str, errs: List[str]) -> str:
    head = f"sanitizer[{scope}]{f' at {where}' if where else ''}: "\
           f"{len(errs)} invariant violation"\
           f"{'s' if len(errs) != 1 else ''}"
    return head + "".join(f"\n  - {e}" for e in errs)
