"""Unified head-wise KV cache pool (paper §3.4).

The pool is a single arena of *head-blocks*: each block holds
``BLOCK_TOKENS`` tokens of one KV head (``[BLOCK_TOKENS, head_dim]``).
Because the block shape is model-independent (head_dim is uniform across
the colocated LLMs — 128 for LLaMA/GPT-3 per the paper; we check and
group pools by head_dim), LLMs of different depths/head-counts share one
memory space.  ADBS enforces per-LLM head-block quotas and re-allocates
them at runtime (paper Alg. 3).

Allocation granularity: within one LLM, a logical *token block* (16
tokens of one sequence) needs ``n_layers × n_kv_heads`` head-blocks; we
allocate them as one contiguous range ("group") so the device-side
block table is a single base id per token block and the physical index
is ``base + layer*KV + head``.  Sharing between models remains at
head-block granularity (groups of different sizes draw from the same
free space); freeing coalesces ranges, so external fragmentation is
bounded by group size at range boundaries (measured in tests).

SSM models store their constant-size state separately (state is O(1)
per sequence — paging adds nothing); their token-block usage for ADBS
quota accounting is computed from the state footprint.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import BLOCK_TOKENS, ModelConfig


class BlockAllocator:
    """First-fit contiguous range allocator over head-blocks (host side).

    Free space kept as a sorted list of ``[start, end)`` ranges.

    Blocks are refcounted (DESIGN.md §13): ``alloc`` hands out ranges
    at refcount 1, ``share`` adds a holder, and ``free`` drops one —
    a block returns to the free list only when its last holder lets
    go.  Two usage figures follow: ``used`` is refcount-weighted (what
    every holder is charged, so per-view quota sums still equal it),
    while ``physical_used`` counts distinct live blocks (what the
    arena actually spends — ``free_blocks`` derives from it).  Absent
    sharing the two are equal and behavior is bit-identical to the
    un-refcounted allocator.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[Tuple[int, int]] = [(0, n_blocks)]
        self._refs: Dict[int, int] = {}
        self.used = 0
        self.physical_used = 0

    def alloc(self, n: int) -> Optional[int]:
        for i, (s, e) in enumerate(self._free):
            if e - s >= n:
                if e - s == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (s + n, e)
                self.used += n
                self.physical_used += n
                for b in range(s, s + n):
                    self._refs[b] = 1
                return s
        return None

    def share(self, start: int, n: int) -> None:
        """Add one holder to every block in ``[start, start+n)``.  The
        range must be live — sharing free space is a caller bug."""
        if n <= 0:
            return
        refs = self._refs
        for b in range(start, start + n):
            if b not in refs:
                raise ValueError(f"share of unallocated head-block {b}")
        for b in range(start, start + n):
            refs[b] += 1
        self.used += n

    def refcount(self, block: int) -> int:
        return self._refs.get(block, 0)

    def refcounts(self) -> Dict[int, int]:
        """Copy of the live refcount map (tests/debugging)."""
        return dict(self._refs)

    def free_ranges(self) -> List[Tuple[int, int]]:
        """Copy of the sorted free list (sanitizer/tests) — half-open
        ``(start, end)`` ranges."""
        return list(self._free)

    def free(self, start: int, n: int) -> None:
        """Drop one holder per block; blocks reaching refcount 0 are
        coalesced back into the free list.  Freeing a dead block
        raises — a double free would corrupt a later allocation."""
        if n <= 0:
            return
        refs = self._refs
        runs: List[Tuple[int, int]] = []   # maximal runs reaching 0
        run_s: Optional[int] = None
        for b in range(start, start + n):
            r = refs.get(b)
            if r is None:
                raise ValueError(f"double free of head-block {b}")
            if r == 1:
                del refs[b]
                self.physical_used -= 1
                if run_s is None:
                    run_s = b
            else:
                refs[b] = r - 1
                if run_s is not None:
                    runs.append((run_s, b))
                    run_s = None
        if run_s is not None:
            runs.append((run_s, start + n))
        self.used -= n
        if not runs:
            return
        for new in runs:
            bisect.insort(self._free, new)
        # coalesce neighbours
        merged: List[Tuple[int, int]] = []
        for s, e in self._free:
            if merged and s <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self._free = merged

    def grow(self, n: int) -> None:
        """Extend the arena by ``n`` head-blocks of new free space
        (zero-copy weight de-dup grants reclaimed HBM back to the
        pool — see UnifiedKVPool.grow)."""
        if n <= 0:
            return
        start = self.n_blocks
        self.n_blocks += n
        if self._free and self._free[-1][1] == start:
            self._free[-1] = (self._free[-1][0], start + n)
        else:
            self._free.append((start, start + n))

    def shrink(self, n: int) -> int:
        """Remove up to ``n`` head-blocks from the END of the arena.

        The inverse of ``grow``: only entirely-free tail space is
        released — in-use blocks are never reclaimed, so a shrink that
        would cut below a live allocation is clamped to the free tail
        (possibly 0).  When the tail is idle, ``shrink(n)`` after
        ``grow(n)`` restores the arena exactly.  Returns the number of
        blocks actually removed.
        """
        if n <= 0:
            return 0
        take = 0
        if self._free and self._free[-1][1] == self.n_blocks:
            s, e = self._free[-1]
            take = min(n, e - s)
            if take == e - s:
                self._free.pop()
            else:
                self._free[-1] = (s, e - take)
        self.n_blocks -= take
        return take

    @property
    def free_blocks(self) -> int:
        return self.n_blocks - self.physical_used

    def largest_free_range(self) -> int:
        """Largest contiguous free run — an *allocatability* figure
        (can a group-size run be placed?), NOT a shrink capacity:
        ``shrink`` only takes from the arena tail, which a single
        pinned block clamps regardless of interior space.  Use
        ``shrinkable_tail`` when planning shrinks."""
        return max((e - s for s, e in self._free), default=0)

    def shrinkable_tail(self) -> int:
        """Head-blocks ``shrink`` could actually remove right now: the
        length of the free run ending exactly at ``n_blocks``, 0 when
        any live block (a sequence's — or a shared/prefix-cached
        one's) pins the tail."""
        if self._free and self._free[-1][1] == self.n_blocks:
            s, e = self._free[-1]
            return e - s
        return 0

    def fragmentation(self) -> float:
        """1 − largest_free/total_free (0 = one contiguous free range).
        Like ``largest_free_range`` this describes interior
        allocatability, not the shrinkable tail."""
        if self.free_blocks == 0:
            return 0.0
        return 1.0 - self.largest_free_range() / self.free_blocks


@dataclass
class SeqCache:
    """Host-side bookkeeping for one sequence's cache."""
    seq_id: int
    bases: List[int] = field(default_factory=list)   # group base per token-block
    n_tokens: int = 0
    # leading block groups adopted read-only from other holders via
    # share_prefix (prefix caching, DESIGN.md §13); writes into this
    # region trigger copy-on-write.  Always a prefix: bases[:shared].
    shared: int = 0


class PrefixIndex:
    """Per-LLM prompt-prefix → cached-block-group index (DESIGN.md §13).

    Keyed by a hash chain over FULL prompt token-blocks: ``h_i =
    hash((h_{i−1}, block_i_tokens))``, so an entry for block *i* is
    only reachable when blocks ``0..i−1`` matched too — a lookup
    always adopts a chain prefix.  Only fully-written blocks are
    indexed (chunked prefill's pad garbage lands at positions ≥ the
    prompt length, i.e. never inside an indexed block), and each entry
    stores the block's tokens alongside the base so a hash collision
    can never adopt wrong KV.

    Entries hold their own allocator refcount on the group, so cached
    prefixes survive the inserting sequence; they are disposable pool
    inventory, never quota-charged: evicted LRU-first under allocation
    pressure (``reclaim``), dropped when a shrink dooms their tail
    blocks (``release_from``), and cleared wholesale when the view
    unregisters (crash recovery / migration source).  Dict insertion
    order doubles as the LRU order.
    """

    def __init__(self, view: "ModelCacheView"):
        self.view = view
        self._entries: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted = 0
        self.evicted = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def held_blocks(self) -> int:
        """Head-blocks the index holds a refcount on."""
        return len(self._entries) * self.view.group_size

    def entries(self) -> List[Tuple[int, Tuple[int, Tuple[int, ...]]]]:
        """(hash, (base, block_tokens)) pairs in LRU→MRU order."""
        return list(self._entries.items())

    @staticmethod
    def chain_hashes(prompt: List[int], n_blocks: int
                     ) -> List[Tuple[int, Tuple[int, ...]]]:
        out: List[Tuple[int, Tuple[int, ...]]] = []
        h = 0
        for i in range(n_blocks):
            blk = tuple(prompt[i * BLOCK_TOKENS:(i + 1) * BLOCK_TOKENS])
            h = hash((h, blk))
            out.append((h, blk))
        return out

    def lookup(self, prompt: List[int]) -> Tuple[int, List[int]]:
        """Longest cached chain prefix of ``prompt`` as ``(n_tokens,
        group bases)``.  Clamped to ``(len(prompt)−1)//BLOCK_TOKENS``
        blocks so prefill always computes at least the prompt's last
        token — the engine needs its logits for the first generated
        token."""
        self.lookups += 1
        bases: List[int] = []
        max_adopt = (len(prompt) - 1) // BLOCK_TOKENS
        for h, blk in self.chain_hashes(prompt, max_adopt):
            ent = self._entries.get(h)
            if ent is None or ent[1] != blk:
                break
            self._entries[h] = self._entries.pop(h)      # LRU touch
            bases.append(ent[0])
        if bases:
            self.hits += 1
            self.hit_tokens += len(bases) * BLOCK_TOKENS
        return len(bases) * BLOCK_TOKENS, bases

    def insert(self, prompt: List[int], bases: List[int]) -> int:
        """Index every full prompt block of a live sequence (called at
        prompt completion — the blocks are fully written and stable
        from then on: decode appends past the prompt).  Takes a share
        ref per new entry; existing hashes are kept (first writer
        wins).  Returns entries added."""
        n_full = min(len(prompt) // BLOCK_TOKENS, len(bases))
        added = 0
        for (h, blk), base in zip(self.chain_hashes(prompt, n_full), bases):
            if h in self._entries:
                continue
            self.view.pool.allocator.share(base, self.view.group_size)
            self._entries[h] = (base, blk)
            added += 1
        self.inserted += added
        return added

    def adopt(self, h: int, base: int, blk: Tuple[int, ...]) -> None:
        """Install a remapped entry (migration rebuild): share the
        destination group and record it under the unchanged hash."""
        if h in self._entries:
            return
        self.view.pool.allocator.share(base, self.view.group_size)
        self._entries[h] = (base, blk)

    def evictable_blocks(self) -> int:
        """Head-blocks ``reclaim`` could free right now (entries whose
        group the index alone holds)."""
        alloc = self.view.pool.allocator
        g = self.view.group_size
        return sum(g for base, _ in self._entries.values()
                   if alloc.refcount(base) == 1)

    def reclaim(self, need_blocks: int) -> int:
        """Evict LRU-first entries whose group the index alone holds
        until ``need_blocks`` head-blocks returned to the free list.
        Entries a live sequence shares free nothing by eviction and
        keep their future hits — skipped.  Returns blocks freed."""
        alloc = self.view.pool.allocator
        g = self.view.group_size
        freed = 0
        for h, (base, _) in list(self._entries.items()):
            if freed >= need_blocks:
                break
            if alloc.refcount(base) == 1:
                alloc.free(base, g)
                del self._entries[h]
                freed += g
                self.evicted += 1
        return freed

    def release_from(self, doomed_start: int) -> int:
        """Pre-shrink invalidation: drop index-only entries whose
        group intersects ``[doomed_start, ∞)`` so the doomed tail
        becomes free and the shrink isn't clamped by disposable cache
        inventory.  Entries a live sequence still shares keep their
        blocks alive — the shrink clamps below them, the entry stays
        valid, so it is kept.  Returns blocks freed."""
        alloc = self.view.pool.allocator
        g = self.view.group_size
        dropped = 0
        for h, (base, _) in list(self._entries.items()):
            if base + g > doomed_start and alloc.refcount(base) == 1:
                alloc.free(base, g)
                del self._entries[h]
                dropped += g
                self.evicted += 1
        return dropped

    def clear(self) -> None:
        """Drop every entry and its ref (view unregister — crash
        recovery tears the whole view down, migration re-indexes on
        the destination)."""
        alloc = self.view.pool.allocator
        g = self.view.group_size
        for base, _ in self._entries.values():
            alloc.free(base, g)
        self.evicted += len(self._entries)
        self._entries.clear()

    def stats(self) -> dict:
        return {"entries": len(self._entries), "lookups": self.lookups,
                "hits": self.hits, "hit_tokens": self.hit_tokens,
                "inserted": self.inserted, "evicted": self.evicted,
                "held_blocks": self.held_blocks,
                "hit_rate": (self.hits / self.lookups
                             if self.lookups else 0.0)}


class ModelCacheView:
    """Per-LLM adapter onto the shared pool.

    Tracks quota (head-blocks) granted by ADBS and per-sequence block
    tables.  ``group_size = n_layers × n_kv_heads`` head-blocks per
    token block (attention models); SSM models have group_size 0 and a
    fixed per-seq state cost (accounted against quota, not the arena).
    """

    def __init__(self, cfg: ModelConfig, pool: "UnifiedKVPool", quota: int,
                 prefix_cache: bool = False):
        self.cfg = cfg
        self.pool = pool
        self.quota = quota
        self.used = 0
        self.group_size = cfg.n_attn_layers * cfg.n_kv_heads
        self.seqs: Dict[int, SeqCache] = {}
        self._started: set = set()
        # prefix caching is a paged-attention feature: SSM/hybrid state
        # is a running summary of the whole prefix and cannot be
        # adopted block-wise, so those views never index
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(self)
            if prefix_cache and self.group_size > 0 and not cfg.ssm
            else None)
        # SSM quota accounting: state bytes expressed in head-block units
        self._ssm_blocks_per_seq = 0
        if cfg.ssm:
            state_bytes = (cfg.n_ssm_layers * cfg.n_ssm_heads
                           * cfg.ssm.head_dim * cfg.ssm.d_state * 4)
            self._ssm_blocks_per_seq = max(
                1, state_bytes // pool.head_block_bytes)

    # ---- quota ------------------------------------------------------
    def quota_headroom(self) -> int:
        return self.quota - self.used

    def can_append(self, seq_id: int, n_tokens: int) -> bool:
        # available_blocks (not raw free_blocks): prefix-cache blocks
        # are disposable and evicted on demand, so admission may count
        # them — otherwise a full cache would starve admission forever
        return self._blocks_needed(seq_id, n_tokens) <= min(
            self.quota_headroom(), self.pool.available_blocks())

    def _blocks_needed(self, seq_id: int, n_tokens: int) -> int:
        sc = self.seqs.get(seq_id)
        have = len(sc.bases) * BLOCK_TOKENS if sc else 0
        cur = sc.n_tokens if sc else 0
        need_tokens = max(0, cur + n_tokens - have)
        n_groups = -(-need_tokens // BLOCK_TOKENS)
        cost = n_groups * self.group_size
        if sc is None and self.cfg.ssm:
            cost += self._ssm_blocks_per_seq
        return cost

    # ---- allocation ---------------------------------------------------
    def share_prefix(self, seq_id: int, bases: List[int],
                     n_tokens: int) -> bool:
        """Adopt ``bases`` — block groups already live in the pool
        (a cached prefix) — as the leading blocks of a NEW sequence,
        read-only.  Quota policy (DESIGN.md §13): the sharer is
        charged fully, exactly as if it had allocated the blocks
        itself, so a later copy-on-write never needs quota headroom —
        only physical blocks.  Returns False (nothing changed) when
        quota is short."""
        assert seq_id not in self.seqs, "share_prefix needs a new sequence"
        assert self.group_size > 0 and not self.cfg.ssm, \
            "prefix sharing is a paged-attention feature"
        assert (len(bases) - 1) * BLOCK_TOKENS < n_tokens \
            <= len(bases) * BLOCK_TOKENS, (len(bases), n_tokens)
        cost = len(bases) * self.group_size
        if cost > self.quota_headroom():
            return False
        for b in bases:
            self.pool.allocator.share(b, self.group_size)
        self.seqs[seq_id] = SeqCache(seq_id, list(bases), n_tokens,
                                     shared=len(bases))
        self._started.add(seq_id)
        self.used += cost
        self.pool.used_by[self.cfg.name] = self.used
        return True

    def _cow_tail(self, sc: SeqCache) -> bool:
        """Copy-on-write before a write lands inside the shared
        prefix.  Only the LAST shared block can ever be hit: earlier
        ones are full and append-only writes never revisit a full
        block.  Sole remaining holder → unshare in place (no copy);
        otherwise allocate a private group, copy the pages
        device-side, drop our ref on the shared group and swap the
        base — ``paging.resolve_physical_blocks`` never sees any of
        this.  View quota/used are untouched (the sharer already paid
        full charge).  Returns False when no private group can be
        carved out even after evicting cache inventory."""
        blk = sc.shared - 1
        assert sc.n_tokens // BLOCK_TOKENS == blk, \
            "write into a full shared block — sharing invariant broken"
        old = sc.bases[blk]
        alloc = self.pool.allocator
        if alloc.refcount(old) == 1:
            sc.shared = blk
            return True
        new = alloc.alloc(self.group_size)
        if new is None:
            self.pool.reclaim_index_blocks(self.group_size)
            new = alloc.alloc(self.group_size)
            if new is None:
                return False
        from repro.serving.cache_ops import copy_block_groups
        self.pool.k, self.pool.v = copy_block_groups(
            self.pool.k, self.pool.v, [old], [new],
            self.cfg.n_kv_heads, self.cfg.n_attn_layers)
        alloc.free(old, self.group_size)
        sc.bases[blk] = new
        sc.shared = blk
        return True

    def append_tokens(self, seq_id: int, n_tokens: int) -> bool:
        """Reserve cache space for n_tokens more tokens of seq_id."""
        cost = self._blocks_needed(seq_id, n_tokens)
        if cost > self.quota_headroom():
            return False
        sc = self.seqs.setdefault(seq_id, SeqCache(seq_id))
        if (n_tokens > 0 and sc.shared
                and sc.n_tokens < sc.shared * BLOCK_TOKENS):
            if not self._cow_tail(sc):
                return False
        have = len(sc.bases) * BLOCK_TOKENS
        need_tokens = max(0, sc.n_tokens + n_tokens - have)
        n_groups = -(-need_tokens // BLOCK_TOKENS)
        newly = []
        for _ in range(n_groups):
            if self.group_size > 0:
                base = self.pool.allocator.alloc(self.group_size)
                if base is None and self.pool.reclaim_index_blocks(
                        self.group_size):
                    base = self.pool.allocator.alloc(self.group_size)
                if base is None:
                    for b in newly:   # roll back
                        self.pool.allocator.free(b, self.group_size)
                    return False
                newly.append(base)
        sc.bases.extend(newly)
        sc.n_tokens += n_tokens
        extra = n_groups * self.group_size
        if seq_id not in self._started and self.cfg.ssm:
            extra += self._ssm_blocks_per_seq
        self._started.add(seq_id)
        self.used += extra
        self.pool.used_by[self.cfg.name] = self.used
        return True

    def free_seq(self, seq_id: int) -> None:
        sc = self.seqs.pop(seq_id, None)
        if sc is None:
            return
        for b in sc.bases:
            self.pool.allocator.free(b, self.group_size)
        freed = len(sc.bases) * self.group_size
        if self.cfg.ssm and seq_id in self._started:
            freed += self._ssm_blocks_per_seq
        self._started.discard(seq_id)
        self.used -= freed
        self.pool.used_by[self.cfg.name] = self.used

    # ---- device-side tables -------------------------------------------
    def block_table(self, seq_ids: List[int], max_blocks: int) -> np.ndarray:
        """[len(seq_ids), max_blocks] int32 group bases (−1 padded)."""
        t = np.full((len(seq_ids), max_blocks), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            bases = self.seqs[sid].bases[:max_blocks]
            t[i, :len(bases)] = bases
        return t

    def seq_lens(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self.seqs[s].n_tokens for s in seq_ids], np.int32)


def fused_block_tables(views_seqs: List[Tuple["ModelCacheView", List[int]]],
                       rows: int, max_blocks: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Combined block-table assembly for the fused multi-LLM decode tick
    (DESIGN.md §2): each colocated model's per-sequence tables are
    resolved by its own ``ModelCacheView`` against the shared arena,
    then padded to a common ``rows × max_blocks`` shape so one jitted
    step can consume every model's rows at once.

    Returns ``(tables [M, rows, max_blocks] int32, lens [M, rows]
    int32)``.  Padded table entries are −1 (KV writes drop, attention
    masks); padded lens are 1 so the fused attention sweep reads a
    single masked position instead of an empty range.
    """
    M = len(views_seqs)
    tables = np.full((M, rows, max_blocks), -1, np.int32)
    lens = np.ones((M, rows), np.int32)
    for m, (view, seq_ids) in enumerate(views_seqs):
        b = len(seq_ids)
        tables[m, :b] = view.block_table(seq_ids, max_blocks)
        lens[m, :b] = view.seq_lens(seq_ids)
    return tables, lens


class UnifiedKVPool:
    """The shared device arena + host allocator for one LLM unit."""

    def __init__(self, n_head_blocks: int, head_dim: int,
                 dtype=jnp.bfloat16, block_tokens: int = BLOCK_TOKENS,
                 prefix_cache: bool = False):
        self.n_head_blocks = n_head_blocks
        self.head_dim = head_dim
        self.block_tokens = block_tokens
        self.dtype = dtype
        # pool-level so register_model (including the re-register on
        # crash recovery) creates per-view prefix indexes uniformly
        self.prefix_cache = prefix_cache
        self.k = jnp.zeros((n_head_blocks, block_tokens, head_dim), dtype)
        self.v = jnp.zeros((n_head_blocks, block_tokens, head_dim), dtype)
        self.allocator = BlockAllocator(n_head_blocks)
        self.views: Dict[str, ModelCacheView] = {}
        self.used_by: Dict[str, int] = {}

    @property
    def head_block_bytes(self) -> int:
        return 2 * self.block_tokens * self.head_dim * self.dtype_bytes

    @property
    def dtype_bytes(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    def hbm_bytes(self) -> int:
        """Device bytes held by the arena (k + v)."""
        return int(self.k.nbytes) + int(self.v.nbytes)

    def grow(self, extra_blocks: int) -> int:
        """Extend the arena by ``extra_blocks`` head-blocks.

        The zero-copy stacked-weights scheme (DESIGN.md §2) frees one
        full weight copy per fused group; those bytes are granted back
        to the pool here — the paper's memory-multiplexing argument in
        reverse: reclaimed weight HBM becomes KV head-blocks, which
        admit more sequences.  Returns the blocks actually added.
        """
        if extra_blocks <= 0:
            return 0
        n = self.n_head_blocks + extra_blocks
        if self.allocator.used == 0:
            # no sequence holds blocks, so arena contents are dead —
            # reallocate at the final size instead of concatenating
            # (which would transiently hold 2× the arena)
            self.k = jnp.zeros((n, self.block_tokens, self.head_dim),
                               self.dtype)
            self.v = jnp.zeros((n, self.block_tokens, self.head_dim),
                               self.dtype)
        else:
            pad = jnp.zeros((extra_blocks, self.block_tokens,
                             self.head_dim), self.dtype)
            self.k = jnp.concatenate([self.k, pad])
            self.v = jnp.concatenate([self.v, jnp.zeros_like(pad)])
        self.allocator.grow(extra_blocks)
        self.n_head_blocks = n
        return extra_blocks

    def shrink(self, extra_blocks: int) -> int:
        """Release up to ``extra_blocks`` head-blocks from the arena
        tail — the inverse of ``grow`` (live reconfiguration dissolves
        a fused group and returns its zero-copy grant before the
        members re-materialize private weight copies; DESIGN.md §10).
        Only free tail space is released — the allocator refuses to
        cut below in-use blocks — so the returned count may be smaller
        than requested.  Returns the blocks actually removed.
        """
        if (extra_blocks > 0
                and extra_blocks > self.allocator.shrinkable_tail()):
            # prefix-cache inventory is disposable: drop index-only
            # entries in the doomed tail first so cached blocks never
            # clamp a shrink (and a lost-tail shrink removes exactly
            # what the fault doomed — see tail_victims)
            doomed = self.n_head_blocks - extra_blocks
            for v in self.views.values():
                if v.prefix_index is not None:
                    v.prefix_index.release_from(doomed)
        removed = self.allocator.shrink(extra_blocks)
        if removed:
            n = self.n_head_blocks - removed
            self.k = self.k[:n]
            self.v = self.v[:n]
            self.n_head_blocks = n
        return removed

    def shrinkable_tail(self) -> int:
        """Head-blocks a ``shrink`` could remove right now (free tail
        only) — what reconfig should consult instead of
        ``largest_free_range`` when planning capacity returns."""
        return self.allocator.shrinkable_tail()

    def available_blocks(self) -> int:
        """Free head-blocks plus prefix-cache inventory evictable on
        demand — the figure admission may count on.  Equals
        ``allocator.free_blocks`` when prefix caching is off."""
        n = self.allocator.free_blocks
        for v in self.views.values():
            if v.prefix_index is not None:
                n += v.prefix_index.evictable_blocks()
        return n

    def reclaim_index_blocks(self, need: int) -> int:
        """Evict prefix-cache entries (LRU-first, index-only holders)
        across views until ``need`` head-blocks are free.  Returns the
        blocks actually freed."""
        freed = 0
        for v in self.views.values():
            short = need - self.allocator.free_blocks
            if short <= 0:
                break
            if v.prefix_index is not None:
                freed += v.prefix_index.reclaim(short)
        return freed

    def prefix_stats(self) -> Dict[str, dict]:
        """Per-LLM prefix-cache counters (empty when caching is off)."""
        return {n: v.prefix_index.stats() for n, v in self.views.items()
                if v.prefix_index is not None}

    def tail_victims(self, n_lost: int) -> Dict[str, List[int]]:
        """Sequences whose cache touches the arena's last ``n_lost``
        head-blocks (fault injection: a bad HBM region eats the tail —
        serving/faults.py ``block_loss``).  A block group is a victim
        if ANY of its head-blocks lies in ``[n_blocks − n_lost,
        n_blocks)``; the whole sequence is torn down (partial KV is
        useless under paged attention).  Once every victim is evicted
        the doomed tail is entirely free, so ``shrink(n_lost)`` then
        removes exactly the lost blocks.  Returns {view name: [seq
        ids]} for the scheduler to evict at the engine level (engine
        eviction keeps slot/view/pool bookkeeping consistent)."""
        doomed = self.n_head_blocks - max(n_lost, 0)
        out: Dict[str, List[int]] = {}
        for name, v in self.views.items():
            if v.group_size == 0:
                continue            # SSM state lives off-arena
            ids = sorted(sid for sid, sc in v.seqs.items()
                         if any(b + v.group_size > doomed
                                for b in sc.bases))
            if ids:
                out[name] = ids
        return out

    def register_model(self, cfg: ModelConfig, quota: int) -> ModelCacheView:
        assert cfg.attn_free or cfg.hd == self.head_dim, \
            (f"pools are grouped by head_dim: model {cfg.name!r} has "
             f"head_dim {cfg.hd}, pool has {self.head_dim}")
        v = ModelCacheView(cfg, self, quota, prefix_cache=self.prefix_cache)
        self.views[cfg.name] = v
        self.used_by[cfg.name] = 0
        return v

    def unregister_model(self, name: str) -> None:
        """Drop a model's view (its sequences must already be freed or
        migrated away) — the source-pool half of an engine move.  The
        view's prefix index is cleared with it: every cached base the
        index alone held returns to the free list, so crash recovery
        can never leave a dangling index ref."""
        v = self.views.pop(name, None)
        self.used_by.pop(name, None)
        if v is not None and v.prefix_index is not None:
            v.prefix_index.clear()
        assert v is None or not v.seqs, \
            "unregistering a view with live sequences leaks pool blocks"

    def grant_min_quota(self, view: "ModelCacheView", need: int) -> bool:
        """Raise ``view``'s quota to at least ``need`` head-blocks by
        pulling spare quota (quota − used) from the other views,
        most-spare first.  Escape hatch for the scheduler when a
        queued request's lifetime no longer fits a quota that
        ``adapt_quotas`` shrank — without it the request would be
        re-queued forever.  Returns True if the target was reached.
        """
        if view.quota >= need:
            return True
        donors = sorted((v for v in self.views.values() if v is not view),
                        key=lambda v: v.quota - v.used, reverse=True)
        for d in donors:
            # leave one block-group of growth headroom per active
            # sequence so draining the donor doesn't immediately stall
            # its in-flight decodes into rollback/preemption
            margin = len(d.seqs) * d.group_size
            spare = max(0, d.quota - d.used - margin)
            take = min(spare, need - view.quota)
            if take > 0:
                d.quota -= take
                view.quota += take
            if view.quota >= need:
                return True
        return view.quota >= need

    # ---- ADBS quota adaptation (paper Alg. 3, last line) ---------------
    def adapt_quotas(self, min_quota: int = 64) -> None:
        """Move head-block quota from low- to high-utilization LLMs."""
        if len(self.views) < 2:
            return
        util = {n: (v.used / v.quota if v.quota else 1.0)
                for n, v in self.views.items()}
        lo = min(util, key=util.get)
        hi = max(util, key=util.get)
        if util[hi] - util[lo] < 0.2:
            return
        v_lo, v_hi = self.views[lo], self.views[hi]
        spare = v_lo.quota - v_lo.used
        move = min(spare // 2, self.n_head_blocks // 8)
        if move > 0 and v_lo.quota - move >= min_quota:
            v_lo.quota -= move
            v_hi.quota += move

    def utilization(self) -> float:
        return self.allocator.used / self.n_head_blocks


def migrate_view(src: ModelCacheView, dst_pool: "UnifiedKVPool",
                 quota: int) -> Tuple[ModelCacheView, int]:
    """Move one LLM's live cache between pools (engine/KV migration —
    the zero-downtime half of live reconfiguration, DESIGN.md §10).

    Every sequence keeps its identity: logical token-blocks are
    re-allocated in the destination arena, the KV pages are copied
    device-side (physical ids resolved through
    ``paging.resolve_physical_blocks`` — the SAME resolution every
    kernel uses, so the copy can never disagree with the pool layout),
    and the per-sequence bookkeeping (block tables, lengths, SSM state
    accounting) is rebuilt on a fresh ``ModelCacheView``.  In-flight
    decodes continue bit-identically off the new pool because the
    pages are exact copies and block tables are always re-resolved
    from the view at step time.  The source view is drained and
    unregistered.

    Shared prefix blocks migrate as shared: a group referenced by
    several sequences is allocated ONCE on the destination and
    ``share``d for every further holder (the src→dst base map keeps
    the sharing structure, and ``SeqCache.shared`` marks carry over so
    copy-on-write still triggers where it would have).  The prefix
    index is rebuilt on the destination from the same map — entries
    whose blocks a migrating sequence holds keep their hashes and
    refs; cache-only entries (no live holder) are deliberately
    dropped, so warm-cache state never inflates the capacity
    pre-check.

    Returns ``(dst_view, migrated_head_blocks)``.  Raises if the
    destination pool cannot hold the live cache (the caller sizes the
    move; nothing is freed on failure).
    """
    from repro.serving.cache_ops import copy_block_groups

    cfg = src.cfg
    assert dst_pool is not src.pool, "migrate_view needs two pools"
    assert dst_pool.block_tokens == src.pool.block_tokens \
        and dst_pool.head_dim == src.pool.head_dim \
        and dst_pool.dtype == src.pool.dtype, \
        "pools must share block geometry for a page-exact migration"
    # physical need = DISTINCT groups (shared bases land once)
    uniq = {b for sc in src.seqs.values() for b in sc.bases}
    need = len(uniq) * src.group_size
    if need > dst_pool.allocator.free_blocks:
        dst_pool.reclaim_index_blocks(need)   # cache blocks are disposable
    if need > dst_pool.allocator.free_blocks:
        raise RuntimeError(
            f"destination pool cannot hold migrated KV of {cfg.name}: "
            f"need {need} head-blocks, "
            f"free {dst_pool.allocator.free_blocks}")

    dst = dst_pool.register_model(cfg, quota)
    base_map: Dict[int, int] = {}
    refs_made: List[int] = []   # one entry per alloc/share, for rollback
    src_groups: List[int] = []
    dst_groups: List[int] = []
    for sid, sc in src.seqs.items():
        new_bases = []
        for b in sc.bases:
            nb = base_map.get(b)
            if nb is None:
                nb = dst_pool.allocator.alloc(dst.group_size)
                if nb is None and dst_pool.reclaim_index_blocks(
                        dst.group_size):
                    nb = dst_pool.allocator.alloc(dst.group_size)
                if nb is None:
                    # the free-space total passed the pre-check but no
                    # CONTIGUOUS group-size run is left (fragmentation
                    # from other views' churn) — roll the half-built
                    # destination back completely; the source is
                    # untouched until the copy below, so the caller
                    # can abort the move cleanly
                    for rb in refs_made:
                        dst_pool.allocator.free(rb, dst.group_size)
                    dst.seqs.clear()
                    dst.used = 0
                    dst_pool.unregister_model(cfg.name)
                    raise RuntimeError(
                        f"destination pool too fragmented for {cfg.name}: "
                        f"no contiguous {dst.group_size}-block run "
                        f"(free {dst_pool.allocator.free_blocks}, largest "
                        f"run {dst_pool.allocator.largest_free_range()})")
                base_map[b] = nb
                src_groups.append(b)
                dst_groups.append(nb)
            else:
                dst_pool.allocator.share(nb, dst.group_size)
            refs_made.append(nb)
            new_bases.append(nb)
        dst.seqs[sid] = SeqCache(sid, new_bases, sc.n_tokens,
                                 shared=sc.shared)
        used = len(new_bases) * dst.group_size
        if cfg.ssm and sid in src._started:
            used += dst._ssm_blocks_per_seq
        dst.used += used
    dst._started = set(src._started)
    dst.quota = max(dst.quota, dst.used)
    dst_pool.used_by[cfg.name] = dst.used

    # rebuild the prefix index under the remap (LRU order preserved);
    # the hash chain is content-addressed, so hashes carry unchanged
    if src.prefix_index is not None and dst.prefix_index is not None:
        for h, (b, blk) in src.prefix_index.entries():
            nb = base_map.get(b)
            if nb is not None:
                dst.prefix_index.adopt(h, nb, blk)

    migrated = 0
    if src_groups:
        # each distinct group is copied exactly once, elementwise
        # aligned src→dst through the same physical resolution every
        # kernel uses (cache_ops.copy_block_groups)
        dst_pool.k, dst_pool.v = copy_block_groups(
            dst_pool.k, dst_pool.v, src_groups, dst_groups,
            cfg.n_kv_heads, cfg.n_attn_layers,
            src_k=src.pool.k, src_v=src.pool.v)
        migrated = len(src_groups) * src.group_size

    for sid in list(src.seqs):
        src.free_seq(sid)
    src.pool.unregister_model(cfg.name)
    return dst, migrated
