"""Unified head-wise KV cache pool (paper §3.4).

The pool is a single arena of *head-blocks*: each block holds
``BLOCK_TOKENS`` tokens of one KV head (``[BLOCK_TOKENS, head_dim]``).
Because the block shape is model-independent (head_dim is uniform across
the colocated LLMs — 128 for LLaMA/GPT-3 per the paper; we check and
group pools by head_dim), LLMs of different depths/head-counts share one
memory space.  ADBS enforces per-LLM head-block quotas and re-allocates
them at runtime (paper Alg. 3).

Allocation granularity: within one LLM, a logical *token block* (16
tokens of one sequence) needs ``n_layers × n_kv_heads`` head-blocks; we
allocate them as one contiguous range ("group") so the device-side
block table is a single base id per token block and the physical index
is ``base + layer*KV + head``.  Sharing between models remains at
head-block granularity (groups of different sizes draw from the same
free space); freeing coalesces ranges, so external fragmentation is
bounded by group size at range boundaries (measured in tests).

SSM models store their constant-size state separately (state is O(1)
per sequence — paging adds nothing); their token-block usage for ADBS
quota accounting is computed from the state footprint.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import BLOCK_TOKENS, ModelConfig


class BlockAllocator:
    """First-fit contiguous range allocator over head-blocks (host side).

    Free space kept as a sorted list of ``[start, end)`` ranges.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[Tuple[int, int]] = [(0, n_blocks)]
        self.used = 0

    def alloc(self, n: int) -> Optional[int]:
        for i, (s, e) in enumerate(self._free):
            if e - s >= n:
                if e - s == n:
                    self._free.pop(i)
                else:
                    self._free[i] = (s + n, e)
                self.used += n
                return s
        return None

    def free(self, start: int, n: int) -> None:
        if n <= 0:
            return
        self.used -= n
        new = (start, start + n)
        i = bisect.bisect_left(self._free, new)
        self._free.insert(i, new)
        # coalesce neighbours
        merged: List[Tuple[int, int]] = []
        for s, e in self._free:
            if merged and s <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        self._free = merged

    def grow(self, n: int) -> None:
        """Extend the arena by ``n`` head-blocks of new free space
        (zero-copy weight de-dup grants reclaimed HBM back to the
        pool — see UnifiedKVPool.grow)."""
        if n <= 0:
            return
        start = self.n_blocks
        self.n_blocks += n
        if self._free and self._free[-1][1] == start:
            self._free[-1] = (self._free[-1][0], start + n)
        else:
            self._free.append((start, start + n))

    def shrink(self, n: int) -> int:
        """Remove up to ``n`` head-blocks from the END of the arena.

        The inverse of ``grow``: only entirely-free tail space is
        released — in-use blocks are never reclaimed, so a shrink that
        would cut below a live allocation is clamped to the free tail
        (possibly 0).  When the tail is idle, ``shrink(n)`` after
        ``grow(n)`` restores the arena exactly.  Returns the number of
        blocks actually removed.
        """
        if n <= 0:
            return 0
        take = 0
        if self._free and self._free[-1][1] == self.n_blocks:
            s, e = self._free[-1]
            take = min(n, e - s)
            if take == e - s:
                self._free.pop()
            else:
                self._free[-1] = (s, e - take)
        self.n_blocks -= take
        return take

    @property
    def free_blocks(self) -> int:
        return self.n_blocks - self.used

    def largest_free_range(self) -> int:
        return max((e - s for s, e in self._free), default=0)

    def fragmentation(self) -> float:
        """1 − largest_free/total_free (0 = one contiguous free range)."""
        if self.free_blocks == 0:
            return 0.0
        return 1.0 - self.largest_free_range() / self.free_blocks


@dataclass
class SeqCache:
    """Host-side bookkeeping for one sequence's cache."""
    seq_id: int
    bases: List[int] = field(default_factory=list)   # group base per token-block
    n_tokens: int = 0


class ModelCacheView:
    """Per-LLM adapter onto the shared pool.

    Tracks quota (head-blocks) granted by ADBS and per-sequence block
    tables.  ``group_size = n_layers × n_kv_heads`` head-blocks per
    token block (attention models); SSM models have group_size 0 and a
    fixed per-seq state cost (accounted against quota, not the arena).
    """

    def __init__(self, cfg: ModelConfig, pool: "UnifiedKVPool", quota: int):
        self.cfg = cfg
        self.pool = pool
        self.quota = quota
        self.used = 0
        self.group_size = cfg.n_attn_layers * cfg.n_kv_heads
        self.seqs: Dict[int, SeqCache] = {}
        self._started: set = set()
        # SSM quota accounting: state bytes expressed in head-block units
        self._ssm_blocks_per_seq = 0
        if cfg.ssm:
            state_bytes = (cfg.n_ssm_layers * cfg.n_ssm_heads
                           * cfg.ssm.head_dim * cfg.ssm.d_state * 4)
            self._ssm_blocks_per_seq = max(
                1, state_bytes // pool.head_block_bytes)

    # ---- quota ------------------------------------------------------
    def quota_headroom(self) -> int:
        return self.quota - self.used

    def can_append(self, seq_id: int, n_tokens: int) -> bool:
        return self._blocks_needed(seq_id, n_tokens) <= min(
            self.quota_headroom(), self.pool.allocator.free_blocks)

    def _blocks_needed(self, seq_id: int, n_tokens: int) -> int:
        sc = self.seqs.get(seq_id)
        have = len(sc.bases) * BLOCK_TOKENS if sc else 0
        cur = sc.n_tokens if sc else 0
        need_tokens = max(0, cur + n_tokens - have)
        n_groups = -(-need_tokens // BLOCK_TOKENS)
        cost = n_groups * self.group_size
        if sc is None and self.cfg.ssm:
            cost += self._ssm_blocks_per_seq
        return cost

    # ---- allocation ---------------------------------------------------
    def append_tokens(self, seq_id: int, n_tokens: int) -> bool:
        """Reserve cache space for n_tokens more tokens of seq_id."""
        cost = self._blocks_needed(seq_id, n_tokens)
        if cost > self.quota_headroom():
            return False
        sc = self.seqs.setdefault(seq_id, SeqCache(seq_id))
        have = len(sc.bases) * BLOCK_TOKENS
        need_tokens = max(0, sc.n_tokens + n_tokens - have)
        n_groups = -(-need_tokens // BLOCK_TOKENS)
        newly = []
        for _ in range(n_groups):
            if self.group_size > 0:
                base = self.pool.allocator.alloc(self.group_size)
                if base is None:
                    for b in newly:   # roll back
                        self.pool.allocator.free(b, self.group_size)
                    return False
                newly.append(base)
        sc.bases.extend(newly)
        sc.n_tokens += n_tokens
        extra = n_groups * self.group_size
        if seq_id not in self._started and self.cfg.ssm:
            extra += self._ssm_blocks_per_seq
        self._started.add(seq_id)
        self.used += extra
        self.pool.used_by[self.cfg.name] = self.used
        return True

    def free_seq(self, seq_id: int) -> None:
        sc = self.seqs.pop(seq_id, None)
        if sc is None:
            return
        for b in sc.bases:
            self.pool.allocator.free(b, self.group_size)
        freed = len(sc.bases) * self.group_size
        if self.cfg.ssm and seq_id in self._started:
            freed += self._ssm_blocks_per_seq
        self._started.discard(seq_id)
        self.used -= freed
        self.pool.used_by[self.cfg.name] = self.used

    # ---- device-side tables -------------------------------------------
    def block_table(self, seq_ids: List[int], max_blocks: int) -> np.ndarray:
        """[len(seq_ids), max_blocks] int32 group bases (−1 padded)."""
        t = np.full((len(seq_ids), max_blocks), -1, np.int32)
        for i, sid in enumerate(seq_ids):
            bases = self.seqs[sid].bases[:max_blocks]
            t[i, :len(bases)] = bases
        return t

    def seq_lens(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self.seqs[s].n_tokens for s in seq_ids], np.int32)


def fused_block_tables(views_seqs: List[Tuple["ModelCacheView", List[int]]],
                       rows: int, max_blocks: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Combined block-table assembly for the fused multi-LLM decode tick
    (DESIGN.md §2): each colocated model's per-sequence tables are
    resolved by its own ``ModelCacheView`` against the shared arena,
    then padded to a common ``rows × max_blocks`` shape so one jitted
    step can consume every model's rows at once.

    Returns ``(tables [M, rows, max_blocks] int32, lens [M, rows]
    int32)``.  Padded table entries are −1 (KV writes drop, attention
    masks); padded lens are 1 so the fused attention sweep reads a
    single masked position instead of an empty range.
    """
    M = len(views_seqs)
    tables = np.full((M, rows, max_blocks), -1, np.int32)
    lens = np.ones((M, rows), np.int32)
    for m, (view, seq_ids) in enumerate(views_seqs):
        b = len(seq_ids)
        tables[m, :b] = view.block_table(seq_ids, max_blocks)
        lens[m, :b] = view.seq_lens(seq_ids)
    return tables, lens


class UnifiedKVPool:
    """The shared device arena + host allocator for one LLM unit."""

    def __init__(self, n_head_blocks: int, head_dim: int,
                 dtype=jnp.bfloat16, block_tokens: int = BLOCK_TOKENS):
        self.n_head_blocks = n_head_blocks
        self.head_dim = head_dim
        self.block_tokens = block_tokens
        self.dtype = dtype
        self.k = jnp.zeros((n_head_blocks, block_tokens, head_dim), dtype)
        self.v = jnp.zeros((n_head_blocks, block_tokens, head_dim), dtype)
        self.allocator = BlockAllocator(n_head_blocks)
        self.views: Dict[str, ModelCacheView] = {}
        self.used_by: Dict[str, int] = {}

    @property
    def head_block_bytes(self) -> int:
        return 2 * self.block_tokens * self.head_dim * self.dtype_bytes

    @property
    def dtype_bytes(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    def hbm_bytes(self) -> int:
        """Device bytes held by the arena (k + v)."""
        return int(self.k.nbytes) + int(self.v.nbytes)

    def grow(self, extra_blocks: int) -> int:
        """Extend the arena by ``extra_blocks`` head-blocks.

        The zero-copy stacked-weights scheme (DESIGN.md §2) frees one
        full weight copy per fused group; those bytes are granted back
        to the pool here — the paper's memory-multiplexing argument in
        reverse: reclaimed weight HBM becomes KV head-blocks, which
        admit more sequences.  Returns the blocks actually added.
        """
        if extra_blocks <= 0:
            return 0
        n = self.n_head_blocks + extra_blocks
        if self.allocator.used == 0:
            # no sequence holds blocks, so arena contents are dead —
            # reallocate at the final size instead of concatenating
            # (which would transiently hold 2× the arena)
            self.k = jnp.zeros((n, self.block_tokens, self.head_dim),
                               self.dtype)
            self.v = jnp.zeros((n, self.block_tokens, self.head_dim),
                               self.dtype)
        else:
            pad = jnp.zeros((extra_blocks, self.block_tokens,
                             self.head_dim), self.dtype)
            self.k = jnp.concatenate([self.k, pad])
            self.v = jnp.concatenate([self.v, jnp.zeros_like(pad)])
        self.allocator.grow(extra_blocks)
        self.n_head_blocks = n
        return extra_blocks

    def shrink(self, extra_blocks: int) -> int:
        """Release up to ``extra_blocks`` head-blocks from the arena
        tail — the inverse of ``grow`` (live reconfiguration dissolves
        a fused group and returns its zero-copy grant before the
        members re-materialize private weight copies; DESIGN.md §10).
        Only free tail space is released — the allocator refuses to
        cut below in-use blocks — so the returned count may be smaller
        than requested.  Returns the blocks actually removed.
        """
        removed = self.allocator.shrink(extra_blocks)
        if removed:
            n = self.n_head_blocks - removed
            self.k = self.k[:n]
            self.v = self.v[:n]
            self.n_head_blocks = n
        return removed

    def tail_victims(self, n_lost: int) -> Dict[str, List[int]]:
        """Sequences whose cache touches the arena's last ``n_lost``
        head-blocks (fault injection: a bad HBM region eats the tail —
        serving/faults.py ``block_loss``).  A block group is a victim
        if ANY of its head-blocks lies in ``[n_blocks − n_lost,
        n_blocks)``; the whole sequence is torn down (partial KV is
        useless under paged attention).  Once every victim is evicted
        the doomed tail is entirely free, so ``shrink(n_lost)`` then
        removes exactly the lost blocks.  Returns {view name: [seq
        ids]} for the scheduler to evict at the engine level (engine
        eviction keeps slot/view/pool bookkeeping consistent)."""
        doomed = self.n_head_blocks - max(n_lost, 0)
        out: Dict[str, List[int]] = {}
        for name, v in self.views.items():
            if v.group_size == 0:
                continue            # SSM state lives off-arena
            ids = sorted(sid for sid, sc in v.seqs.items()
                         if any(b + v.group_size > doomed
                                for b in sc.bases))
            if ids:
                out[name] = ids
        return out

    def register_model(self, cfg: ModelConfig, quota: int) -> ModelCacheView:
        assert cfg.attn_free or cfg.hd == self.head_dim or True, \
            "pools are grouped by head_dim"
        v = ModelCacheView(cfg, self, quota)
        self.views[cfg.name] = v
        self.used_by[cfg.name] = 0
        return v

    def unregister_model(self, name: str) -> None:
        """Drop a model's view (its sequences must already be freed or
        migrated away) — the source-pool half of an engine move."""
        v = self.views.pop(name, None)
        self.used_by.pop(name, None)
        assert v is None or not v.seqs, \
            "unregistering a view with live sequences leaks pool blocks"

    def grant_min_quota(self, view: "ModelCacheView", need: int) -> bool:
        """Raise ``view``'s quota to at least ``need`` head-blocks by
        pulling spare quota (quota − used) from the other views,
        most-spare first.  Escape hatch for the scheduler when a
        queued request's lifetime no longer fits a quota that
        ``adapt_quotas`` shrank — without it the request would be
        re-queued forever.  Returns True if the target was reached.
        """
        if view.quota >= need:
            return True
        donors = sorted((v for v in self.views.values() if v is not view),
                        key=lambda v: v.quota - v.used, reverse=True)
        for d in donors:
            # leave one block-group of growth headroom per active
            # sequence so draining the donor doesn't immediately stall
            # its in-flight decodes into rollback/preemption
            margin = len(d.seqs) * d.group_size
            spare = max(0, d.quota - d.used - margin)
            take = min(spare, need - view.quota)
            if take > 0:
                d.quota -= take
                view.quota += take
            if view.quota >= need:
                return True
        return view.quota >= need

    # ---- ADBS quota adaptation (paper Alg. 3, last line) ---------------
    def adapt_quotas(self, min_quota: int = 64) -> None:
        """Move head-block quota from low- to high-utilization LLMs."""
        if len(self.views) < 2:
            return
        util = {n: (v.used / v.quota if v.quota else 1.0)
                for n, v in self.views.items()}
        lo = min(util, key=util.get)
        hi = max(util, key=util.get)
        if util[hi] - util[lo] < 0.2:
            return
        v_lo, v_hi = self.views[lo], self.views[hi]
        spare = v_lo.quota - v_lo.used
        move = min(spare // 2, self.n_head_blocks // 8)
        if move > 0 and v_lo.quota - move >= min_quota:
            v_lo.quota -= move
            v_hi.quota += move

    def utilization(self) -> float:
        return self.allocator.used / self.n_head_blocks


def migrate_view(src: ModelCacheView, dst_pool: "UnifiedKVPool",
                 quota: int) -> Tuple[ModelCacheView, int]:
    """Move one LLM's live cache between pools (engine/KV migration —
    the zero-downtime half of live reconfiguration, DESIGN.md §10).

    Every sequence keeps its identity: logical token-blocks are
    re-allocated in the destination arena, the KV pages are copied
    device-side (physical ids resolved through
    ``paging.resolve_physical_blocks`` — the SAME resolution every
    kernel uses, so the copy can never disagree with the pool layout),
    and the per-sequence bookkeeping (block tables, lengths, SSM state
    accounting) is rebuilt on a fresh ``ModelCacheView``.  In-flight
    decodes continue bit-identically off the new pool because the
    pages are exact copies and block tables are always re-resolved
    from the view at step time.  The source view is drained and
    unregistered.

    Returns ``(dst_view, migrated_head_blocks)``.  Raises if the
    destination pool cannot hold the live cache (the caller sizes the
    move; nothing is freed on failure).
    """
    import jax.numpy as jnp

    from repro.paging import resolve_physical_blocks

    cfg = src.cfg
    assert dst_pool is not src.pool, "migrate_view needs two pools"
    assert dst_pool.block_tokens == src.pool.block_tokens \
        and dst_pool.head_dim == src.pool.head_dim \
        and dst_pool.dtype == src.pool.dtype, \
        "pools must share block geometry for a page-exact migration"
    n_groups = sum(len(sc.bases) for sc in src.seqs.values())
    if n_groups * src.group_size > dst_pool.allocator.free_blocks:
        raise RuntimeError(
            f"destination pool cannot hold migrated KV of {cfg.name}: "
            f"need {n_groups * src.group_size} head-blocks, "
            f"free {dst_pool.allocator.free_blocks}")

    dst = dst_pool.register_model(cfg, quota)
    src_bases: List[int] = []
    dst_bases: List[int] = []
    for sid, sc in src.seqs.items():
        new_bases = []
        for _ in sc.bases:
            nb = dst_pool.allocator.alloc(dst.group_size)
            if nb is None:
                # the free-space total passed the pre-check but no
                # CONTIGUOUS group-size run is left (fragmentation from
                # other views' churn) — roll the half-built destination
                # back completely; the source is untouched until the
                # copy below, so the caller can abort the move cleanly
                for b in new_bases + dst_bases:
                    dst_pool.allocator.free(b, dst.group_size)
                dst.seqs.clear()
                dst.used = 0
                dst_pool.unregister_model(cfg.name)
                raise RuntimeError(
                    f"destination pool too fragmented for {cfg.name}: "
                    f"no contiguous {dst.group_size}-block run "
                    f"(free {dst_pool.allocator.free_blocks}, largest "
                    f"run {dst_pool.allocator.largest_free_range()})")
            new_bases.append(nb)
        dst.seqs[sid] = SeqCache(sid, new_bases, sc.n_tokens)
        src_bases.extend(sc.bases)
        dst_bases.extend(new_bases)
        used = len(new_bases) * dst.group_size
        if cfg.ssm and sid in src._started:
            used += dst._ssm_blocks_per_seq
        dst.used += used
    dst._started = set(src._started)
    dst.quota = max(dst.quota, dst.used)
    dst_pool.used_by[cfg.name] = dst.used

    migrated = 0
    if src_bases:
        # resolve logical group bases to physical head-block ids layer
        # by layer — elementwise aligned between source and destination
        # tables, so the gather/scatter below is an exact page copy
        st = jnp.asarray(np.array([src_bases], np.int32))
        dt = jnp.asarray(np.array([dst_bases], np.int32))
        kv, n_l = cfg.n_kv_heads, cfg.n_attn_layers
        sp = jnp.concatenate([resolve_physical_blocks(st, li, kv)
                              for li in range(n_l)], axis=1).reshape(-1)
        dp = jnp.concatenate([resolve_physical_blocks(dt, li, kv)
                              for li in range(n_l)], axis=1).reshape(-1)
        dst_pool.k = dst_pool.k.at[dp].set(src.pool.k[sp])
        dst_pool.v = dst_pool.v.at[dp].set(src.pool.v[sp])
        migrated = int(sp.shape[0])

    for sid in list(src.seqs):
        src.free_seq(sid)
    src.pool.unregister_model(cfg.name)
    return dst, migrated
