"""granite-moe-3b-a800m — MoE decoder, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family] 32L, d_model 1536,
24 heads, 8 kv heads, per-expert d_ff 512, vocab 49155, 32 experts
top-8.  (The assignment header says "40e"; the explicit note and the
granite model card family say 32 experts — we follow the note, recorded
in DESIGN.md §4.)
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                      # per-expert hidden size
    vocab_size=49155,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

REDUCED = ModelConfig(
    name="granite-moe-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
    source="reduced smoke variant",
)
