"""phi-3-vision-4.2b — VLM: phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct] Backbone: 32L, d_model 3072,
32 heads (kv=32, MHA), d_ff 8192, vocab 32064.  The CLIP/ViT vision
encoder + projector is a STUB — ``input_specs`` provides precomputed
patch embeddings [batch, n_patches, d_model] consumed as prefix tokens.
Full attention only → ``long_500k`` skipped (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    frontend_dim=3072,             # projected CLIP patch embeddings (stub)
    n_prefix_tokens=576,           # 24×24 patches
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

REDUCED = ModelConfig(
    name="phi-3-vision-reduced",
    family="vlm",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    frontend_dim=256,
    n_prefix_tokens=16,
    source="reduced smoke variant",
)
