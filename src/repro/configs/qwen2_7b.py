"""qwen2-7b — dense GQA decoder with QKV bias.

[arXiv:2407.10671] Qwen2-7B: 28L, d_model 3584, 28 heads, 4 kv heads,
d_ff 18944, vocab 152064.  QKV bias on.  A sliding-window decode
variant (window 4096) is provided so this dense arch also exercises
``long_500k`` (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    sliding_window=4096,            # long_500k windowed-decode variant
    source="arXiv:2407.10671 (Qwen2-7B)",
)

REDUCED = ModelConfig(
    name="qwen2-7b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    qkv_bias=True,
    sliding_window=64,
    source="reduced smoke variant",
)
