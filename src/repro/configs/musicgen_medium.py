"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] MusicGen medium: 48L, d_model 1536, 24 heads
(kv=24, i.e. MHA), d_ff 6144, vocab 2048 (one EnCodec codebook; the
delay-pattern interleave of the 4 codebooks happens upstream of the
backbone).  The audio frontend (EnCodec conv codec) is a STUB —
``input_specs`` provides precomputed frame embeddings.  A sliding-window
decode variant (window 4096) provides the sub-quadratic path for
``long_500k`` (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    rope_theta=1e4,
    sliding_window=4096,            # used only by the long_500k shape
    frontend_dim=1536,              # EnCodec frame embeddings (stub)
    n_prefix_tokens=256,
    source="arXiv:2306.05284 (MusicGen medium)",
)

REDUCED = ModelConfig(
    name="musicgen-medium-reduced",
    family="audio",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    sliding_window=64,
    frontend_dim=256,
    n_prefix_tokens=8,
    source="reduced smoke variant",
)
