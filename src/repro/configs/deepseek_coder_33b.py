"""deepseek-coder-33b — dense llama-architecture decoder.

[arXiv:2401.14196] DeepSeek-Coder-33B: 62L, d_model 7168, 56 heads,
8 kv heads (GQA), d_ff 19200, vocab 32256.  Full attention only →
``long_500k`` skipped (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=1e5,
    source="arXiv:2401.14196 (DeepSeek-Coder-33B)",
)

REDUCED = ModelConfig(
    name="deepseek-coder-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    source="reduced smoke variant",
)
