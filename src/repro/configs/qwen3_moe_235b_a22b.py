"""qwen3-moe-235b-a22b — large MoE, 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B family, 235B-A22B point] 94L, d_model 4096,
64 heads, 4 kv heads, per-expert d_ff 1536, vocab 151936, 128 experts
top-8, qk_norm.  Full attention only → ``long_500k`` skipped
(DESIGN.md §4).
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                     # per-expert hidden size
    vocab_size=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    source="hf:Qwen/Qwen3-30B-A3B (Qwen3-MoE family, 235B-A22B point)",
)

REDUCED = ModelConfig(
    name="qwen3-moe-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    head_dim=64,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
    source="reduced smoke variant",
)
