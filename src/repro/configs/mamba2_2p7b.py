"""mamba2-2.7b — pure SSM (SSD, state-space duality), attention-free.

[arXiv:2405.21060] Mamba2-2.7B: 64 layers, d_model 2560, d_inner 5120,
SSM head_dim 64 (80 heads), d_state 128, vocab 50280.  No attention →
the paper's paged-KV machinery is replaced by the fixed-size SSM state
cache in the unified pool (DESIGN.md §4); ``long_500k`` runs natively
(O(1) decode state).
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128),
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2-2.7B)",
)

REDUCED = ModelConfig(
    name="mamba2-reduced",
    family="ssm",
    n_layers=2,
    d_model=256,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm=SSMConfig(d_state=16, chunk_size=32),
    tie_embeddings=True,
    source="reduced smoke variant",
)
