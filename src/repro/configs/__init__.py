"""Assigned architecture configs (one module per architecture).

Every config cites its source in ``ModelConfig.source``.  Use
``repro.configs.get(name)`` or ``repro.models.registry``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "musicgen_medium",
    "qwen2_7b",
    "granite_moe_3b_a800m",
    "zamba2_1p2b",
    "qwen3_14b",
    "phi_3_vision_4p2b",
    "command_r_plus_104b",
    "mamba2_2p7b",
    "qwen3_moe_235b_a22b",
    "deepseek_coder_33b",
]

# canonical dashed ids (as given in the assignment) → module names
ALIASES = {
    "musicgen-medium": "musicgen_medium",
    "qwen2-7b": "qwen2_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen3-14b": "qwen3_14b",
    "phi-3-vision-4.2b": "phi_3_vision_4p2b",
    "command-r-plus-104b": "command_r_plus_104b",
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-coder-33b": "deepseek_coder_33b",
}


def get(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
