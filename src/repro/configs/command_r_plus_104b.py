"""command-r-plus-104b — large dense GQA decoder, no biases.

[hf:CohereForAI/c4ai-command-r-v01 family] 64L, d_model 12288, 96 heads,
8 kv heads, d_ff 33792, vocab 256000, no bias, tied embeddings
(Cohere ties input/output embeddings).  Full attention only →
``long_500k`` skipped (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    tie_embeddings=True,
    rope_theta=75e4,
    source="hf:CohereForAI/c4ai-command-r-v01 (R+ 104B point)",
)

REDUCED = ModelConfig(
    name="command-r-plus-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    tie_embeddings=True,
    source="reduced smoke variant",
)
