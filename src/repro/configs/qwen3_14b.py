"""qwen3-14b — dense GQA decoder with QK-norm.

[hf:Qwen/Qwen3-8B family] Qwen3-14B: 40L, d_model 5120, 40 heads,
8 kv heads, d_ff 17408, vocab 151936, qk_norm, no attention bias.
Full attention only → ``long_500k`` skipped (DESIGN.md §4).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (Qwen3 family, 14B point)",
)

REDUCED = ModelConfig(
    name="qwen3-14b-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    qk_norm=True,
    source="reduced smoke variant",
)
