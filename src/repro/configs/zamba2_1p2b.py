"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242] Zamba2-1.2B: 38 Mamba2 layers, d_model 2048, with a
*shared* transformer block (32 heads MHA, d_ff 8192) applied every 6
layers; ssm_state 64.  We model the shared block with tied weights
(Zamba2's per-use LoRA deltas are omitted — noted in DESIGN.md §4).
The attention blocks use a sliding-window KV cache (window 4096) in the
``long_500k`` shape so the cache stays bounded.
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64),
    attn_every=6,
    shared_attn=True,
    sliding_window=4096,
    source="arXiv:2411.15242 (Zamba2-1.2B)",
)

REDUCED = ModelConfig(
    name="zamba2-reduced",
    family="hybrid",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    ssm=SSMConfig(d_state=16, chunk_size=32),
    attn_every=2,
    shared_attn=True,
    sliding_window=64,
    source="reduced smoke variant",
)
