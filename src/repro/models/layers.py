"""Core transformer building blocks — pure JAX, explicit param pytrees.

Parameters are plain nested dicts of ``jnp.ndarray``.  Per-layer weights
are *stacked* on a leading layer axis so the model forward is a single
``jax.lax.scan`` over layers (keeps the HLO small — essential for the
512-device dry-run compiles).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# activation-sharding hygiene
# ---------------------------------------------------------------------------
def _mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:                                    # noqa: BLE001
        return ()
    if mesh is None or not mesh.axis_names:
        return ()
    return tuple(mesh.axis_names)


# global sharding policy knobs (set per lowering by launch/specs.py):
#   attn_tp   — shard attention heads / MLP hidden on the model axis
#               (Megatron TP).  Off for MoE-EP layouts where the model
#               axis belongs to the experts and attention runs
#               data-parallel (§Perf, qwen3-moe train).
#   seq_shard — sequence-shard the residual stream between layers
#               (Megatron-SP).  Off when attention is data-parallel
#               (no TP collectives to amortize; the AG/RS ping-pong
#               would be pure overhead).
_POLICY = {"attn_tp": True, "seq_shard": True}


def set_sharding_policy(**kw) -> dict:
    old = dict(_POLICY)
    for k, v in kw.items():
        assert k in _POLICY, k
        _POLICY[k] = v
    return old


def model_axis_size() -> int:
    """Size of the 'model' mesh axis in the current tracing context
    (1 outside a mesh — CPU tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return 1
        return int(mesh.shape["model"])
    except Exception:                                    # noqa: BLE001
        return 1


def constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """``with_sharding_constraint`` filtered to axes present in the
    current abstract mesh (no-op on CPU/1-device runs).  Each entry is
    an axis name, a tuple of names, or None."""
    present = set(_mesh_axes())
    if not present:
        return x

    def keep(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            t = tuple(x_ for x_ in a if x_ in present)
            return t if t else None
        return a if a in present else None

    spec = [keep(a) for a in axes]
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def shard_activation(x: jnp.ndarray, *, last: str | None = None,
                     seq: str | None = None) -> jnp.ndarray:
    """Constrain an activation to batch-on-(pod,data) [+ seq/last dims].

    Without these constraints GSPMD occasionally re-shards the residual
    stream onto the model axis with the batch replicated — measured at
    24 GiB/device of stacked residuals on phi-3 train_4k (EXPERIMENTS.md
    §Perf).  ``seq="model"`` additionally shards dim 1 (sequence
    parallelism for the residual stream between layers — Megatron-SP
    style; GSPMD inserts the all-gather before attention and the
    reduce-scatter after).  No-op outside a mesh context (CPU tests see
    1 device).
    """
    axes = _mesh_axes()
    if not axes:
        return x
    batch = tuple(a for a in ("pod", "data") if a in axes)
    if not batch:
        return x
    spec = [batch] + [None] * (x.ndim - 1)
    if seq is not None and seq in axes and x.ndim >= 3 \
            and x.shape[1] % 16 == 0 and _POLICY["seq_shard"]:
        spec[1] = seq
    if last is not None and last in axes:
        spec[-1] = last
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (XLA reference path; the Pallas kernels mirror these semantics)
# ---------------------------------------------------------------------------
def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """[B, S, KV, hd] -> [B, S, KV*n_rep, hd] (GQA broadcast)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return x.reshape(b, s, kv * n_rep, hd)


def causal_attention(q, k, v, *, window: Optional[int] = None,
                     q_offset: int = 0) -> jnp.ndarray:
    """Plain causal attention.  q: [B,Sq,H,hd], k/v: [B,Sk,KV,hd].

    ``q_offset`` positions q tokens at ``q_offset + arange(Sq)`` in the
    kv timeline (used for chunked prefill).  ``window``: sliding window.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    k = repeat_kv(k, h // kvh)
    v = repeat_kv(v, h // kvh)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blocked_causal_attention(q, k, v, *, block_q: int = 512,
                             block_k: int = 1024,
                             window: Optional[int] = None) -> jnp.ndarray:
    """Memory-bounded causal attention: online-softmax over kv blocks.

    Pure-jnp flash attention — the oracle for ``kernels/flash_prefill``
    and the XLA fallback used in dry-run lowering (keeps the 32k×32k
    score matrix out of the memory analysis).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if s % block_q != 0 or s % block_k != 0:
        return causal_attention(q, k, v, window=window)
    n_rep = h // kvh
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(b, nq, block_q, h, hd)
    kb = k.reshape(b, nk, block_k, kvh, hd)
    vb = v.reshape(b, nk, block_k, kvh, hd)

    def per_qblock(qi, q_blk):
        # online softmax accumulation over kv blocks
        m0 = jnp.full((b, h, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)

        @jax.checkpoint
        def body(carry, ki):
            # checkpointed: without it the scan's backward saves every
            # [b,h,block_q,block_k] f32 probability block — the full
            # S×S matrix in aggregate (32 GiB/device/layer at 4k×256,
            # measured in the dry-run).  Recompute-per-block is the
            # flash-attention backward strategy.
            m, l, acc = carry
            k_blk = repeat_kv(kb[:, ki], n_rep)          # [b,block_k,h,hd]
            v_blk = repeat_kv(vb[:, ki], n_rep)
            s_ij = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            q_pos = qi * block_q + jnp.arange(block_q)[:, None]
            k_pos = ki * block_k + jnp.arange(block_k)[None, :]
            mask = k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            s_ij = jnp.where(mask[None, None], s_ij, -1e30)
            m_new = jnp.maximum(m, s_ij.max(-1))
            p = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        # only kv blocks that intersect the causal/window mask matter;
        # keep the scan over all blocks (masked) for a static shape.
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype).transpose(0, 2, 1, 3)   # [b,block_q,h,hd]

    outs = jax.lax.map(lambda qi: per_qblock(qi, qb[:, qi]), jnp.arange(nq))
    # outs: [nq, b, block_q, h, hd] -> [b, s, h, hd]
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# attention block params
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ModelConfig, n_layers: int, dtype=jnp.bfloat16) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    sco = 1.0 / math.sqrt(h * hd)
    L = n_layers
    p = {
        "wq": jax.random.normal(ks[0], (L, d, h * hd), dtype) * sc,
        "wk": jax.random.normal(ks[1], (L, d, kv * hd), dtype) * sc,
        "wv": jax.random.normal(ks[2], (L, d, kv * hd), dtype) * sc,
        "wo": jax.random.normal(ks[3], (L, h * hd, d), dtype) * sco,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, h * hd), dtype)
        p["bk"] = jnp.zeros((L, kv * hd), dtype)
        p["bv"] = jnp.zeros((L, kv * hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, hd), dtype)
        p["k_norm"] = jnp.ones((L, hd), dtype)
    return p


def attn_qkv(x, p, li, cfg: ModelConfig, positions):
    """Project to q/k/v (+bias, qk_norm, rope).  x: [B,S,d]."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"][li]
    k = x @ p["wk"][li]
    v = x @ p["wv"][li]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"][li], k + p["bk"][li], v + p["bv"][li]
    hx = "model" if _POLICY["attn_tp"] else None
    q = constrain(q.reshape(b, s, h, hd), ("pod", "data"), None, hx, None)
    k = constrain(k.reshape(b, s, kv, hd), ("pod", "data"), None, hx, None)
    v = constrain(v.reshape(b, s, kv, hd), ("pod", "data"), None, hx, None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"][li], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"][li], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# dense SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, n_layers: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(ks[0], (n_layers, d, f), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(ks[1], (n_layers, d, f), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(ks[2], (n_layers, f, d), dtype) / math.sqrt(f),
    }


def mlp(x, p, li):
    # Megatron TP: the hidden dim rides the model axis with the batch
    # on (pod,data) — without this constraint GSPMD keeps the residual
    # stream's sequence sharding and fully replicates w_down instead
    # (1.55 GiB f32 × live-set on command-r train, EXPERIMENTS §Perf)
    h = jax.nn.silu(x @ p["w_gate"][li]) * (x @ p["w_up"][li])
    last = "model" if _POLICY["attn_tp"] else None
    spec = [("pod", "data")] + [None] * (h.ndim - 2) + [last]
    h = constrain(h, *spec)
    return h @ p["w_down"][li]


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig, v_padded: int, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    p = {
        "embed": jax.random.normal(ks[0], (v_padded, d), dtype) * 0.02,
        "out_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(ks[1], (d, v_padded), dtype) / math.sqrt(d)
    return p


def lm_logits(x, p, cfg: ModelConfig):
    x = rms_norm(x, p["out_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        return x @ p["embed"].T
    return x @ p["lm_head"]
