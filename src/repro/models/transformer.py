"""Model assembly: init + train/prefill forward for every family.

The forward is a single ``jax.lax.scan`` over stacked layer params so
the traced HLO has one layer body regardless of depth (compile-time
control for the 512-device dry-run).  Families:

  dense / vlm / audio : [attn → mlp] × L
  moe                 : [attn → moe_ffn] × L
  ssm                 : [mamba2] × L
  hybrid (zamba2)     : [mamba2 (+ shared attn every k)] × L

VLM/audio frontends are stubs: precomputed patch/frame embeddings are
consumed as prefix tokens (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, pad_vocab
from repro.models import layers as Lyr
from repro.models import mamba2 as M2
from repro.models import moe as MoE
from repro.models.layers import (attn_qkv, blocked_causal_attention,
                                 init_attn, init_embed,
                                 init_mlp, lm_logits, mlp, rms_norm,
                                 shard_activation)

Params = Dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, 8)
    v_pad = pad_vocab(cfg.vocab_size)
    p: Params = {"tok": init_embed(keys[0], cfg, v_pad, dtype)}
    L = cfg.n_layers

    if cfg.family in ("dense", "vlm", "audio"):
        p["layers"] = {
            **init_attn(keys[1], cfg, L, dtype),
            **init_mlp(keys[2], cfg.d_model, cfg.d_ff, L, dtype),
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ln2": jnp.ones((L, cfg.d_model), dtype),
        }
    elif cfg.family == "moe":
        p["layers"] = {
            **init_attn(keys[1], cfg, L, dtype),
            **MoE.init_moe(keys[2], cfg, L, dtype),
            "ln1": jnp.ones((L, cfg.d_model), dtype),
            "ln2": jnp.ones((L, cfg.d_model), dtype),
        }
    elif cfg.family == "ssm":
        p["layers"] = {
            **M2.init_mamba2(keys[1], cfg, L, dtype),
            "ln1": jnp.ones((L, cfg.d_model), dtype),
        }
    elif cfg.family == "hybrid":
        p["layers"] = {
            **M2.init_mamba2(keys[1], cfg, L, dtype),
            "ln1": jnp.ones((L, cfg.d_model), dtype),
        }
        # one shared attention+MLP block (Zamba2-style tied weights)
        p["shared_attn"] = {
            **init_attn(keys[3], cfg, 1, dtype),
            **init_mlp(keys[4], cfg.d_model, cfg.d_ff, 1, dtype),
            "ln1": jnp.ones((1, cfg.d_model), dtype),
            "ln2": jnp.ones((1, cfg.d_model), dtype),
        }
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill semantics: full sequence, causal)
# ---------------------------------------------------------------------------
def _attn_block(x, lp, li, cfg: ModelConfig, positions, window):
    h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
    q, k, v = attn_qkv(h, lp, li, cfg, positions)
    o = blocked_causal_attention(q, k, v, window=window)
    b, s, _, _ = o.shape
    x = x + o.reshape(b, s, -1) @ lp["wo"][li]
    return x


def _mlp_block(x, lp, li, cfg: ModelConfig):
    h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
    return x + mlp(h, lp, li)


def _moe_block(x, lp, li, cfg: ModelConfig, dropless: bool = False):
    h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
    fn = MoE.moe_ffn_dropless if dropless else MoE.moe_ffn
    out, aux = fn(h, lp, li, cfg)
    return x + out, aux


def embed_inputs(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 prefix_emb: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = params["tok"]["embed"][tokens]                   # [B,S,d]
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    return x


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix_emb: Optional[jnp.ndarray] = None,
            window: Optional[int] = None,
            remat: bool = True,
            moe_dropless: bool = False,
            slice_vocab: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full causal forward.  Returns (logits [B,S_total,vocab], aux_loss).

    ``window`` optionally restricts attention (sliding-window variant).
    """
    x = embed_inputs(params, cfg, tokens, prefix_emb)
    x = shard_activation(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    lp = params["layers"]

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        is_moe = cfg.family == "moe"

        def layer(carry, li):
            x, aux = carry
            x = _attn_block(x, lp, li, cfg, positions, window)
            if is_moe:
                x, a = _moe_block(x, lp, li, cfg, moe_dropless)
                aux = aux + a
            else:
                x = _mlp_block(x, lp, li, cfg)
            return (shard_activation(x, seq="model"), aux), None

        body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                                   jnp.arange(cfg.n_layers))

    elif cfg.family == "ssm":
        sp = Lyr.model_axis_size()     # sequence-parallel SSD (§Perf)

        def layer(x, li):
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, _ = M2.mamba2_mixer(h, lp, li, cfg, seq_parallel=sp)
            return shard_activation(x + out, seq="model"), None

        body = jax.checkpoint(layer, prevent_cse=False) if remat else layer
        x, _ = jax.lax.scan(body, x, jnp.arange(cfg.n_layers))
        aux = jnp.float32(0)

    elif cfg.family == "hybrid":
        # grouped scan: [attn_every × mamba2 → shared attn] × n_groups,
        # then the ungrouped tail layers.  No lax.cond in the body —
        # the static structure lowers cleaner and keeps the HLO FLOP
        # count well-defined (launch/hlo_analysis.py).
        sa = params["shared_attn"]
        n_groups = cfg.n_layers // cfg.attn_every
        tail_layers = cfg.n_layers - n_groups * cfg.attn_every
        sp = Lyr.model_axis_size()     # sequence-parallel SSD (§Perf)

        def ssm_layer(x, li):
            h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
            out, _ = M2.mamba2_mixer(h, lp, li, cfg, seq_parallel=sp)
            return shard_activation(x + out, seq="model")

        def group(x, gi):
            for j in range(cfg.attn_every):
                x = ssm_layer(x, gi * cfg.attn_every + j)
            x = _attn_block(x, sa, 0, cfg, positions, window)
            x = _mlp_block(x, sa, 0, cfg)
            return shard_activation(x, seq="model"), None

        body = jax.checkpoint(group, prevent_cse=False) if remat else group
        x, _ = jax.lax.scan(body, x, jnp.arange(n_groups))
        for j in range(tail_layers):
            x = ssm_layer(x, n_groups * cfg.attn_every + j)
        aux = jnp.float32(0)
    else:
        raise ValueError(cfg.family)

    logits = lm_logits(x, params["tok"], cfg)
    if slice_vocab:
        logits = logits[..., :cfg.vocab_size]
    return logits, aux


def cross_entropy(logits, labels):
    """CE that stays sharding-friendly when the vocab dim is sharded.

    Avoids materializing a full f32 log-softmax and avoids the gather of
    ``take_along_axis`` along a (potentially model-sharded) vocab axis:
    reductions (max / logsumexp) partition cleanly under GSPMD, and the
    label logit is picked with a fused iota-compare mask.
    """
    logits = Lyr.shard_activation(logits, last="model")
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lmax = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == safe[..., None], shifted, 0.0),
                     axis=-1)
    nll = lse - picked
    n = jnp.maximum(mask.sum(), 1)
    return (nll * mask).sum() / n


def loss_fn(params: Params, cfg: ModelConfig, tokens, labels,
            prefix_emb=None, remat: bool = True):
    """Causal LM cross-entropy (labels −100 are masked)."""
    # keep the padded vocab dim intact: the CE reductions shard cleanly
    # and labels never index the padding (slicing would break the
    # model-axis sharding of the logits)
    logits, aux = forward(params, cfg, tokens, prefix_emb, remat=remat,
                          slice_vocab=False)
    if prefix_emb is not None:
        logits = logits[:, prefix_emb.shape[1]:]
    return cross_entropy(logits, labels) + aux
