"""Mamba2 — SSD (state-space duality) mixer, pure JAX.

Implements the chunked SSD algorithm [arXiv:2405.21060 §6]: within a
chunk the sequence mixing is a dense (masked) matmul — MXU-friendly —
and states are carried across chunks with a first-order recurrence.
``kernels/ssd_scan`` is the Pallas version of the chunk kernel; this
module is the oracle and the XLA fallback.

Layer structure (Mamba2 block):
  in_proj: d → [z(di), x(di), B(G·N), C(G·N), dt(H)]
  causal conv1d (kernel K) over [x, B, C]
  SSD: y = SSD(x·dt, A·dt, B, C) + D⊙x
  gated RMSNorm(y · silu(z)); out_proj: di → d

Decode keeps a per-sequence cache: conv tail [conv_dim, K-1] and SSM
state [H, P, N] — constant size, stored in the unified pool as state
pages (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import rms_norm


def init_mamba2(key, cfg: ModelConfig, n_layers: int, dtype=jnp.bfloat16) -> Dict:
    sc = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H, P, N, G, K = cfg.n_ssm_heads, sc.head_dim, sc.d_state, sc.n_groups, sc.conv_kernel
    L = n_layers
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * G * N + H
    return {
        "in_proj": jax.random.normal(ks[0], (L, d, d_in_proj), dtype) / math.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (L, K, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32), (L, H))),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, H, dtype=jnp.float32))),
            (L, H)),
        "d_skip": jnp.ones((L, H), jnp.float32),
        "gnorm": jnp.ones((L, di), dtype),
        "out_proj": jax.random.normal(ks[2], (L, di, d), dtype) / math.sqrt(di),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    sc = cfg.ssm
    di, G, N, H = cfg.d_inner, sc.n_groups, sc.d_state, cfg.n_ssm_heads
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, xs, B, C, dt


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                tail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: [B,S,C], w: [K,C], tail: [B,K-1,C]."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)              # [B, S+K-1, C]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def causal_conv_slabbed(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                        slabs: int) -> jnp.ndarray:
    """Causal conv over a sequence whose slabs ride the model axis.

    The K−1 halo comes from the previous slab's tail via a shift along
    the (sharded) slab dim — a [B, slabs, K−1, C] boundary exchange
    instead of GSPMD's whole-tensor resharding of the shifted slices
    (22.6 GiB → KB-scale permutes on mamba2 prefill_32k, §Perf).
    Zero halo for the first slab ≡ zero conv tail (prefill semantics).
    """
    from repro.models.layers import constrain
    B_, S, C = x.shape
    K = w.shape[0]
    Ls = S // slabs
    xs = x.reshape(B_, slabs, Ls, C)
    xs = constrain(xs, ("pod", "data"), "model", None, None)
    tails = xs[:, :, Ls - (K - 1):, :]                  # [B,slabs,K-1,C]
    halo = jnp.pad(tails[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))
    xp = jnp.concatenate([halo, xs], axis=2)            # [B,slabs,K-1+Ls,C]
    out = sum(xp[:, :, i:i + Ls] * w[i] for i in range(K))
    out = jax.nn.silu(out + b)
    return out.reshape(B_, S, C)


def ssd_chunked(x, dt, a_log, B, C, d_skip, chunk: int,
                init_state=None, shard_heads: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (oracle semantics for kernels/ssd_scan).

    x:  [b, S, H, P]   inputs per head
    dt: [b, S, H]      softplus-activated step sizes
    B:  [b, S, G, N]   input projections (G groups broadcast over H)
    C:  [b, S, G, N]   output projections
    Returns (y [b,S,H,P], final_state [b,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    nc = S // chunk
    assert S % chunk == 0, "sequence must be divisible by chunk"
    rep = H // G

    a = -jnp.exp(a_log.astype(jnp.float32))              # [H] (negative)
    dA = dt.astype(jnp.float32) * a                      # [b,S,H] log-decay
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # reshape into chunks
    xc = xdt.reshape(b, nc, chunk, H, P)
    dAc = dA.reshape(b, nc, chunk, H)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, G, N)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, G, N)

    # cumulative decay within chunk: l[i] = sum_{j<=i} dA[j]
    l = jnp.cumsum(dAc, axis=2)                          # [b,nc,Q,H]
    total = l[:, :, -1]                                  # [b,nc,H]

    # --- intra-chunk (dense, MXU-friendly) -----------------------------
    # scores[i,j] = (C_i · B_j) * exp(l_i - l_j) for i >= j
    from repro.models.layers import constrain
    Bh = jnp.repeat(Bc, rep, axis=3)                     # [b,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)
    if shard_heads:
        Bh = constrain(Bh, ("pod", "data"), None, None, "model", None)
        Ch = constrain(Ch, ("pod", "data"), None, None, "model", None)
    cb = jnp.einsum("bnihN,bnjhN->bnhij", Ch, Bh)        # [b,nc,H,Q,Q]
    seg = l[:, :, :, None, :] - l[:, :, None, :, :]      # l_i - l_j [b,nc,Q,Q,H]
    seg = seg.transpose(0, 1, 4, 2, 3)                   # [b,nc,H,Q,Q]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE the exp: above the diagonal seg is a positive sum of
    # decays, exp overflows to inf, and although where() masks the
    # forward, the backward is d(exp)=exp=inf × 0-cotangent = NaN
    decay = jnp.exp(jnp.where(causal, seg, -1e30))
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", cb * decay, xc)

    # --- chunk states ---------------------------------------------------
    # S_n = sum_j exp(total - l_j) * B_j ⊗ x_j   [b,nc,H,P,N]
    w = jnp.exp(total[:, :, None] - l)                   # [b,nc,Q,H]
    states = jnp.einsum("bnjhN,bnjhp,bnjh->bnhpN", Bh, xc, w)

    # --- inter-chunk recurrence ------------------------------------------
    if init_state is None:
        init_state = jnp.zeros((b, H, P, N), jnp.float32)

    decay_chunk = jnp.exp(total)                         # [b,nc,H]

    def step(carry, inp):
        s_prev = carry
        st, dc = inp                                     # [b,H,P,N], [b,H]
        s_new = s_prev * dc[:, :, None, None] + st
        return s_new, s_prev

    (final_state, prev_states) = jax.lax.scan(
        step, init_state,
        (states.transpose(1, 0, 2, 3, 4), decay_chunk.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [b,nc,H,P,N]

    # y_inter[i] = (C_i · prev_state) * exp(l_i)
    y_inter = jnp.einsum("bnihN,bnhpN,bnih->bnihp", Ch, prev_states, jnp.exp(l))
    y = (y_intra + y_inter).reshape(b, S, H, P)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final_state


def ssd_seq_parallel(x, dt, a_log, B, C, d_skip, chunk: int,
                     slabs: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequence-parallel chunked SSD (§Perf, mamba2 prefill).

    The sequence is cut into ``slabs`` that ride the batch dim (merged
    ``b·slabs`` sharded over (data, model)); each slab runs the local
    chunked SSD from a zero state, and the cross-slab composition uses
    the fact that the SSM is affine in its state:

        s_out = D_slab ⊙ s_in + s_local,  D_slab = exp(Σ_slab dA)

    so a [b, slabs, H, P, N] prefix scan (MB-scale traffic) replaces
    the per-layer tensor-parallel all-reduces of head sharding —
    measured 124 GiB → sub-GiB collectives on mamba2 prefill_32k.
    Exact: matches ssd_chunked bit-for-bit up to f32 reassociation
    (asserted in tests/test_kernels.py).
    """
    from repro.models.layers import constrain
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert S % slabs == 0
    Ls = S // slabs
    rep = H // G

    def slab(t):
        # [b, S, ...] → [slabs·b, Ls, ...] (SLAB-major merge so the
        # merged dim shards ('model','pod','data')-major and every row
        # stays on the device that already holds it — a batch-major
        # merge forces ~50 MB collective-permutes per layer, measured)
        return t.reshape((b, slabs, Ls) + t.shape[2:]) \
                .swapaxes(0, 1) \
                .reshape((slabs * b, Ls) + t.shape[2:])

    xs, dts, Bs, Cs = slab(x), slab(dt), slab(B), slab(C)
    xs = constrain(xs, ("model", "pod", "data"), None, None, None)
    dts = constrain(dts, ("model", "pod", "data"), None, None)
    y_loc, fs_loc = ssd_chunked(xs, dts, a_log, Bs, Cs, d_skip,
                                min(chunk, Ls), shard_heads=False)

    # slab decay D = exp(Σ dA) and prefix states across slabs
    a = -jnp.exp(a_log.astype(jnp.float32))              # [H]
    dA_tot = (dts.astype(jnp.float32) * a).sum(axis=1)   # [slabs·b, H]
    D = dA_tot.reshape(slabs, b, H)
    fs = fs_loc.reshape(slabs, b, H, P, N)

    def step(s_prev, inp):
        st, dc = inp                                     # [b,H,P,N],[b,H]
        s_new = s_prev * jnp.exp(dc)[:, :, None, None] + st
        return s_new, s_prev

    final, prefix = jax.lax.scan(
        step, jnp.zeros((b, H, P, N), jnp.float32), (fs, D))
    # prefix: [slabs, b, H, P, N]

    # correction: y[t] += exp(l_local(t)) · C_t · prefix_state
    l_loc = jnp.cumsum(
        (dts.astype(jnp.float32) * a).reshape(slabs, b, Ls, H), axis=2)
    Ch = jnp.repeat(Cs.reshape(slabs, b, Ls, G, N), rep, axis=3)
    corr = jnp.einsum("sbihN,sbhpN,sbih->sbihp",
                      Ch.astype(jnp.float32), prefix, jnp.exp(l_loc))
    y = y_loc.reshape(slabs, b, Ls, H, P).astype(jnp.float32) + corr
    y = y.swapaxes(0, 1).reshape(b, S, H, P)
    return y.astype(x.dtype), final


def mamba2_mixer(x, p, li, cfg: ModelConfig,
                 conv_tail=None, ssm_state=None, return_cache=False,
                 length_mask=None, seq_parallel: int = 0):
    """Full Mamba2 block (train/prefill path).  x: [B,S,d].

    ``length_mask`` [B,S] (True = real token): padded positions get
    dt=0 so they neither update nor decay the SSM state — the final
    state equals the state at the last real token.
    """
    sc = cfg.ssm
    b, s, _ = x.shape
    H, P, G, N, K = cfg.n_ssm_heads, sc.head_dim, sc.n_groups, sc.d_state, sc.conv_kernel
    di = cfg.d_inner

    zxbcdt = x @ p["in_proj"][li]
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc_pre = jnp.concatenate([xs, B, C], axis=-1)       # pre-conv inputs
    if seq_parallel > 1 and s % seq_parallel == 0 and conv_tail is None:
        xbc = causal_conv_slabbed(xbc_pre, p["conv_w"][li],
                                  p["conv_b"][li], seq_parallel)
    else:
        xbc = causal_conv(xbc_pre, p["conv_w"][li], p["conv_b"][li],
                          conv_tail)
    xs, B, C = jnp.split(xbc, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][li])
    if length_mask is not None:
        dt = dt * length_mask[:, :, None].astype(dt.dtype)
    xh = xs.reshape(b, s, H, P)
    Bg = B.reshape(b, s, G, N)
    Cg = C.reshape(b, s, G, N)
    chunk = min(sc.chunk_size, s)
    from repro.models.layers import constrain
    if seq_parallel > 1 and s % seq_parallel == 0 and ssm_state is None:
        # sequence-parallel SSD (prefill path — §Perf)
        y, final_state = ssd_seq_parallel(xh, dt, p["a_log"][li], Bg, Cg,
                                          p["d_skip"][li], chunk,
                                          slabs=seq_parallel)
    else:
        # SSM head parallelism: heads ride the model axis (the SSD scan
        # is independent per head); B/C are per-group (G=1), replicated.
        # Without this the SSD quadratic intra-chunk term is computed
        # replicated on every model rank (measured 41 GiB/dev temp and a
        # 16× compute waste on mamba2 prefill_32k — EXPERIMENTS.md §Perf)
        xh = constrain(xh, ("pod", "data"), None, "model", None)
        dt = constrain(dt, ("pod", "data"), None, "model")
        y, final_state = ssd_chunked(xh, dt, p["a_log"][li], Bg, Cg,
                                     p["d_skip"][li], chunk,
                                     init_state=ssm_state)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"][li], cfg.rms_eps)
    out = y @ p["out_proj"][li]
    if return_cache:
        # conv tail = last K-1 *pre-activation* conv inputs of each
        # sequence (positions len-K+1 .. len-1; padded batches gather at
        # their own length, zeros when the sequence is shorter than K-1)
        prev = conv_tail if conv_tail is not None else \
            jnp.zeros((b, K - 1, di + 2 * G * N), x.dtype)
        full = jnp.concatenate([prev, xbc_pre], axis=1)   # [b, K-1+S, conv]
        if length_mask is not None:
            lens = length_mask.sum(axis=1).astype(jnp.int32)     # [b]
        else:
            lens = jnp.full((b,), s, jnp.int32)
        idx = lens[:, None] + jnp.arange(K - 1)[None, :]  # last K-1 slots
        new_tail = jnp.take_along_axis(full, idx[:, :, None], axis=1)
        return out, final_state, new_tail
    return out, final_state


def mamba2_decode_step(x, p, li, cfg: ModelConfig, conv_tail, ssm_state):
    """Single-token decode.  x: [B,d]; conv_tail: [B,K-1,conv_dim];
    ssm_state: [B,H,P,N] (float32).  Returns (out, new_tail, new_state)."""
    sc = cfg.ssm
    b = x.shape[0]
    H, P, G, N, K = cfg.n_ssm_heads, sc.head_dim, sc.n_groups, sc.d_state, sc.conv_kernel
    di = cfg.d_inner

    zxbcdt = x @ p["in_proj"][li]
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc_new = jnp.concatenate([xs, B, C], axis=-1)       # [B, conv_dim]

    window = jnp.concatenate([conv_tail, xbc_new[:, None]], axis=1)  # [B,K,conv]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"][li]) + p["conv_b"][li]
    conv_out = jax.nn.silu(conv_out)
    new_tail = window[:, 1:]

    xs, B, C = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][li])   # [B,H]
    a = -jnp.exp(p["a_log"][li].astype(jnp.float32))     # [H]
    dA = jnp.exp(dt * a)                                 # [B,H]

    xh = xs.reshape(b, H, P).astype(jnp.float32)
    Bg = jnp.repeat(B.reshape(b, G, N), H // G, axis=1).astype(jnp.float32)
    Cg = jnp.repeat(C.reshape(b, G, N), H // G, axis=1).astype(jnp.float32)

    # s ← s·exp(dtA) + dt·(B ⊗ x)
    new_state = ssm_state * dA[:, :, None, None] + \
        jnp.einsum("bhp,bhN,bh->bhpN", xh, Bg, dt)
    y = jnp.einsum("bhpN,bhN->bhp", new_state, Cg) + \
        p["d_skip"][li][None, :, None] * xh
    y = y.reshape(b, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"][li], cfg.rms_eps)
    return y @ p["out_proj"][li], new_tail, new_state
