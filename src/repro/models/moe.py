"""Mixture-of-Experts FFN with capacity-based dispatch.

Top-k routing with a static per-expert capacity (tokens beyond capacity
are dropped — standard Switch/GShard semantics).  The dispatch is
implemented with integer scatter/gather (not one-hot matmuls) so the
compiled FLOPs reflect *active* compute, which matters for the roofline
(MODEL_FLOPS uses 6·N_active·D for MoE).

Expert weights are stacked ``[L, E, d, f]`` → expert-parallel sharding
puts E on the ``model`` mesh axis; with tokens sharded on ``data`` the
dispatch/combine lower to all-to-all style collectives under GSPMD.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig


def init_moe(key, cfg: ModelConfig, n_layers: int, dtype=jnp.bfloat16) -> Dict:
    mc = cfg.moe
    d, fe, E, L = cfg.d_model, mc.d_expert, mc.n_experts, n_layers
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (L, d, E), jnp.float32) * 0.02,
        "w_gate": jax.random.normal(ks[1], (L, E, d, fe), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(ks[2], (L, E, d, fe), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(ks[3], (L, E, fe, d), dtype) / math.sqrt(fe),
    }


def expert_capacity(n_tokens: int, mc: MoEConfig) -> int:
    cap = int(math.ceil(n_tokens * mc.top_k / mc.n_experts * mc.capacity_factor))
    return max(8, ((cap + 7) // 8) * 8)   # pad to 8 for TPU-friendly tiling


def route(x: jnp.ndarray, router_w: jnp.ndarray, mc: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, d] → (gates [T,k], expert_idx [T,k], aux_loss)."""
    logits = x.astype(jnp.float32) @ router_w            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mc.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    me = probs.mean(0)                                   # mean prob per expert
    ce = jnp.zeros((mc.n_experts,), jnp.float32).at[idx[:, 0]].add(1.0)
    ce = ce / x.shape[0]
    aux = mc.n_experts * jnp.sum(me * ce) * mc.aux_loss_coef
    return gates, idx, aux


def moe_ffn_dropless(x: jnp.ndarray, p: Dict, li, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless MoE (per-token gathered expert weights).

    Serving path: token outputs are independent of batch composition —
    required for prefill/decode vs full-forward consistency.  Memory
    cost is O(T·k·d·f_e) gathered weights, fine for the CPU engine; the
    distributed paths use the capacity dispatch below.
    """
    mc = cfg.moe
    b, s, d = x.shape
    T = b * s
    xt = x.reshape(T, d)
    gates, idx, aux = route(xt, p["router"][li], mc)      # [T,k]
    wg = p["w_gate"][li][idx]                             # [T,k,d,fe]
    wu = p["w_up"][li][idx]
    wd = p["w_down"][li][idx]                             # [T,k,fe,d]
    h = jnp.einsum("td,tkdf->tkf", xt, wg)
    u = jnp.einsum("td,tkdf->tkf", xt, wu)
    y = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(h) * u, wd)
    out = (y * gates[..., None].astype(y.dtype)).sum(axis=1)
    return out.reshape(b, s, d), aux


def moe_ffn(x: jnp.ndarray, p: Dict, li, cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] → (out [B, S, d], aux_loss scalar).

    Sharding: token-major tensors ride the (pod, data) axes, the
    expert-major dispatch buffer rides the model axis — the T-sharded →
    E-sharded transition is the expert-parallel all-to-all under GSPMD.
    Without the explicit constraints GSPMD replicates the [T·k, d]
    combine intermediates (measured 128 GiB/device on qwen3-moe
    train_4k — EXPERIMENTS.md §Perf).
    """
    from repro.models.layers import constrain
    mc = cfg.moe
    b, s, d = x.shape
    T = b * s
    xt = constrain(x.reshape(T, d), ("pod", "data"), None)
    gates, idx, aux = route(xt, p["router"][li], mc)     # [T,k]

    E, k = mc.n_experts, mc.top_k
    cap = expert_capacity(T, mc)

    # position of each (token, slot) within its expert, in flat order
    flat_e = idx.reshape(-1)                              # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # [T*k, E]
    pos_in_e = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                   flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap                                 # capacity mask

    # dispatch: [E, cap, d].  slot i belongs to token i//k, so the
    # token→slot expansion is a local broadcast+reshape (keeps the
    # (pod,data) sharding — a gather ``xt[tok_of_slot]`` would force an
    # all-gather of the whole token tensor).  The scatter into the
    # E-major buffer is the expert-parallel all-to-all.
    e_safe = jnp.where(keep, flat_e, 0)
    p_safe = jnp.where(keep, pos_in_e, cap - 1)
    xt_rep = jnp.broadcast_to(xt[:, None], (T, k, d)).reshape(T * k, d)
    upd = jnp.where(keep[:, None], xt_rep, 0).astype(xt.dtype)
    upd = constrain(upd, ("pod", "data"), None)
    disp = jnp.zeros((E, cap, d), xt.dtype)
    disp = disp.at[e_safe, p_safe].add(upd)
    disp = constrain(disp, "model", None, None)

    # expert FFN: [E, cap, d] x [E, d, fe]  (E on the model axis)
    h = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"][li])
    u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"][li])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"][li])
    y = constrain(y, "model", None, None)

    # combine: slot outputs gathered back token-major, then a local
    # [T, k] reduction (no scatter — slots of one token are adjacent)
    slot_out = y[e_safe, p_safe]                          # [T*k, d]
    slot_out = constrain(slot_out, ("pod", "data"), None)
    slot_out = jnp.where(keep[:, None], slot_out, 0)
    w = (gates.reshape(-1) * keep).astype(slot_out.dtype) # [T*k]
    out = (slot_out * w[:, None]).reshape(T, k, d).sum(axis=1)
    out = constrain(out, ("pod", "data"), None)
    return out.reshape(b, s, d), aux
