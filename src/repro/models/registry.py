"""Architecture registry: arch-id → (config, init, forward)."""
from __future__ import annotations


import jax.numpy as jnp

from repro import configs
from repro.config import ModelConfig
from repro.models import transformer


def get_config(arch: str) -> ModelConfig:
    return configs.get(arch)


def get_reduced_config(arch: str) -> ModelConfig:
    return configs.get_reduced(arch)


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    return transformer.init_params(key, cfg, dtype)


def forward(params, cfg: ModelConfig, tokens, **kw):
    return transformer.forward(params, cfg, tokens, **kw)


def list_archs():
    return list(configs.ARCH_IDS)
