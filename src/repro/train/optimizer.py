"""AdamW + schedules, pure JAX (no optax dependency).

Optimizer state mirrors the param pytree (m, v in float32) so the same
sharding rules apply to both — ``launch/sharding.py`` maps a param's
PartitionSpec onto its optimizer slots verbatim, which is what makes
the train-shape dry-run memory analysis meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    m: Pytree                  # first moment  (f32, like params)
    v: Pytree                  # second moment (f32, like params)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to ``min_lr_frac·lr``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(path: Tuple, leaf) -> bool:
    """Weight decay applies to matrices only (no norms/biases/scalars)."""
    names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
    name = str(names[-1]) if names else ""
    if leaf.ndim <= 1:
        return False
    return not any(s in name for s in ("norm", "ln", "bias", "a_log",
                                       "dt_bias", "d_skip"))


def apply_updates(params: Pytree, grads: Pytree, state: AdamWState,
                  cfg: AdamWConfig) -> Tuple[Pytree, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path, p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state.m)
    v_leaves = jax.tree.leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat, g_leaves, m_leaves, v_leaves):
        p2, m2, v2 = upd(path, p, g, m, v)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = AdamWState(step=step, m=jax.tree.unflatten(treedef, new_m),
                        v=jax.tree.unflatten(treedef, new_v))
    return params2, state2, {"lr": lr, "grad_norm": gnorm}
