"""Checkpointing: pytree ⇄ directory of .npy files + a JSON manifest.

No external deps (orbax not installed): leaves are saved individually
with flattened key-paths so checkpoints are inspectable, partial-
loadable, and robust to pytree-library version drift.  Atomic via
write-to-tmp + rename.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "/"


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SEP.join(parts)


def save(ckpt_dir: str, tree: Pytree, step: int,
         extra: Optional[Dict] = None) -> str:
    """Write ``tree`` under ``ckpt_dir/step_{step}``; returns the path."""
    dest = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, name), np.asarray(leaf))
        manifest["leaves"].append({
            "path": _path_str(path), "file": name,
            "dtype": str(np.asarray(leaf).dtype),
            "shape": list(np.asarray(leaf).shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(dest):
        shutil.rmtree(dest)
    os.rename(tmp, dest)
    return dest


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Pytree, step: Optional[int] = None
            ) -> Tuple[Pytree, int, Dict]:
    """Restore into the structure of ``like`` (dtype/shape-checked)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints in {ckpt_dir}"
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = _path_str(path)
        entry = by_path.get(key)
        assert entry is not None, f"checkpoint missing leaf {key}"
        arr = np.load(os.path.join(src, entry["file"]))
        assert list(arr.shape) == list(leaf.shape),\
            f"{key}: shape {arr.shape} != {leaf.shape}"
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, leaves), manifest["step"],\
        manifest["extra"]
