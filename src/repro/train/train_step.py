"""Training step: CE loss → grad → AdamW update (donated buffers).

``make_train_step`` returns the pure function lowered by both the real
CPU trainer (examples/train_small.py) and the 512-device dry-run — one
definition, two scales.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer
from repro.train.optimizer import AdamWConfig, AdamWState, apply_updates


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    remat: bool = True, microbatches: int = 1) -> Callable:
    """``microbatches > 1`` splits the global batch and accumulates
    gradients in f32 over a ``lax.scan`` — one optimizer update per
    step.  Used for the largest models (command-r-104b, qwen3-moe-235b)
    where a full 256×4k batch's activations don't fit 16 GiB/chip even
    with remat + sequence sharding (EXPERIMENTS.md §Perf)."""

    def loss_of(p, tokens, labels, prefix_emb):
        return transformer.loss_fn(p, cfg, tokens, labels,
                                   prefix_emb=prefix_emb, remat=remat)

    def train_step(params, opt_state: AdamWState, tokens, labels,
                   prefix_emb=None):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, tokens,
                                                      labels, prefix_emb)
        else:
            M = microbatches
            B = tokens.shape[0]
            assert B % M == 0
            tk = tokens.reshape(M, B // M, *tokens.shape[1:])
            lb = labels.reshape(M, B // M, *labels.shape[1:])
            pf = None if prefix_emb is None else \
                prefix_emb.reshape(M, B // M, *prefix_emb.shape[1:])

            def micro(acc, xs):
                loss_acc, g_acc = acc
                t, l = xs[0], xs[1]
                pe = xs[2] if len(xs) > 2 else None
                loss, g = jax.value_and_grad(loss_of)(params, t, l, pe)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / M, g_acc, g)
                return (loss_acc + loss / M, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            xs = (tk, lb) if pf is None else (tk, lb, pf)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), g0), xs)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                                 params)
        params2, opt_state2, info = apply_updates(params, grads, opt_state,
                                                  opt)
        metrics = {"loss": loss, **info}
        return params2, opt_state2, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def eval_step(params, tokens, labels, prefix_emb=None):
        loss = transformer.loss_fn(params, cfg, tokens, labels,
                                   prefix_emb=prefix_emb, remat=False)
        return {"loss": loss}

    return eval_step
