"""Deterministic synthetic token pipeline.

A seeded, shardable data source: documents are Markov chains over the
vocabulary with per-document transition structure so the LM loss has a
learnable signal (loss decreases within a few hundred steps on the
reduced configs — asserted in tests/test_train.py).  Batches are
produced host-side as numpy and fed to the jit'd step; the iterator is
stateless given (seed, step) so training is reproducible and resumable
from a checkpoint without data-state serialization.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64           # distinct Markov row-patterns
    frontend_dim: Optional[int] = None   # audio/vlm stub embeddings
    n_prefix_tokens: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC0FFEE]))


def synth_batch(cfg: DataConfig, step: int
                ) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Returns (tokens [B,S] int32, labels [B,S] int32, prefix or None).

    Each sequence follows ``next = (a*cur + b) % V`` with per-sequence
    (a, b) drawn from a small pattern set + 10% uniform noise — a signal
    an LM head can pick up quickly, with an irreducible floor.
    """
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    pat = rng.integers(0, cfg.n_patterns, B)
    a = (2 * pat + 1) % V            # odd multiplier → full-period-ish
    b = (7 * pat + 3) % V
    toks = np.empty((B, S), np.int32)
    toks[:, 0] = rng.integers(0, V, B)
    noise = rng.random((B, S)) < 0.1
    rand = rng.integers(0, V, (B, S))
    for t in range(1, S):
        nxt = (a * toks[:, t - 1] + b) % V
        toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
    labels = np.concatenate([toks[:, 1:], np.full((B, 1), -100, np.int32)],
                            axis=1)
    prefix = None
    if cfg.frontend_dim:
        prefix = rng.standard_normal(
            (B, cfg.n_prefix_tokens, cfg.frontend_dim)).astype(np.float32)
    return toks, labels, prefix


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield synth_batch(cfg, step)
        step += 1
