from repro.train import checkpoint, data, optimizer, train_step  # noqa: F401
