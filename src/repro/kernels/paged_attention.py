"""Pallas TPU kernel: decode attention over the unified head-wise pool.

This is the hot-spot of MuxServe's unified resource manager: every
colocated LLM's decode job reads scattered head-blocks from the shared
arena.  The GPU original inherits vLLM's paged-attention CUDA kernel;
the TPU rethink uses *scalar-prefetched block tables*
(``PrefetchScalarGridSpec``) so the physical block id for grid step
(b, h, j) — ``table[b, j] + layer*KV + kv_head`` — is known early
enough for the pipeline to stream the right ``[BLOCK_TOKENS, head_dim]``
tile HBM→VMEM while the VPU/MXU works on the previous one.

Grid: (batch, kv_heads, max_blocks) with the block axis sequential; the
q-head group of each kv head ([group, hd] — the GQA sublane batch)
stays resident in VMEM and online-softmax accumulators live in scratch.

A ``[16, 128]`` head-block is exactly the bf16 minimum tile.  Streaming
one head-block per step is DMA-latency-bound for long contexts; the
§Perf hillclimb evaluates BLOCK_TOKENS=64 pools (4 tiles per fetch) —
the pool granularity is a config knob, not a kernel assumption.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.paging import resolve_physical_blocks

NEG_INF = -1e30


def _paged_kernel(phys_ref, lens_ref,                # scalar prefetch
                  q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  bt: int, n_blocks: int, scale: float, group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]
    run = j * bt < seq_len

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bt, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        t_pos = j * bt + jax.lax.broadcasted_iota(jnp.int32, (group, bt), 1)
        s = jnp.where(t_pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_paged_decode_attention(q, pool_k, pool_v, phys, seq_lens, *,
                                 interpret: bool = False):
    """Multi-sequence decode attention over pre-resolved physical blocks.

    The fused multi-LLM tick (DESIGN.md §2) concatenates the decode
    rows of every colocated same-architecture engine into one batch;
    each row's ``phys`` entries already carry the (model, layer) →
    physical-id resolution from the unified pool, so one kernel sweep
    serves all colocated LLMs at once instead of one launch per model.

    q: [B, H, hd] (one post-RoPE query token per row; rows may belong
        to different models)
    pool_k/v: [N, BT, hd] head-block arena
    phys: [B, n_kv, max_blocks] int32 physical head-block ids (invalid
        entries must point at a valid block — e.g. 0 — and be masked
        via seq_lens)
    seq_lens: [B] (length including the current token)
    """
    B, H, hd = q.shape
    N, BT, _ = pool_k.shape
    n_kv, max_blocks = phys.shape[1], phys.shape[2]
    group = H // n_kv
    scale = 1.0 / math.sqrt(hd)

    qt = q.reshape(B, n_kv, group, hd)
    kernel = functools.partial(_paged_kernel, bt=BT, n_blocks=max_blocks,
                               scale=scale, group=group)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_kv, max_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda b, h, j, *refs: (b, h, 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda b, h, j, *refs: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_kv, group, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(phys, seq_lens, qt, pool_k, pool_v)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("n_kv", "interpret"))
def paged_decode_attention(q, pool_k, pool_v, table, seq_lens, layer, *,
                           n_kv: int, interpret: bool = False):
    """Decode attention against the paged pool (single-model view).

    q: [B, H, hd] (one post-RoPE query token per sequence)
    pool_k/v: [N, BT, hd] head-block arena
    table: [B, max_blocks] int32 group bases (−1 padded)
    seq_lens: [B] (length including the current token)
    layer: int32 scalar — attention-layer cache index
    """
    # padded table entries resolve to block 0 but are masked by
    # seq_lens in-kernel (shared resolution with the XLA oracle)
    phys = resolve_physical_blocks(table, layer, n_kv)
    return fused_paged_decode_attention(q, pool_k, pool_v, phys, seq_lens,
                                        interpret=interpret)
