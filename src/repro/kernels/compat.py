"""Version compatibility for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in
newer jax releases; resolve whichever this interpreter ships so the
kernels import (and run in interpret mode) on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
