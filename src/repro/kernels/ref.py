"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These delegate to the framework's reference implementations so kernel
tests pin the kernels to exactly the semantics the engine/dry-run use.
"""
from __future__ import annotations

from repro.models.layers import causal_attention
from repro.models.mamba2 import ssd_chunked
from repro.paging import paged_decode_attention as _paged_ref


def flash_prefill_ref(q, k, v, *, window=None):
    """Causal attention oracle.  q:[B,S,H,hd], k/v:[B,S,KV,hd]."""
    return causal_attention(q, k, v, window=window)


def paged_decode_ref(q, pool_k, pool_v, table, seq_lens, layer, *, n_kv):
    """Paged decode oracle — the engine's XLA path."""
    return _paged_ref(q, pool_k, pool_v, table, seq_lens, layer, n_kv)


def ssd_scan_ref(x, dt, a_log, B, C, d_skip, *, chunk=64):
    """SSD oracle — the model's chunked scan (itself validated against a
    step-by-step recurrence in tests/test_mamba2.py)."""
    return ssd_chunked(x, dt, a_log, B, C, d_skip, chunk)
