"""Pallas TPU kernel: int8 paged decode attention (W8/KV8 serving path).

Same grid/pipeline structure as ``paged_attention.py`` (scalar-
prefetched block tables, online softmax in VMEM scratch), but the KV
head-blocks are stored int8 with one f32 scale per (block, token):
dequantization happens in-register after the HBM→VMEM copy, so the
DMA traffic is half the bf16 kernel's — exactly the §Perf P2 memory
win, now at kernel granularity.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.paging import resolve_physical_blocks

NEG_INF = -1e30


def _paged_kernel_i8(phys_ref, lens_ref,                 # scalar prefetch
                     q_ref, k_ref, v_ref, sk_ref, sv_ref, o_ref,
                     m_ref, l_ref, acc_ref, *,
                     bt: int, n_blocks: int, scale: float, group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]
    run = j * bt < seq_len

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [group, hd]
        # dequantize in-register: int8 values × per-token f32 scales
        k = k_ref[0].astype(jnp.float32) * sk_ref[0][:, :1]
        v = v_ref[0].astype(jnp.float32) * sv_ref[0][:, :1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        t_pos = j * bt + jax.lax.broadcasted_iota(jnp.int32, (group, bt), 1)
        s = jnp.where(t_pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_kv", "interpret"))
def paged_decode_attention_int8(q, pool_k, pool_v, pool_sk, pool_sv,
                                table, seq_lens, layer, *,
                                n_kv: int, interpret: bool = False):
    """Decode attention over an int8 paged pool.

    q: [B, H, hd] (post-RoPE); pool_k/v: [N, BT, hd] int8;
    pool_sk/sv: [N, BT] f32 per-token scales; table: [B, max_blocks]
    int32 group bases (−1 padded); seq_lens: [B]."""
    B, H, hd = q.shape
    N, BT, _ = pool_k.shape
    max_blocks = table.shape[1]
    group = H // n_kv
    scale = 1.0 / math.sqrt(hd)

    phys = resolve_physical_blocks(table, layer, n_kv)

    qt = q.reshape(B, n_kv, group, hd)
    # scales carried as [N, BT, 1] so the lane dim exists for VMEM tiles
    sk = pool_sk[..., None]
    sv = pool_sv[..., None]
    kernel = functools.partial(_paged_kernel_i8, bt=BT,
                               n_blocks=max_blocks, scale=scale,
                               group=group)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_kv, max_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda b, h, j, *refs: (b, h, 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
                pl.BlockSpec((1, BT, 1),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
                pl.BlockSpec((1, BT, 1),
                             lambda b, h, j, phys_ref, lens_ref:
                                 (phys_ref[b, h, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda b, h, j, *refs: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_kv, group, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(phys, seq_lens, qt, pool_k, pool_v, sk, sv)
    return out.reshape(B, H, hd)
