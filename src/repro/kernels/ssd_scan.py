"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the intra-chunk
term is a masked [Q×Q] matmul (MXU), the inter-chunk state recurrence is
a first-order scan carried in VMEM scratch across the sequential chunk
axis of the grid — the TPU analogue of the GPU kernel's SM-local
chunk-state pipeline.

Grid: (batch, heads, num_chunks), chunk axis sequential.  Per step the
kernel holds x[Q,P], dt[Q], B[Q,N], C[Q,N] plus the carried state [P,N]
in VMEM: at Q=256, P=64, N=128 that is ≈ 0.4MB — small; Q is chosen so
the [Q×Q] decay matmul saturates the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                y_ref, fs_ref, state_ref, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q, 1]
    a = a_ref[0, 0]                              # [1, 1] f32 (A_log)
    B = b_ref[0, 0].astype(jnp.float32)          # [Q, N]
    C = c_ref[0, 0].astype(jnp.float32)          # [Q, N]
    d_skip = d_ref[0, 0]                         # [1, 1] f32

    neg_a = -jnp.exp(a[0, 0])
    dA = dt[:, 0] * neg_a                        # [Q] log-decay
    l = jnp.cumsum(dA)                           # [Q]
    xdt = x * dt                                 # [Q, P]

    # intra-chunk: scores[i,j] = (C_i·B_j)·exp(l_i − l_j), i ≥ j
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = l[:, None] - l[None, :]
    # mask before exp (overflow above the diagonal — see mamba2.py)
    decay = jnp.exp(jnp.where(li >= lj, seg, -1e30))
    y_intra = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_inter[i] = exp(l_i) · (C_i · S_prev)
    s_prev = state_ref[...]                      # [P, N]
    y_inter = jnp.exp(l)[:, None] * jax.lax.dot_general(
        C, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [Q, P]

    y_ref[0, 0] = (y_intra + y_inter + d_skip[0, 0] * x).astype(y_ref.dtype)

    # state update: S ← exp(Σ dA)·S_prev + Σ_j exp(l_last − l_j)·x_j ⊗ B_j
    w = jnp.exp(l[-1] - l)                       # [Q]
    s_new = s_prev * jnp.exp(l[-1]) + jax.lax.dot_general(
        xdt * w[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [P, N]
    state_ref[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        fs_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, B, C, d_skip, *, chunk: int = 256,
             interpret: bool = False):
    """Chunked SSD.  x:[b,S,H,P], dt:[b,S,H], a_log:[H], B/C:[b,S,G,N],
    d_skip:[H] → (y [b,S,H,P], final_state [b,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    # layout: head-major so each grid step reads contiguous [Q,·] tiles
    xt = x.transpose(0, 2, 1, 3)                               # [b,H,S,P]
    dtt = dt.transpose(0, 2, 1)[..., None]                     # [b,H,S,1]
    Bt = jnp.repeat(B.transpose(0, 2, 1, 3), rep, axis=1)      # [b,H,S,N]
    Ct = jnp.repeat(C.transpose(0, 2, 1, 3), rep, axis=1)
    a2 = jnp.broadcast_to(a_log.astype(jnp.float32)[None, :, None, None],
                          (b, H, 1, 1))
    d2 = jnp.broadcast_to(d_skip.astype(jnp.float32)[None, :, None, None],
                          (b, H, 1, 1))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a2, Bt, Ct, d2)
    return y.transpose(0, 2, 1, 3), fs
