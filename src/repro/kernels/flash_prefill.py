"""Pallas TPU kernel: blocked causal flash attention (prefill phase).

The prefill job is compute-bound (paper §2.1) — this kernel keeps the
MXU busy with [block_q × hd] · [hd × block_k] matmuls while the online
softmax keeps the working set in VMEM.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks), with the k-block
axis innermost/sequential; (m, l, acc) accumulators live in VMEM scratch
and persist across the k-block iterations.  GQA is handled in the
index maps: q head h reads kv head h // group.

Block sizes default to (256 q × 512 k) at head_dim 128 →
q(64KB) + k(128KB) + v(128KB) + acc(128KB f32) ≈ 0.5MB VMEM per step,
well inside the ~16MB/core budget while giving 256×512 MXU tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, n_kb: int, scale: float,
                  window: int | None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    # skip fully-masked blocks (start of the window / above the diagonal)
    run = (ki * block_k <= qi * block_q + block_q - 1)
    if window is not None:
        run &= (ki + 1) * block_k - 1 > qi * block_q - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "window",
                                             "interpret"))
def flash_prefill(q, k, v, *, block_q: int = 256, block_k: int = 512,
                  window: int | None = None, interpret: bool = False):
    """Causal flash attention.  q: [B,S,H,hd]; k/v: [B,S,KV,hd]."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_qb, n_kb = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3)       # [B,H,S,hd]
    kt = k.transpose(0, 2, 1, 3)       # [B,KV,S,hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, n_kb=n_kb, scale=scale,
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)   # [B,S,H,hd]


# ---------------------------------------------------------------------------
# fused paged flash prefill — the prefill-phase mirror of
# kernels/paged_attention.fused_paged_decode_attention (DESIGN.md §2)
# ---------------------------------------------------------------------------
def _paged_prefill_kernel(phys_ref, offs_ref,            # scalar prefetch
                          q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *,
                          bt: int, n_blocks: int, scale: float,
                          rows: int, group: int, chunk: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = offs_ref[b]
    # blocks entirely above the last query position are fully masked
    run = j * bt <= off + chunk - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [rows, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bt, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # row r = c*group + g queries absolute position off + c; the
        # causal mask admits every pool position ≤ that (earlier
        # chunks + this chunk's already-written KV), matching the XLA
        # oracle (cache_ops.fused_paged_chunk_attention)
        t_pos = j * bt + jax.lax.broadcasted_iota(
            jnp.int32, (rows, bt), 1)
        q_pos = off + jax.lax.broadcasted_iota(
            jnp.int32, (rows, bt), 0) // group
        s = jnp.where(t_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_paged_flash_prefill(q, pool_k, pool_v, phys, q_offset, *,
                              interpret: bool = False):
    """Multi-sequence chunked-prefill attention over pre-resolved
    physical head-blocks.

    The fused multi-LLM prefill sweep (DESIGN.md §2) flattens every
    in-flight prompt chunk of every colocated same-architecture engine
    into one batch; ``phys`` rows already carry the (model, layer) →
    physical-id resolution, so one kernel sweep serves all colocated
    LLMs' prefill chunks at once — mirroring
    ``fused_paged_decode_attention`` with C query tokens per row and a
    causal chunk mask.  Scalar-prefetched block ids stream the right
    ``[BLOCK_TOKENS, head_dim]`` tile HBM→VMEM ahead of compute; the
    chunk's query block ([C·group, hd]) stays resident in VMEM.

    q: [B, C, H, hd] (post-RoPE, absolute positions q_offset+i; rows
        may belong to different models)
    pool_k/v: [N, BT, hd] head-block arena
    phys: [B, n_kv, max_blocks] int32 physical head-block ids (invalid
        entries must point at a valid block — e.g. 0 — and be masked
        via the causal positions)
    q_offset: [B] int32 absolute position of each row's first query
    Returns [B, C, H, hd].
    """
    B, C, H, hd = q.shape
    N, BT, _ = pool_k.shape
    n_kv, max_blocks = phys.shape[1], phys.shape[2]
    group = H // n_kv
    rows = C * group
    scale = 1.0 / math.sqrt(hd)

    qt = (q.reshape(B, C, n_kv, group, hd)
           .transpose(0, 2, 1, 3, 4)
           .reshape(B, n_kv, rows, hd))
    kernel = functools.partial(_paged_prefill_kernel, bt=BT,
                               n_blocks=max_blocks, scale=scale,
                               rows=rows, group=group, chunk=C)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_kv, max_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, rows, hd),
                             lambda b, h, j, *refs: (b, h, 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, offs_ref:
                                 (phys_ref[b, h, j], 0, 0)),
                pl.BlockSpec((1, BT, hd),
                             lambda b, h, j, phys_ref, offs_ref:
                                 (phys_ref[b, h, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, hd),
                                   lambda b, h, j, *refs: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_kv, rows, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(phys, q_offset, qt, pool_k, pool_v)
    return (out.reshape(B, n_kv, C, group, hd)
               .transpose(0, 2, 1, 3, 4)
               .reshape(B, C, H, hd))
