"""Jit'd dispatch wrappers over the Pallas kernels.

``backend="pallas"`` targets TPU (or ``interpret=True`` on CPU for
validation); ``backend="xla"`` routes to the pure-jnp reference path —
used by the dry-run lowering (Pallas TPU kernels cannot lower for the
CPU-host placeholder devices) and by the CPU engine.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_prefill as _fp
from repro.kernels import paged_attention as _pa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref

_ON_TPU = jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, window=None, backend: str = "auto",
                    block_q: int = 256, block_k: int = 512):
    if backend == "xla" or (backend == "auto" and not _ON_TPU):
        from repro.models.layers import blocked_causal_attention
        return blocked_causal_attention(q, k, v, window=window)
    interpret = backend == "interpret" or not _ON_TPU
    return _fp.flash_prefill(q, k, v, window=window, block_q=block_q,
                             block_k=block_k, interpret=interpret)


def paged_attention(q, pool_k, pool_v, table, seq_lens, layer, *, n_kv,
                    backend: str = "auto"):
    if backend == "xla" or (backend == "auto" and not _ON_TPU):
        return _ref.paged_decode_ref(q, pool_k, pool_v, table, seq_lens,
                                     layer, n_kv=n_kv)
    interpret = backend == "interpret" or not _ON_TPU
    return _pa.paged_decode_attention(q, pool_k, pool_v, table, seq_lens,
                                      layer, n_kv=n_kv, interpret=interpret)


def ssd(x, dt, a_log, B, C, d_skip, *, chunk=256, backend: str = "auto"):
    if backend == "xla" or (backend == "auto" and not _ON_TPU):
        return _ref.ssd_scan_ref(x, dt, a_log, B, C, d_skip, chunk=chunk)
    interpret = backend == "interpret" or not _ON_TPU
    return _ssd.ssd_scan(x, dt, a_log, B, C, d_skip, chunk=chunk,
                         interpret=interpret)
