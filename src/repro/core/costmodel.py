"""Analytic latency/throughput cost model (roofline-based).

Replaces the paper's profiled latency tables (§3.3: "prefill and
decoding latency ... can be profiled in advance") with a first-
principles roofline model — necessary here because we have no GPU to
profile, and it doubles as the TPU-adaptation layer: the same formulas
with v5e constants drive the TPU placement decisions, with A100
constants they reproduce the paper's setting (Figs. 3, 5, 7–10).

A job holding compute fraction ``f`` (paper: MPS SM percentage; TPU:
submesh share / interleave ratio — DESIGN.md §2) runs at:

    t(job) = max( FLOPs / (f · peak · eff),  bytes / HBM_bw ) + t_coll

i.e. compute scales with the fraction, HBM bandwidth does not (MPS
partitions SMs, not memory channels).  This reproduces Fig. 3: decode
(memory-bound) latency is flat in f until f is tiny, prefill
(compute-bound) scales ≈ 1/f.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.config import ModelConfig


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # bf16 FLOP/s per device
    hbm_bw: float              # bytes/s per device
    hbm_bytes: float           # capacity per device
    link_bw: float             # interconnect bytes/s per device
    mfu: float = 0.55          # achievable fraction of peak in GEMMs
    mbu: float = 0.75          # achievable fraction of HBM bw


A100 = Hardware("a100-80g", 312e12, 2.039e12, 80e9, 600e9 / 8)
TPU_V5E = Hardware("tpu-v5e", 197e12, 819e9, 16 * 1024**3, 50e9)


# ---------------------------------------------------------------------------
# per-step FLOPs / bytes
# ---------------------------------------------------------------------------
def step_flops(cfg: ModelConfig, n_tokens: int, ctx_len: float) -> float:
    """FLOPs for one forward step over n_tokens with average context
    ctx_len (attention term); 2·N_active per token for the GEMMs."""
    gemm = 2.0 * cfg.active_param_count() * n_tokens
    attn = 4.0 * cfg.n_attn_layers * cfg.n_heads * cfg.hd * n_tokens * ctx_len
    return gemm + attn


def decode_bytes(cfg: ModelConfig, batch: int, ctx_len: float,
                 dtype_bytes: int = 2) -> float:
    """HBM traffic of one decode step: weights once + KV of each seq."""
    w = cfg.active_param_count() * dtype_bytes
    kv = batch * ctx_len * cfg.kv_bytes_per_token(dtype_bytes)
    ssm = 0.0
    if cfg.ssm:
        ssm = (batch * cfg.n_ssm_layers * cfg.n_ssm_heads
               * cfg.ssm.head_dim * cfg.ssm.d_state * 4)
    return w + kv + ssm


def prefill_bytes(cfg: ModelConfig, batch: int, seq: int,
                  dtype_bytes: int = 2, block_q: int = 512) -> float:
    w = cfg.param_count() * dtype_bytes
    act = 2.0 * batch * seq * cfg.d_model * cfg.n_layers * dtype_bytes
    # flash attention re-reads K/V once per q-block pass
    flash = 0.0
    if cfg.n_attn_layers and seq > block_q:
        passes = seq / block_q
        flash = passes * batch * seq * 2 * cfg.n_kv_heads * cfg.hd \
            * dtype_bytes * cfg.n_attn_layers
    return w + act + flash


def train_step_bytes(cfg: ModelConfig, batch: int, seq: int,
                     dtype_bytes: int = 2) -> float:
    """HBM traffic of one optimizer step (fwd + bwd with per-layer
    remat + AdamW): weights ×3 reads (fwd, remat, bwd) + grad write/
    read + f32 m/v read+write + param write, plus activation traffic
    and flash K/V re-reads (fwd ×1, remat+bwd ×2)."""
    n = cfg.param_count()
    w_traffic = 3 * n * dtype_bytes          # fwd + remat + bwd reads
    grads = 2 * n * dtype_bytes              # write + read
    opt = n * (4 + 4) * 2 + n * dtype_bytes  # m,v rw (f32) + param write
    act = 12.0 * batch * seq * cfg.d_model * cfg.n_layers * dtype_bytes
    flash = 3 * (prefill_bytes(cfg, batch, seq, dtype_bytes)
                 - cfg.param_count() * dtype_bytes
                 - 2.0 * batch * seq * cfg.d_model * cfg.n_layers
                 * dtype_bytes)
    return w_traffic + grads + opt + act + max(flash, 0.0)


# ---------------------------------------------------------------------------
# latencies under a compute fraction f and TP degree
# ---------------------------------------------------------------------------
def _tp_collective_time(cfg: ModelConfig, n_tokens: int, tp: int,
                        hw: Hardware, dtype_bytes: int = 2) -> float:
    """Per-step all-reduce cost of Megatron TP: 2 all-reduces per layer
    over [n_tokens, d_model], ring cost 2(tp−1)/tp · bytes / link_bw."""
    if tp <= 1:
        return 0.0
    bytes_per_ar = n_tokens * cfg.d_model * dtype_bytes
    ars = 2 * cfg.n_layers
    return ars * 2 * (tp - 1) / tp * bytes_per_ar / hw.link_bw


def prefill_latency(cfg: ModelConfig, batch: int, seq: int, *, tp: int = 1,
                    f: float = 1.0, hw: Hardware = A100) -> float:
    """Latency of one prefill job for `batch` prompts of length `seq`."""
    fl = step_flops(cfg, batch * seq, seq / 2) / tp
    by = prefill_bytes(cfg, batch, seq) / tp
    t = max(fl / (f * hw.peak_flops * hw.mfu), by / (hw.hbm_bw * hw.mbu))
    return t + _tp_collective_time(cfg, batch * seq, tp, hw)


def decode_latency(cfg: ModelConfig, batch: int, ctx: float, *, tp: int = 1,
                   f: float = 1.0, hw: Hardware = A100) -> float:
    """Latency of one decode step for a running batch at avg context ctx."""
    if batch <= 0:
        return 0.0
    fl = step_flops(cfg, batch, ctx) / tp
    by = decode_bytes(cfg, batch, ctx) / tp
    t = max(fl / (f * hw.peak_flops * hw.mfu), by / (hw.hbm_bw * hw.mbu))
    return t + _tp_collective_time(cfg, batch, tp, hw)


def weight_devices_needed(cfg: ModelConfig, hw: Hardware,
                          headroom: float = 0.75) -> int:
    """Minimum TP degree so weights (+ some KV) fit."""
    need = cfg.weight_bytes()
    per_dev = hw.hbm_bytes * headroom
    return max(1, math.ceil(need / per_dev))


def max_kv_tokens(cfg: ModelConfig, tp: int, hw: Hardware,
                  weight_frac_used: float | None = None) -> int:
    """KV-capacity (tokens) of a tp-way group serving only this LLM."""
    total = hw.hbm_bytes * tp * 0.9
    free = total - cfg.weight_bytes()
    if free <= 0:
        return 0
    per_tok = cfg.kv_bytes_per_token()
    if cfg.ssm and per_tok == 0:
        return 10**9  # SSM state is O(1) per seq
    return int(free / per_tok)
