"""Discrete-event cluster simulator for multi-LLM serving.

Reproduces the paper's evaluation (Figs. 5, 7, 8, 9, 10) without GPUs:
job latencies come from the roofline cost model (core/costmodel.py) —
the same substitution the paper itself makes for its estimator ("the
prefill and decoding latency ... can be profiled in advance", §3.3).

Execution model per LLM unit (mesh + colocated LLMs), per round:

  * ``spatial-temporal`` (MuxServe): at most one prefill job runs per
    round (round-robin, prioritized); decode jobs of all colocated LLMs
    run *concurrently* with each other after it (decode-decode
    colocation), each at its placement compute-fraction ``f``:
        t_round = t_prefill + max_m t_decode_m            (Eq. 3 shape)
  * ``temporal`` (AlpaServe-style): jobs serialize, each takes the
    whole mesh (f = 1):
        t_round = t_prefill + Σ_m t_decode_m
  * ``spatial`` partitioning: one LLM per unit, continuous batching:
        t_round = t_prefill + t_decode

Scheduling policies *within* spatial-temporal units (Fig. 9):
  ``adbs``        prefill priority round-robin + KV quota + adaptation
  ``round_robin`` no prefill priority (alternating), fixed quotas
  ``fcfs``        strict arrival order across LLMs, no quotas

Runtime counterpart: ``serving/mux.MuxScheduler`` runs the same three
policy branches over REAL engines, and ``serving/driver.py`` measures
them under the same SLO conventions (DESIGN.md §9) on the same
``core/workload.py`` traces — each policy-bearing method below names
its runtime twin so the two implementations stay auditable against
each other.

KV accounting is in bytes of the unit's unified pool: capacity =
unit HBM − weights − activation reserve; per-LLM quotas bound usage and
ADBS re-allocates quota from low- to high-utilization LLMs periodically
(Alg. 3's ``adapt_quota_periodically``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel as cm
from repro.core.costmodel import A100, Hardware
from repro.core.estimator import LLMSpec
from repro.core.placement import Placement
from repro.core.workload import RequestSpec, Workload


@dataclass
class SimRequest:
    spec: RequestSpec
    prefill_end: float = -1.0
    finish: float = -1.0
    tokens_done: int = 0

    @property
    def latency(self) -> float:
        return self.finish - self.spec.arrival


@dataclass
class LLMState:
    spec: LLMSpec
    waiting: List[SimRequest] = field(default_factory=list)
    running: List[SimRequest] = field(default_factory=list)
    kv_bytes: float = 0.0
    quota: float = 0.0             # KV byte quota (ADBS)
    finished: List[SimRequest] = field(default_factory=list)
    next_arrival_idx: int = 0

    def kv_cost(self, req: SimRequest, extra_tokens: int) -> float:
        per_tok = self.spec.cfg.kv_bytes_per_token()
        if self.spec.cfg.ssm and per_tok == 0:
            return 0.0 if req.tokens_done else self._ssm_bytes()
        return extra_tokens * per_tok

    def _ssm_bytes(self) -> float:
        c = self.spec.cfg
        if not c.ssm:
            return 0.0
        return c.n_ssm_layers * c.n_ssm_heads * c.ssm.head_dim\
            * c.ssm.d_state * 4.0


class UnitSim:
    """One LLM unit: colocated LLMs sharing a mesh + unified KV pool."""

    def __init__(self, specs: Sequence[LLMSpec], n_devices: int,
                 mode: str = "spatial-temporal", policy: str = "adbs",
                 hw: Hardware = A100, max_batch: int = 64,
                 adapt_every: int = 32, activation_frac: float = 0.08,
                 equal_quota: bool = False):
        self.hw = hw
        self.mode = mode
        self.policy = policy
        self.n_devices = n_devices
        self.max_batch = max_batch
        self.adapt_every = adapt_every
        self.llms: Dict[str, LLMState] = {
            s.name: LLMState(spec=s) for s in specs}
        w_bytes = sum(s.cfg.weight_bytes() for s in specs)
        total = hw.hbm_bytes * n_devices
        self.kv_capacity = max(total * (1 - activation_frac) - w_bytes,
                               total * 0.05)
        # initial quota ∝ rate (popular LLMs start with more cache);
        # ``equal_quota`` models static per-LLM partitions (Fig. 10's
        # "no unified memory manager" ablation arm)
        rate_sum = sum(s.rate for s in specs) or 1.0
        for st in self.llms.values():
            if equal_quota:
                st.quota = self.kv_capacity / len(specs)
            elif policy == "fcfs":
                st.quota = self.kv_capacity
            else:
                st.quota = self.kv_capacity * (st.spec.rate / rate_sum)
        self.clock = 0.0
        self._prefill_rr = 0
        self._round = 0
        self._names = [s.name for s in specs]
        self.kv_used = 0.0
        self.busy_time = 0.0

    # ------------------------------------------------------------------
    def load(self, requests: Sequence[RequestSpec]) -> None:
        self._pending = sorted((SimRequest(r) for r in requests
                                if r.model in self.llms),
                               key=lambda r: r.spec.arrival)
        self._pending_idx = 0

    def _admit_arrivals(self) -> None:
        while self._pending_idx < len(self._pending) and\
                self._pending[self._pending_idx].spec.arrival <= self.clock:
            r = self._pending[self._pending_idx]
            self.llms[r.spec.model].waiting.append(r)
            self._pending_idx += 1

    def _next_arrival(self) -> Optional[float]:
        if self._pending_idx < len(self._pending):
            return self._pending[self._pending_idx].spec.arrival
        return None

    def _has_work(self) -> bool:
        return any(st.waiting or st.running for st in self.llms.values())

    # ------------------------------------------------------------------
    def _lifetime_cost(self, st: LLMState, r: SimRequest) -> float:
        """Whole-lifetime KV reservation (Alg. 3's resource_enough also
        gates decode jobs; reserving prompt+output at admission is the
        preemption-free equivalent).  Runtime twin:
        ``Engine.lifetime_blocks`` — same prompt+output+1 rule, in
        head-blocks instead of bytes (plus SSM state pages)."""
        if st.spec.cfg.ssm:
            return st._ssm_bytes() or 1.0
        per_tok = st.spec.cfg.kv_bytes_per_token()
        return (r.spec.prompt_len + r.spec.output_len + 1) * per_tok or 1.0

    def _try_prefill_batch(self, st: LLMState) -> List[SimRequest]:
        """Admit waiting requests of one LLM into a prefill job (quota-
        and pool-capacity-bounded) — Alg. 3's ``resource_enough`` gate
        over Eq. 2's per-LLM cache share.  Runtime twin:
        ``MuxScheduler._pull_batch`` + ``Engine.can_admit``
        (cumulative lifetime check across the batch)."""
        batch: List[SimRequest] = []
        free_pool = self.kv_capacity - self.kv_used
        quota_room = st.quota - st.kv_bytes
        budget = min(free_pool, quota_room)
        slots = self.max_batch - len(st.running)
        while st.waiting and len(batch) < slots:
            r = st.waiting[0]
            cost = self._lifetime_cost(st, r)
            if cost > budget:
                break
            budget -= cost
            st.waiting.pop(0)
            batch.append(r)
        return batch

    def _do_prefill(self, st: LLMState, batch: List[SimRequest],
                    f: float) -> float:
        if not batch:
            return 0.0
        seq = max(r.spec.prompt_len for r in batch)
        t = cm.prefill_latency(st.spec.cfg, len(batch), seq,
                               tp=st.spec.tp, f=f, hw=self.hw)
        for r in batch:
            cost = self._lifetime_cost(st, r)
            st.kv_bytes += cost
            self.kv_used += cost
            r.tokens_done = 1
            r.prefill_end = self.clock + t
            st.running.append(r)
        return t

    def _do_decode(self, st: LLMState, f: float) -> float:
        if not st.running:
            return 0.0
        ctx = float(np.mean([r.spec.prompt_len + r.tokens_done
                             for r in st.running]))
        t = cm.decode_latency(st.spec.cfg, len(st.running), ctx,
                              tp=st.spec.tp, f=f, hw=self.hw)
        return t

    def _finish_decode(self, st: LLMState, end: float) -> None:
        still = []
        for r in st.running:
            r.tokens_done += 1
            if r.tokens_done >= r.spec.output_len:
                r.finish = end
                freed = self._lifetime_cost(st, r)
                st.kv_bytes -= freed
                self.kv_used -= freed
                st.finished.append(r)
            else:
                still.append(r)
        st.running = still

    # ------------------------------------------------------------------
    def _adapt_quotas(self) -> None:
        """Alg. 3's ``adapt_quota_periodically``: move KV quota from
        low- to high-utilization LLMs.  Runtime twin:
        ``UnifiedKVPool.adapt_quotas`` (same low→high move, bounded
        step, min-quota floor), invoked from ``MuxScheduler.tick``
        every ``adapt_every`` ticks."""
        if len(self.llms) < 2:
            return
        util = {}
        demand = {}
        for n, st in self.llms.items():
            util[n] = st.kv_bytes / st.quota if st.quota > 0 else 1.0
            demand[n] = len(st.waiting)
        lo = min(util, key=lambda n: (util[n], demand[n]))
        hi = max(util, key=lambda n: (util[n], demand[n]))
        if util[hi] - util[lo] < 0.2 and demand[hi] == 0:
            return
        st_lo, st_hi = self.llms[lo], self.llms[hi]
        spare = st_lo.quota - st_lo.kv_bytes
        move = min(spare * 0.5, self.kv_capacity * 0.1)
        min_quota = self.kv_capacity * 0.02
        if move > 0 and st_lo.quota - move >= min_quota:
            st_lo.quota -= move
            st_hi.quota += move

    # ------------------------------------------------------------------
    def _round_spatial_temporal(self) -> float:
        """MuxServe round (Eq. 3 shape): prefill jobs of the colocated
        LLMs execute back-to-back (prioritized, round-robin order, each
        at full compute — a prefill job takes the SMs it needs, Fig. 4
        step 1), then decode jobs of all LLMs run concurrently at their
        placement fractions:

            t_round = Σ_i t_p^i + max_m t_d^m

        Policy variants: ``fcfs`` admits prefills in strict global
        arrival order and only when nothing decodes (the Fig. 9
        baseline); ``round_robin`` is the ADBS loop without quota
        adaptation (fixed quotas).

        Runtime twin: ``MuxScheduler.tick`` — same branch structure
        (prefill-priority round-robin, decode fill, periodic quota
        adaptation), but over real engines where "decode jobs run
        concurrently" is realized as the fused multi-LLM sweep
        (DESIGN.md §2) instead of Eq. 3's max over decode times."""
        n = len(self._names)
        t_prefill = 0.0
        if self.policy == "fcfs":
            # strict arrival order: only the globally-oldest waiting
            # request's LLM may prefill, and only if no decode running
            oldest, oname = math.inf, None
            for name, st in self.llms.items():
                if st.waiting and st.waiting[0].spec.arrival < oldest:
                    oldest, oname = st.waiting[0].spec.arrival, name
            any_running = any(st.running for st in self.llms.values())
            if oname is not None and not any_running:
                st = self.llms[oname]
                batch = self._try_prefill_batch(st)
                t_prefill = self._do_prefill(st, batch, 1.0)
        else:
            for i in range(n):
                name = self._names[(self._prefill_rr + i) % n]
                st = self.llms[name]
                if not st.waiting:
                    continue
                batch = self._try_prefill_batch(st)
                if batch:
                    t_prefill += self._do_prefill(st, batch, 1.0)
            self._prefill_rr = (self._prefill_rr + 1) % n
        # concurrent decode jobs (decode-decode colocation)
        t_dec = 0.0
        deced = []
        for st in self.llms.values():
            t = self._do_decode(st, st.spec.sm_frac)
            if t > 0:
                deced.append(st)
                t_dec = max(t_dec, t)
        t_round = t_prefill + t_dec
        end = self.clock + t_round
        for st in deced:
            self._finish_decode(st, end)
        if self.policy == "adbs":
            self._round += 1
            if self._round % self.adapt_every == 0:
                self._adapt_quotas()
        else:
            self._round += 1
        return t_round

    def _round_temporal(self) -> float:
        """AlpaServe-style: serialized jobs, each at f=1.  Runtime
        twin: the ``fcfs`` branch of ``MuxScheduler.tick`` (oldest
        waiting request picks the LLM, prefill+decode batch-wise to
        completion, no quotas)."""
        t_total = 0.0
        # FCFS across LLMs: oldest waiting request picks the prefill
        oldest, oname = math.inf, None
        for name, st in self.llms.items():
            if st.waiting and st.waiting[0].spec.arrival < oldest:
                oldest, oname = st.waiting[0].spec.arrival, name
        if oname is not None:
            st = self.llms[oname]
            batch = self._try_prefill_batch(st)
            t_total += self._do_prefill(st, batch, 1.0)
        deced = []
        for st in self.llms.values():
            t = self._do_decode(st, 1.0)
            if t > 0:
                t_total += t
                deced.append(st)
        end = self.clock + t_total
        for st in deced:
            self._finish_decode(st, end)
        return t_total

    # ------------------------------------------------------------------
    def run(self, horizon: float, max_rounds: int = 2_000_000) -> None:
        rounds = 0
        while rounds < max_rounds:
            self._admit_arrivals()
            if not self._has_work():
                nxt = self._next_arrival()
                if nxt is None:
                    break
                self.clock = nxt
                continue
            if self.mode == "temporal":
                dt = self._round_temporal()
            else:
                dt = self._round_spatial_temporal()
            if dt <= 0:
                # quota-blocked with nothing running: force smallest job
                nxt = self._next_arrival()
                if nxt is not None and nxt > self.clock:
                    self.clock = nxt
                    continue
                dt = 1e-3
            self.clock += dt
            self.busy_time += dt
            rounds += 1

    # ------------------------------------------------------------------
    def results(self) -> List[SimRequest]:
        out = []
        for st in self.llms.values():
            out.extend(st.finished)
        return out


# ---------------------------------------------------------------------------
# cluster-level driver + metrics
# ---------------------------------------------------------------------------
@dataclass
class SimReport:
    throughput: float                      # finished req/s (aggregate)
    rate_weighted_tpt: float               # paper's weighted metric
    slo_attainment: Dict[float, float]     # slo_scale → attainment
    p99_latency: float
    p99_ttft: float
    p99_tpot: float
    finished: int
    submitted: int
    kv_util_by_llm: Dict[str, float] = field(default_factory=dict)
    # per-LLM finished req/s — the runtime's ``LLMReport.throughput``
    # twin, so sim↔runtime throughput ORDERINGS are directly comparable
    # (tests/test_sm_frac.py gates on this for shared placements)
    per_llm_tpt: Dict[str, float] = field(default_factory=dict)

    def summary(self) -> str:
        att = ", ".join(f"{k:g}×:{v:.2%}" for k, v in
                        sorted(self.slo_attainment.items()))
        return (f"tpt={self.throughput:.2f} req/s (weighted "
                f"{self.rate_weighted_tpt:.2f}), SLO[{att}], "
                f"p99 lat={self.p99_latency:.2f}s ttft={self.p99_ttft:.2f}s "
                f"tpot={self.p99_tpot * 1e3:.1f}ms, "
                f"{self.finished}/{self.submitted} finished")


def _slo_reference_latency(spec: LLMSpec, req: RequestSpec,
                           hw: Hardware) -> float:
    """Single-job dedicated-hardware latency (the paper's 'single device
    execution latency', min-TP for models that need >1 device).

    This is the simulator's side of the shared SLO convention
    (DESIGN.md §9: attained iff E2E ≤ scale × reference).  Runtime
    twins: ``serving/driver.calibrate_slo_refs`` (measured solo
    probes) and ``TickCostModel.solo_reference`` (analytic, for the
    deterministic clock)."""
    tp = cm.weight_devices_needed(spec.cfg, hw)
    t_p = cm.prefill_latency(spec.cfg, 1, req.prompt_len, tp=tp, f=1.0,
                             hw=hw)
    ctx = req.prompt_len + req.output_len / 2
    t_d = cm.decode_latency(spec.cfg, 1, ctx, tp=tp, f=1.0, hw=hw)
    return t_p + req.output_len * t_d


def simulate(placement: Placement, workload: Workload, mode: str,
             policy: str = "adbs", hw: Hardware = A100,
             slo_scales: Sequence[float] = (2, 4, 6, 8, 12, 16),
             max_batch: int = 64, equal_quota: bool = False) -> SimReport:
    per_model = workload.per_model()
    units: List[UnitSim] = []
    for mesh in placement.meshes:
        if not mesh.specs:
            continue
        u = UnitSim(mesh.specs, mesh.n_devices, mode=mode, policy=policy,
                    hw=hw, max_batch=max_batch, equal_quota=equal_quota)
        reqs = [r for s in mesh.specs for r in per_model.get(s.name, [])]
        u.load(reqs)
        units.append(u)
    for u in units:
        u.run(workload.horizon)

    spec_of: Dict[str, LLMSpec] = {
        s.name: s for m in placement.meshes for s in m.specs}
    done: List[Tuple[SimRequest, LLMSpec]] = []
    kv_util: Dict[str, float] = {}
    for u in units:
        for name, st in u.llms.items():
            kv_util[name] = st.quota / u.kv_capacity
        for r in u.results():
            done.append((r, spec_of[r.spec.model]))

    horizon = max((r.finish for r, _ in done), default=workload.horizon)
    horizon = max(horizon, workload.horizon)
    tpt = len(done) / horizon

    # rate-weighted average of per-model throughput (paper §4.1)
    per_tpt: Dict[str, float] = {}
    for name in workload.rates:
        n = sum(1 for r, _ in done if r.spec.model == name)
        per_tpt[name] = n / horizon
    rsum = sum(workload.rates.values()) or 1.0
    weighted = sum(workload.rates[m] * per_tpt.get(m, 0.0)
                   for m in workload.rates) / rsum

    att: Dict[float, float] = {}
    lats, ttfts, tpots = [], [], []
    for r, _spec in done:
        lats.append(r.latency)
        ttfts.append(r.prefill_end - r.spec.arrival)
        tpots.append((r.finish - r.prefill_end)
                     / max(r.spec.output_len - 1, 1))
    for scale in slo_scales:
        ok = 0
        for r, spec in done:
            ref = _slo_reference_latency(spec, r.spec, hw)
            if r.latency <= scale * ref:
                ok += 1
        att[scale] = ok / max(len(done), 1)

    def p99(xs):
        return float(np.percentile(xs, 99)) if xs else float("nan")

    return SimReport(
        throughput=tpt, rate_weighted_tpt=weighted, slo_attainment=att,
        p99_latency=p99(lats), p99_ttft=p99(ttfts), p99_tpot=p99(tpots),
        finished=len(done), submitted=len(workload.requests),
        kv_util_by_llm=kv_util, per_llm_tpt=per_tpt)
