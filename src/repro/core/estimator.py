"""Throughput estimator — paper Eq. 3 + batch-size binary search.

    tpt_S(m, b, W) = min( b^m / (Σ_i t_p^i + t_d^m · l_o^m), W_m )

Prefill phases of colocated LLMs serialize; decode phases overlap
(paper Fig. 12).  ``F(unit)`` sums the per-LLM estimates subject to the
token-block fairness constraint (Eq. 2) and is the objective the
placement algorithm (Alg. 1) maximizes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from repro.config import BLOCK_TOKENS, ModelConfig
from repro.core import costmodel as cm
from repro.core.costmodel import Hardware, A100


@dataclass
class LLMSpec:
    """One LLM's serving config inside a unit."""
    cfg: ModelConfig
    rate: float                 # W_m: request arrival rate (req/s)
    mean_prompt: int = 161
    mean_output: int = 338
    tp: int = 1                 # intra-op parallelism degree
    sm_frac: float = 1.0        # compute fraction (MPS share / interleave)
    # base architecture id when it differs from the (unit-unique) name —
    # the placement→runtime bridge resolves configs by it; None means
    # the name itself (minus any ``#i`` colocation tag) is the arch
    arch: Optional[str] = None

    @property
    def name(self) -> str:
        return self.cfg.name

    @property
    def arch_id(self) -> str:
        return self.arch or self.cfg.name.split("#")[0]


def request_throughput(spec: LLMSpec, batch: int, unit_specs: Sequence[LLMSpec],
                       hw: Hardware = A100) -> float:
    """Eq. 3 for LLM m with batch size b^m inside a unit."""
    if batch <= 0:
        return 0.0
    # Σ_i t_p^i: one prefill per LLM in the unit at its own batch/rate share
    t_p_sum = 0.0
    for s in unit_specs:
        bs = max(1, int(round(batch * s.rate / max(spec.rate, 1e-9))))
        bs = min(bs, 64)
        t_p_sum += cm.prefill_latency(s.cfg, 1, s.mean_prompt, tp=s.tp,
                                      f=max(s.sm_frac, 0.05), hw=hw) * bs
    t_d = cm.decode_latency(spec.cfg, batch,
                            spec.mean_prompt + spec.mean_output / 2,
                            tp=spec.tp, f=max(spec.sm_frac, 0.05), hw=hw)
    denom = t_p_sum + t_d * spec.mean_output
    tpt = batch / max(denom, 1e-9)
    return min(tpt, spec.rate)


def solve_batch(spec: LLMSpec, unit_specs: Sequence[LLMSpec],
                hw: Hardware = A100, max_batch: int = 256
                ) -> Tuple[int, float]:
    """Binary search the smallest batch whose Eq.-3 throughput meets the
    arrival rate (paper §3.3); returns (batch, throughput)."""
    lo, hi = 1, max_batch
    best_b, best_t = max_batch, request_throughput(spec, max_batch,
                                                   unit_specs, hw)
    while lo <= hi:
        mid = (lo + hi) // 2
        t = request_throughput(spec, mid, unit_specs, hw)
        if t >= spec.rate - 1e-9:
            best_b, best_t = mid, t
            hi = mid - 1
        else:
            lo = mid + 1
    return best_b, best_t


# ---------------------------------------------------------------------------
# R(m, W): normalized resource usage (token blocks) — Eq. 2 fairness
# ---------------------------------------------------------------------------
def token_block_usage(spec: LLMSpec, batch: int) -> float:
    """Expected head-block usage of LLM m at batch b, normalized by rate
    (paper §3.3: counting token blocks accounts for LLM scale; dividing
    by rate accounts for popularity)."""
    tokens = batch * (spec.mean_prompt + spec.mean_output / 2)
    if spec.cfg.attn_free:
        blocks = batch * max(1, spec.cfg.n_ssm_layers)
    else:
        blocks = (tokens / BLOCK_TOKENS) * spec.cfg.n_attn_layers \
            * spec.cfg.n_kv_heads
    return blocks / max(spec.rate, 1e-9)


def unit_throughput(specs: Sequence[LLMSpec], n_devices: int,
                    hw: Hardware = A100,
                    fairness_eps: float = 3.0) -> float:
    """F(b, W_b): aggregate unit throughput under the fairness constraint.

    Memory feasibility: weights of all colocated LLMs must fit the
    unit's total HBM with KV headroom; infeasible → −inf.
    """
    if not specs:
        return 0.0
    w_bytes = sum(s.cfg.weight_bytes() for s in specs)
    total = hw.hbm_bytes * n_devices
    if w_bytes > 0.85 * total:
        return float("-inf")

    total_tpt = 0.0
    usages = []
    for s in specs:
        b, tpt = solve_batch(s, specs, hw)
        # KV feasibility: batches must fit the remaining memory
        total_tpt += tpt
        usages.append(token_block_usage(s, b))
    # fairness constraint |R_i − R_j| ≤ ε (in normalized log-space)
    if len(usages) > 1:
        lo, hi = min(usages), max(usages)
        if lo > 0 and math.log(hi / max(lo, 1e-12)) > fairness_eps:
            # heavily-imbalanced colocation: penalize rather than forbid
            total_tpt *= 0.8
    return total_tpt
