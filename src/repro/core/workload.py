"""Workload generation (paper §4.1–4.2).

Synthetic workloads: per-LLM request rates from a power-law with
exponent α (larger α → fewer LLMs take more traffic; α=0.9 ≈ 20% of
LLMs get 50% of traffic, α=2.1 ≈ 20% get 90%), arrival times from
Poisson processes, request lengths from a ShareGPT-like distribution
(mean prompt 161 tokens, mean output 338 — paper §2.1).

The model mix follows Table 1: {4–8B: 12, 8–21B: 4, 21–41B: 2,
41–70B: 1} LLaMA-family models.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import BLOCK_TOKENS, ModelConfig

# ---------------------------------------------------------------------------
# LLaMA-family size buckets (paper Table 1)
# ---------------------------------------------------------------------------
_LLAMA_SHAPES = {
    # name: (layers, d_model, heads, kv_heads, d_ff)
    "llama-7b": (32, 4096, 32, 32, 11008),
    "llama-13b": (40, 5120, 40, 40, 13824),
    "llama-30b": (60, 6656, 52, 52, 17920),
    "llama-65b": (80, 8192, 64, 64, 22016),
}

TABLE1_MIX: List[Tuple[str, int]] = [
    ("llama-7b", 12), ("llama-13b", 4), ("llama-30b", 2), ("llama-65b", 1),
]


def llama_config(name: str, tag: str = "") -> ModelConfig:
    l, d, h, kv, f = _LLAMA_SHAPES[name]
    return ModelConfig(
        name=f"{name}{tag}", family="dense", n_layers=l, d_model=d,
        n_heads=h, n_kv_heads=kv, d_ff=f, vocab_size=32000,
        source="arXiv:2302.13971 (LLaMA)")


def table1_models() -> List[ModelConfig]:
    out = []
    for name, count in TABLE1_MIX:
        for i in range(count):
            out.append(llama_config(name, tag=f"-{i}"))
    return out


# ---------------------------------------------------------------------------
# request-level workload
# ---------------------------------------------------------------------------
@dataclass
class RequestSpec:
    model: str
    arrival: float
    prompt_len: int
    output_len: int
    # explicit prompt token content (len == prompt_len), for traces
    # with cross-request structure the consumer must preserve — e.g.
    # shared prefixes (``shared_prefix_trace``).  None → the driver
    # draws tokens itself, exactly as before.
    prompt_tokens: Optional[List[int]] = None
    # which prefix-pool entry this request reuses (−1 = unique prompt)
    prefix_id: int = -1


@dataclass
class Workload:
    """A trace: per-model rates + a flat arrival-ordered request list."""
    rates: Dict[str, float]                     # req/s per model
    requests: List[RequestSpec] = field(default_factory=list)
    horizon: float = 0.0

    @property
    def total_rate(self) -> float:
        return sum(self.rates.values())

    def per_model(self) -> Dict[str, List[RequestSpec]]:
        out: Dict[str, List[RequestSpec]] = {m: [] for m in self.rates}
        for r in self.requests:
            out[r.model].append(r)
        return out


def power_law_rates(models: Sequence[str], alpha: float, max_rate: float,
                    scale_to_avg: Optional[float] = None) -> Dict[str, float]:
    """Rate_i ∝ (i+1)^(−α), scaled so max = max_rate (paper §4.2) or so
    the mean equals ``scale_to_avg`` when given."""
    n = len(models)
    raw = np.array([(i + 1.0) ** (-alpha) for i in range(n)])
    rates = raw / raw.max() * max_rate
    if scale_to_avg is not None:
        rates = rates / rates.mean() * scale_to_avg
    return {m: float(r) for m, r in zip(models, rates)}


def sharegpt_lengths(rng: np.random.Generator, n: int,
                     mean_prompt: int = 161, mean_output: int = 338,
                     max_len: int = 2048
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Lognormal lengths matched to ShareGPT means (σ chosen to mimic
    its heavy tail), clipped to [4, max_len].  The paper-scale defaults
    (161/338, §2.1) feed the simulator; the runtime driver
    (serving/driver.py) passes reduced means so the same distribution
    shape serves CPU-scale engines."""
    def ln(mean, sigma):
        mu = math.log(mean) - sigma ** 2 / 2
        return np.clip(rng.lognormal(mu, sigma, n).astype(int), 4, max_len)
    return ln(mean_prompt, 0.9), ln(mean_output, 0.8)


def poisson_trace(rates: Dict[str, float], horizon: float, seed: int = 0,
                  mean_prompt: int = 161, mean_output: int = 338,
                  max_len: int = 2048) -> Workload:
    """Poisson arrivals per model at EXPLICIT per-model rates.

    The arrival-process core shared by ``synthesize`` (power-law rates)
    and by placement-driven serving, where the rates come from a plan's
    ``LLMSpec``s instead (``serving/driver.units_from_placement`` +
    ``launch/serve.py --placement``)."""
    rng = np.random.default_rng(seed)
    reqs: List[RequestSpec] = []
    for m, rate in rates.items():
        if rate <= 0:
            continue
        n_exp = rng.poisson(rate * horizon)
        times = np.sort(rng.uniform(0, horizon, n_exp))
        pl, ol = sharegpt_lengths(rng, n_exp, mean_prompt, mean_output,
                                  max_len)
        reqs.extend(RequestSpec(m, float(t), int(p), int(o))
                    for t, p, o in zip(times, pl, ol))
    reqs.sort(key=lambda r: r.arrival)
    return Workload(rates=dict(rates), requests=reqs, horizon=horizon)


def piecewise_poisson_trace(segments: Sequence[Tuple[float, Dict[str, float]]],
                            horizon: float, seed: int = 0,
                            mean_prompt: int = 161, mean_output: int = 338,
                            max_len: int = 2048) -> Workload:
    """Regime-shift traces: piecewise-constant per-LLM rate schedules.

    ``segments`` is ``[(t_start, rates), ...]`` sorted ascending with
    ``t_start == 0`` first; segment k spans ``[t_k, t_{k+1})`` (the
    last runs to ``horizon``) and draws Poisson arrivals per LLM at
    that segment's rates.  This is the workload the live
    reconfiguration subsystem exists for (serving/reconfig.py;
    OServe/AlpaServe-style popularity drift — e.g. a popularity flip
    at t=H/2): a static placement solved for segment 0 strands quota
    and mesh capacity once the rates shift.  ``Workload.rates``
    carries the TIME-AVERAGED per-LLM rates, so quota splits and drift
    baselines start from the honest long-run mix.  Deterministic for
    a fixed seed, like every generator here.
    """
    assert segments and segments[0][0] == 0.0, \
        "segments must start at t=0"
    starts = [t for t, _ in segments]
    assert starts == sorted(starts), "segments must be time-sorted"
    assert horizon > starts[-1], "horizon must extend past the last segment"
    rng = np.random.default_rng(seed)
    names = sorted({m for _, rates in segments for m in rates})
    avg = {m: 0.0 for m in names}
    reqs: List[RequestSpec] = []
    for k, (t0, seg_rates) in enumerate(segments):
        t1 = segments[k + 1][0] if k + 1 < len(segments) else horizon
        span = t1 - t0
        for m in names:
            rate = seg_rates.get(m, 0.0)
            avg[m] += rate * span / horizon
            if rate <= 0:
                continue
            n = rng.poisson(rate * span)
            times = np.sort(rng.uniform(t0, t1, n))
            pl, ol = sharegpt_lengths(rng, n, mean_prompt, mean_output,
                                      max_len)
            reqs.extend(RequestSpec(m, float(t), int(p), int(o))
                        for t, p, o in zip(times, pl, ol))
    reqs.sort(key=lambda r: r.arrival)
    return Workload(rates=avg, requests=reqs, horizon=horizon)


def synthesize(models: Sequence[str], alpha: float, max_rate: float,
               horizon: float, seed: int = 0,
               scale_to_avg: Optional[float] = None,
               mean_prompt: int = 161, mean_output: int = 338,
               max_len: int = 2048) -> Workload:
    """Poisson arrivals per model at power-law rates over ``horizon`` s.

    One generator for BOTH consumers: the discrete-event simulator
    (``core/simulator.simulate``) and the real-engine serving driver
    (``serving/driver.serve_workload``) replay the same ``Workload``,
    so runtime SLO numbers are directly comparable to the simulator's
    predictions for the same trace.  ``mean_prompt`` / ``mean_output``
    rescale the ShareGPT-shaped length distribution (the runtime's
    reduced models use shorter sequences; the distribution shape and
    the Poisson/power-law arrival process are unchanged).
    """
    rates = power_law_rates(models, alpha, max_rate, scale_to_avg)
    return poisson_trace(rates, horizon, seed, mean_prompt, mean_output,
                         max_len)


def shared_prefix_trace(rates: Dict[str, float], horizon: float,
                        seed: int = 0, mean_prompt: int = 161,
                        mean_output: int = 338, max_len: int = 2048,
                        n_prefixes: int = 8, prefix_len: int = 48,
                        zipf_a: float = 1.5, reuse: float = 0.9
                        ) -> Workload:
    """Chat/agent-style trace with shared prompt prefixes (DESIGN.md
    §13): each LLM owns a pool of ``n_prefixes`` fixed token prefixes
    (system prompts / few-shot headers); with probability ``reuse`` a
    request opens with a Zipf-popular pool prefix (rank ``zipf_a``)
    followed by unique tokens, otherwise its prompt is entirely
    unique.

    Built on ``poisson_trace``'s arrival/length process with the SAME
    rng consumption at every ``reuse`` level: the reuse coin, Zipf
    rank and a full-length unique draw are consumed for every request
    and the coin merely selects between them.  Two traces differing
    only in ``reuse`` therefore share arrivals, lengths, Zipf ranks
    and suffixes exactly, and raising ``reuse`` only flips unique
    prompts into shared ones — a NESTED sweep, which is what makes the
    monotone-attainment CI gate (benchmarks/prefix_cache.py)
    meaningful rather than noise.

    Tokens are drawn in ``[1, 2^20)``; the driver maps them into each
    model's vocab with a fixed modular map, preserving cross-request
    prefix equality (``serving/driver.requests_from_workload``).
    """
    wl = poisson_trace(rates, horizon, seed, mean_prompt, mean_output,
                       max_len)
    rng = np.random.default_rng(seed + 0x5EED)
    pools = {m: [rng.integers(1, 1 << 20, prefix_len).tolist()
                 for _ in range(n_prefixes)]
             for m in sorted(wl.rates)}
    for spec in wl.requests:
        u = float(rng.uniform())
        j = int(min(rng.zipf(zipf_a), n_prefixes) - 1)
        unique = rng.integers(1, 1 << 20, spec.prompt_len).tolist()
        if u < reuse:
            pl = min(prefix_len, spec.prompt_len)
            spec.prompt_tokens = (pools[spec.model][j][:pl]
                                  + unique[pl:])
            spec.prefix_id = j
        else:
            spec.prompt_tokens = unique
            spec.prefix_id = -1
    return wl


def prefix_repeat_fraction(wl: Workload,
                           block_tokens: int = BLOCK_TOKENS) -> float:
    """Analytic ceiling on the prefix-cache request hit rate of a
    ``shared_prefix_trace``: the fraction of requests that repeat an
    EARLIER request's prefix with at least one adoptable full block.

    Request r (prefix j) is counted iff some earlier request q shares
    prefix j and the common token run ``s = min(prefix coverage of q,
    of r)`` spans ≥ 1 full block the cache could actually hand over —
    the adoption clamp keeps the prompt's last token computed, so r
    also needs ``prompt_len > block_tokens``.  A run that admits every
    request AFTER its prefix donor finished prefill hits exactly this
    fraction; concurrent admissions (donor still prefilling, nothing
    indexed yet) can only lower it, which is why the CI gate checks
    ``measured ≥ factor × bound`` with a documented slack factor, not
    equality."""
    if not wl.requests:
        return 0.0
    # best-coverage donor seen so far per (model, prefix): prefix
    # coverage grows with prompt length (capped at the pool prefix),
    # so the longest prompt is the best donor; the common run with it
    # is measured directly on tokens — no generator parameters needed
    reps: Dict[Tuple[str, int], List[int]] = {}
    hits = 0
    for spec in wl.requests:
        if spec.prefix_id < 0 or spec.prompt_tokens is None:
            continue
        toks = spec.prompt_tokens
        key = (spec.model, spec.prefix_id)
        rep = reps.get(key)
        if rep is not None:
            s = 0
            for a, b in zip(rep, toks):
                if a != b:
                    break
                s += 1
            if (s // block_tokens >= 1
                    and (spec.prompt_len - 1) // block_tokens >= 1):
                hits += 1
        if rep is None or len(toks) > len(rep):
            reps[key] = toks
    return hits / len(wl.requests)


def cumulative_rate_distribution(rates: Dict[str, float]) -> np.ndarray:
    """Fig. 6: cumulative share of traffic of the top-k LLMs."""
    vals = np.sort(np.array(list(rates.values())))[::-1]
    return np.cumsum(vals) / vals.sum()


def chatlmsys_like(n_models: int = 16, horizon: float = 600.0,
                   avg_rate: float = 4.8, seed: int = 0) -> Workload:
    """Real-workload stand-in (§4.3): 16 LLMs where ~20% of the models
    receive ~50% of the traffic (α≈0.9), rates rescaled to ``avg_rate``,
    with mild sinusoidal non-stationarity like the ChatLMSYS trace."""
    rng = np.random.default_rng(seed)
    models = [f"llm-{i}" for i in range(n_models)]
    wl = synthesize(models, alpha=0.9, max_rate=avg_rate * 3,
                    horizon=horizon, seed=seed, scale_to_avg=avg_rate)
    # modulate arrivals with a slow daily-ish wave (thinning)
    kept = []
    for r in wl.requests:
        p = 0.75 + 0.25 * math.sin(2 * math.pi * r.arrival / horizon)
        if rng.uniform() < p:
            kept.append(r)
    wl.requests = kept
    return wl
