"""Placement optimization — paper Alg. 1 + Alg. 2.

``parallel_candidates`` (Alg. 2): per LLM, for each feasible
intra-operator (TP) degree find the *smallest* compute fraction that
meets the LLM's arrival rate — one candidate per TP degree.

``place`` (Alg. 1): enumerate device-mesh groups (partitions of the
cluster into power-of-two meshes, pruned by node size and workload),
greedily place computation-hungry LLMs first onto the mesh with maximal
throughput delta, keep the best group.

``place_memory_greedy``: the Fig.-8 ablation baseline — prioritize by
arrival rate, place on the mesh with most free memory.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.config import ModelConfig
from repro.core import costmodel as cm
from repro.core.costmodel import A100, Hardware
from repro.core.estimator import LLMSpec, solve_batch, unit_throughput

SM_FRACTIONS = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]


@dataclass
class Candidate:
    tp: int
    sm_frac: float
    batch: int
    tpt: float


@dataclass
class Mesh:
    mesh_id: int
    n_devices: int
    specs: List[LLMSpec] = field(default_factory=list)

    def throughput(self, hw: Hardware) -> float:
        t = unit_throughput(self.specs, self.n_devices, hw)
        return 0.0 if not self.specs else t


@dataclass
class Placement:
    meshes: List[Mesh]
    total_tpt: float

    def describe(self) -> str:
        lines = []
        for m in self.meshes:
            names = ", ".join(f"{s.name}(tp={s.tp},f={s.sm_frac:.1f})"
                              for s in m.specs)
            lines.append(f"  mesh[{m.mesh_id}] x{m.n_devices}: {names or '—'}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# plan serialization — the placement → runtime bridge's wire format
# ---------------------------------------------------------------------------
# A plan JSON captures everything the runtime needs to instantiate real
# colocated units from an optimizer output (serving/driver.py builds
# one MuxScheduler per mesh): mesh sizes and, per LLM, its unit-unique
# name, base architecture (``name`` minus any ``#i`` colocation tag),
# arrival rate (quota split ∝ rate, like the simulator's initial
# quotas) and the planned tp / sm_frac.  Model geometry is NOT
# serialized — the loader resolves the architecture by name, so a plan
# stays valid across config edits and the runtime is free to
# substitute REDUCED variants for CPU-scale serving.

def placement_to_json(pl: Placement) -> dict:
    return {
        "total_tpt": pl.total_tpt,
        "meshes": [{
            "mesh_id": m.mesh_id,
            "n_devices": m.n_devices,
            "specs": [{
                "name": s.name,
                "arch": s.arch_id,
                "rate": s.rate,
                "tp": s.tp,
                "sm_frac": s.sm_frac,
                "mean_prompt": s.mean_prompt,
                "mean_output": s.mean_output,
            } for s in m.specs],
        } for m in pl.meshes],
    }


def placement_from_json(data: dict,
                        resolve_cfg: Callable[[str], ModelConfig]
                        ) -> Placement:
    """Rebuild a ``Placement`` from its plan JSON.

    ``resolve_cfg(arch)`` supplies the ``ModelConfig`` for each spec's
    base architecture (e.g. ``repro.configs.get`` for paper-scale
    geometry or ``configs.get_reduced`` for the CPU runtime); the
    config is renamed to the spec's unit-unique ``name`` so colocated
    instances of one architecture stay distinct.
    """
    meshes = []
    for m in data["meshes"]:
        specs = [LLMSpec(replace(resolve_cfg(s["arch"]), name=s["name"]),
                         s["rate"],
                         # optional in hand-written plans (ShareGPT
                         # defaults, matching LLMSpec's)
                         mean_prompt=int(s.get("mean_prompt", 161)),
                         mean_output=int(s.get("mean_output", 338)),
                         tp=int(s["tp"]),
                         sm_frac=float(s["sm_frac"]), arch=s["arch"])
                 for s in m["specs"]]
        meshes.append(Mesh(int(m["mesh_id"]), int(m["n_devices"]), specs))
    return Placement(meshes, float(data["total_tpt"]))


def save_placement(pl: Placement, path: str) -> None:
    with open(path, "w") as f:
        json.dump(placement_to_json(pl), f, indent=1)


def load_placement(path: str,
                   resolve_cfg: Callable[[str], ModelConfig]) -> Placement:
    with open(path) as f:
        return placement_from_json(json.load(f), resolve_cfg)


# ---------------------------------------------------------------------------
# Alg. 2 — parallel candidate generation
# ---------------------------------------------------------------------------
def parallel_candidates(cfg: ModelConfig, rate: float, hw: Hardware = A100,
                        max_tp: int = 8, mean_prompt: int = 161,
                        mean_output: int = 338) -> List[Candidate]:
    cands: List[Candidate] = []
    min_tp = cm.weight_devices_needed(cfg, hw)
    tp = 1
    while tp <= max_tp:
        if tp >= min_tp:
            for f in SM_FRACTIONS:     # sorted ascending: fewest SMs first
                spec = LLMSpec(cfg, rate, mean_prompt, mean_output,
                               tp=tp, sm_frac=f)
                b, tpt = solve_batch(spec, [spec], hw)
                if tpt >= rate - 1e-9:
                    cands.append(Candidate(tp, f, b, tpt))
                    break
            else:
                # even f=1.0 cannot meet the rate: keep the best-effort
                spec = LLMSpec(cfg, rate, mean_prompt, mean_output,
                               tp=tp, sm_frac=1.0)
                b, tpt = solve_batch(spec, [spec], hw)
                cands.append(Candidate(tp, 1.0, b, tpt))
        tp *= 2
    return cands


# ---------------------------------------------------------------------------
# mesh-group enumeration (pruned)
# ---------------------------------------------------------------------------
def mesh_groups(n_devices: int, node_size: int = 8,
                min_mesh: int = 1, limit: int = 512) -> List[Tuple[int, ...]]:
    """Partitions of n_devices into power-of-two meshes ≤ node_size
    (intra-op within a node — paper §3.2 pruning heuristic)."""
    sizes = [s for s in (1, 2, 4, 8, 16, 32) if min_mesh <= s <= node_size]
    sizes = sizes[::-1]
    out: List[Tuple[int, ...]] = []

    def rec(remaining: int, max_size: int, acc: List[int]):
        if len(out) >= limit:
            return
        if remaining == 0:
            out.append(tuple(acc))
            return
        for s in sizes:
            if s <= max_size and s <= remaining:
                acc.append(s)
                rec(remaining - s, s, acc)
                acc.pop()

    rec(n_devices, max(sizes), [])
    return out


def _computation_requirement(cfg: ModelConfig, rate: float) -> float:
    """Sort key of Alg. 1: model scale × popularity."""
    return cfg.active_param_count() * rate


# ---------------------------------------------------------------------------
# Alg. 1 — enumeration-based greedy placement
# ---------------------------------------------------------------------------
def place(models: Sequence[Tuple[ModelConfig, float]], n_devices: int,
          hw: Hardware = A100, node_size: int = 8,
          group_limit: int = 128, mean_prompt: int = 161,
          mean_output: int = 338) -> Placement:
    cands: Dict[str, List[Candidate]] = {
        cfg.name: parallel_candidates(cfg, rate, hw, max_tp=node_size,
                                      mean_prompt=mean_prompt,
                                      mean_output=mean_output)
        for cfg, rate in models}

    # prune mesh groups: a mesh must be able to host the largest model
    min_mesh = max(cm.weight_devices_needed(cfg, hw) for cfg, _ in models)
    groups = mesh_groups(n_devices, node_size, limit=group_limit)
    groups = [g for g in groups if max(g) >= min_mesh]
    order = sorted(models,
                   key=lambda mr: _computation_requirement(*mr), reverse=True)

    best: Optional[Placement] = None
    for g in groups:
        meshes = [Mesh(i, s) for i, s in enumerate(g)]
        feasible = True
        for cfg, rate in order:
            best_mesh, best_delta, best_spec = None, -math.inf, None
            for mesh in meshes:
                cand = _fit_candidate(cands[cfg.name], mesh.n_devices)
                if cand is None:
                    continue
                spec = LLMSpec(cfg, rate, mean_prompt, mean_output,
                               tp=cand.tp, sm_frac=cand.sm_frac)
                before = unit_throughput(mesh.specs, mesh.n_devices, hw)
                after = unit_throughput(mesh.specs + [spec],
                                        mesh.n_devices, hw)
                if not math.isfinite(after):
                    continue
                delta = after - (before if math.isfinite(before) else 0.0)
                if delta > best_delta:
                    best_mesh, best_delta, best_spec = mesh, delta, spec
            if best_mesh is None:
                feasible = False
                break
            best_mesh.specs.append(best_spec)
        if not feasible:
            continue
        tpt = sum(max(m.throughput(hw), 0.0) for m in meshes)
        if best is None or tpt > best.total_tpt:
            best = Placement([Mesh(m.mesh_id, m.n_devices, list(m.specs))
                              for m in meshes], tpt)
    # the dedicated-mesh layout is also a member of the search space
    # (units of one LLM); keep it when colocation does not pay — this
    # matters for near-uniform popularity (small α), where the paper's
    # gains come from elsewhere and forcing colocation only adds
    # prefill serialization
    try:
        spatial = place_spatial(models, n_devices, hw, node_size,
                                mean_prompt, mean_output)
        if best is None or spatial.total_tpt > best.total_tpt:
            best = spatial
    except AssertionError:
        pass
    assert best is not None, "no feasible placement"
    return best


def place_onto_meshes(models: Sequence[Tuple[ModelConfig, float]],
                      mesh_sizes: Sequence[Tuple[int, int]],
                      hw: Hardware = A100, mean_prompt: int = 161,
                      mean_output: int = 338,
                      archs: Optional[Dict[str, str]] = None) -> Placement:
    """Alg. 1's greedy inner loop over a FIXED mesh structure.

    ``place`` enumerates mesh groups because at planning time the
    cluster partition is free; *online* re-placement (the live
    reconfiguration subsystem, ``serving/reconfig.py``) operates on
    physical units that already hold weights and KV, so only the
    LLM → mesh assignment (plus each LLM's tp / sm_frac candidate)
    re-solves — re-partitioning meshes would mean cross-node weight
    reloads.  ``mesh_sizes`` is ``[(mesh_id, n_devices), ...]``;
    ``archs`` optionally maps unit-unique names to base architecture
    ids (propagated onto the specs so the placement → runtime bridge
    keeps resolving configs).  Greedy order and the throughput-delta
    mesh choice are identical to ``place``.
    """
    assert models and mesh_sizes
    archs = archs or {}
    max_mesh = max(n for _, n in mesh_sizes)
    cands = {cfg.name: parallel_candidates(cfg, rate, hw, max_tp=max_mesh,
                                           mean_prompt=mean_prompt,
                                           mean_output=mean_output)
             for cfg, rate in models}
    meshes = [Mesh(mid, n) for mid, n in mesh_sizes]
    order = sorted(models,
                   key=lambda mr: _computation_requirement(*mr), reverse=True)
    for cfg, rate in order:
        best_mesh, best_delta, best_spec = None, -math.inf, None
        for mesh in meshes:
            cand = _fit_candidate(cands[cfg.name], mesh.n_devices)
            if cand is None:
                continue
            spec = LLMSpec(cfg, rate, mean_prompt, mean_output,
                           tp=cand.tp, sm_frac=cand.sm_frac,
                           arch=archs.get(cfg.name))
            before = unit_throughput(mesh.specs, mesh.n_devices, hw)
            after = unit_throughput(mesh.specs + [spec],
                                    mesh.n_devices, hw)
            if not math.isfinite(after):
                continue
            delta = after - (before if math.isfinite(before) else 0.0)
            if delta > best_delta:
                best_mesh, best_delta, best_spec = mesh, delta, spec
        assert best_mesh is not None,\
            f"no mesh can host {cfg.name} at rate {rate}"
        best_mesh.specs.append(best_spec)
    tpt = sum(max(m.throughput(hw), 0.0) for m in meshes)
    return Placement(meshes, tpt)


def _fit_candidate(cands: List[Candidate], mesh_size: int
                   ) -> Optional[Candidate]:
    """Largest-TP candidate that fits the mesh (more TP → lower latency,
    paper §2.2), falling back to smaller TP."""
    fitting = [c for c in cands if c.tp <= mesh_size]
    if not fitting:
        return None
    return max(fitting, key=lambda c: c.tp)


# ---------------------------------------------------------------------------
# Fig.-8 baseline: memory-greedy placement
# ---------------------------------------------------------------------------
def place_memory_greedy(models: Sequence[Tuple[ModelConfig, float]],
                        n_devices: int, hw: Hardware = A100,
                        node_size: int = 8, mean_prompt: int = 161,
                        mean_output: int = 338) -> Placement:
    """Prioritize high-rate LLMs, place each on the mesh with the most
    free memory (the paper's ablation baseline, §4.4)."""
    # fixed balanced group: split into node-size meshes
    g = []
    rem = n_devices
    while rem > 0:
        s = min(node_size, rem)
        g.append(s)
        rem -= s
    meshes = [Mesh(i, s) for i, s in enumerate(g)]
    free = {m.mesh_id: m.n_devices * hw.hbm_bytes for m in meshes}
    order = sorted(models, key=lambda mr: mr[1], reverse=True)  # by rate
    for cfg, rate in order:
        need = cfg.weight_bytes()
        mesh = max(meshes, key=lambda m: free[m.mesh_id])
        tp = min(cm.weight_devices_needed(cfg, hw), mesh.n_devices)
        mesh.specs.append(LLMSpec(cfg, rate, mean_prompt, mean_output,
                                  tp=tp, sm_frac=1.0))
        free[mesh.mesh_id] -= need
    tpt = sum(max(m.throughput(hw), 0.0) for m in meshes)
    return Placement(meshes, tpt)


# ---------------------------------------------------------------------------
# spatial-partitioning baseline: one LLM per dedicated mesh
# ---------------------------------------------------------------------------
def place_spatial(models: Sequence[Tuple[ModelConfig, float]],
                  n_devices: int, hw: Hardware = A100,
                  node_size: int = 8, mean_prompt: int = 161,
                  mean_output: int = 338) -> Placement:
    """Dedicated GPUs per LLM, sized by weight need then rate-weighted
    share of the remainder (the vLLM-per-model baseline, §4.1)."""
    base = {cfg.name: cm.weight_devices_needed(cfg, hw)
            for cfg, _ in models}
    used = sum(base.values())
    assert used <= n_devices, "cluster too small for spatial partitioning"
    spare = n_devices - used
    total_need = sum(rate * cfg.active_param_count()
                     for cfg, rate in models) or 1.0
    extra: Dict[str, int] = {}
    for cfg, rate in models:
        share = rate * cfg.active_param_count() / total_need
        extra[cfg.name] = int(spare * share)
    # distribute leftovers to the highest-rate models
    leftover = spare - sum(extra.values())
    for cfg, _rate in sorted(models, key=lambda mr: mr[1], reverse=True):
        if leftover <= 0:
            break
        extra[cfg.name] += 1
        leftover -= 1
    meshes = []
    for i, (cfg, rate) in enumerate(models):
        n = base[cfg.name] + extra[cfg.name]
        tp = 1
        while tp * 2 <= min(n, node_size):
            tp *= 2
        tp = max(tp, cm.weight_devices_needed(cfg, hw))
        meshes.append(Mesh(i, n, [LLMSpec(cfg, rate, mean_prompt,
                                          mean_output, tp=tp, sm_frac=1.0)]))
    tpt = sum(max(m.throughput(hw), 0.0) for m in meshes)
    return Placement(meshes, tpt)
