"""Input specs + sharding plans for every (arch × input-shape) pair.

``build_lowering(arch, shape, mesh)`` returns everything ``dryrun.py``
needs to ``jax.jit(step).lower(...)``: the step function, abstract
ShapeDtypeStruct arguments (weak-type-correct, no device allocation),
and the matching in_shardings.

Conventions:
  * audio/vlm shapes: ``tokens`` covers ``seq_len − n_prefix`` positions
    and the modality stub supplies ``prefix_emb`` for the rest, so the
    total context is exactly the assigned seq_len (and stays divisible
    by the flash block sizes).
  * decode shapes carry a cache of ``seq_len`` context and process ONE
    token (lens = seq_len, new token at position seq_len−1).
  * long_500k lowers the windowed/SSM decode path; pure full-attention
    archs without a windowed variant are skipped (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.config import ModelConfig, SHAPES
from repro.launch import sharding as shd
from repro.launch import steps
from repro.models.transformer import init_params
from repro.train.optimizer import AdamWConfig, AdamWState, init_state
from repro.train.train_step import make_train_step

BF16 = jnp.bfloat16

# archs that run long_500k and the mechanism they use (DESIGN.md §4)
LONG_CTX_MODE: Dict[str, str] = {
    "mamba2-2.7b": "ssm",
    "zamba2-1.2b": "hybrid-windowed",
    "musicgen-medium": "windowed",
    "qwen2-7b": "windowed",
}

SKIP_LONG = ("full-attention arch without a windowed variant at 500k "
             "context (DESIGN.md §4: long_500k requires sub-quadratic "
             "attention)")


@dataclass
class Lowering:
    arch: str
    shape: str
    kind: str                       # train | prefill | decode
    step_fn: Callable
    args: Tuple                     # ShapeDtypeStructs
    in_specs: Tuple                 # PartitionSpec pytrees (match args)
    donate: Tuple[int, ...] = ()
    cfg: Optional[ModelConfig] = None
    skip: Optional[str] = None      # reason, when not lowered


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(partial(init_params, cfg=cfg, dtype=BF16),
                          jax.random.PRNGKey(0))


def _n_attn_cache_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _prefix(cfg: ModelConfig, batch: int):
    if cfg.frontend_dim:
        return _sds((batch, cfg.n_prefix_tokens, cfg.frontend_dim),
                    jnp.float32)
    return None


# ---------------------------------------------------------------------------
def build_quantized_decode(arch: str, shape_name: str, mesh) -> Lowering:
    """§Perf variant: W8/KV8 decode with model-axis-only weight
    sharding (dense/moe/vlm/audio, full-cache decode shapes)."""
    from repro.serving.quantize import quantize_params
    shape = SHAPES[shape_name]
    logical = configs.get(arch)
    tp = mesh.shape["model"]
    cfg = shd.physical_config(logical, tp)
    assert shape.kind == "decode" and cfg.family in ("dense", "moe",
                                                     "vlm", "audio")
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(mesh, B)
    b0 = bspec[0] if len(bspec) else None
    qshapes = jax.eval_shape(quantize_params, param_shapes(cfg))
    # int8 weights usually fit model-sharded (no FSDP gathers); the
    # 235B MoE still needs the data dim even at int8 (14.7 GiB/chip)
    fsdp = cfg.weight_bytes() / 2 / tp > 10 * 2 ** 30
    pspecs = shd.param_specs(qshapes, fsdp=fsdp)
    hd, KV, La = cfg.hd, cfg.n_kv_heads, cfg.n_layers
    step = steps.make_decode_step_w8kv8(cfg)
    kv_spec = P(None, b0, None, "model", None)
    sc_spec = P(None, b0, None, "model")
    args = (qshapes,
            _sds((La, B, S, KV, hd), jnp.int8),
            _sds((La, B, S, KV, hd), jnp.int8),
            _sds((La, B, S, KV), jnp.float32),
            _sds((La, B, S, KV), jnp.float32),
            _sds((B,), jnp.int32), _sds((B,), jnp.int32))
    ins = (pspecs, kv_spec, kv_spec, sc_spec, sc_spec, P(b0), P(b0))
    return Lowering(arch, shape_name, "decode", step, args, ins,
                    donate=(1, 2, 3, 4), cfg=cfg)


def build_lowering(arch: str, shape_name: str, mesh) -> Lowering:
    shape = SHAPES[shape_name]
    logical = configs.get(arch)
    tp = mesh.shape["model"]
    cfg = shd.physical_config(logical, tp)
    B, S = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(mesh, B)
    b0 = bspec[0] if len(bspec) else None
    # serving shapes drop the FSDP data-dim weight sharding when the
    # model-sharded weights fit comfortably — FSDP at inference means a
    # per-layer weight all-gather every step (§Perf: mamba2 prefill
    # 12.7 GiB/step of gathers removed; a decode step pays it per token)
    fsdp = shape.kind == "train" or \
        cfg.weight_bytes() / tp > 5 * 2 ** 30
    pspecs = shd.param_specs(param_shapes(cfg), fsdp=fsdp)

    if shape.kind == "decode" and shape_name == "long_500k" \
            and arch not in LONG_CTX_MODE:
        return Lowering(arch, shape_name, "decode", None, (), (),
                        cfg=cfg, skip=SKIP_LONG)

    # ---------------- train -------------------------------------------
    if shape.kind == "train":
        opt = AdamWConfig()
        # microbatch the giants so activations fit 16 GiB/chip even
        # under the CPU backend's bf16→f32 normalization inflation;
        # top-k=8 MoE gets a floor of 2 (slot expansion is 8× tokens)
        n_params = cfg.param_count()
        if n_params > 150e9:
            micro = 8      # §Perf: 16→8 cuts per-step weight-gather
            #              traffic 16% at +3 GiB reported temp
        elif n_params > 60e9:
            micro = 8
        elif n_params > 25e9 or (cfg.moe and cfg.moe.top_k >= 8):
            micro = 2
        else:
            micro = 1
        step = make_train_step(cfg, opt, remat=True, microbatches=micro)
        params = param_shapes(cfg)
        opt_state = jax.eval_shape(init_state, params)
        ospecs = AdamWState(step=P(), m=pspecs, v=pspecs)
        n_pre = cfg.n_prefix_tokens if cfg.frontend_dim else 0
        s_tok = S - n_pre
        args = [params, opt_state,
                _sds((B, s_tok), jnp.int32), _sds((B, s_tok), jnp.int32)]
        ins = [pspecs, ospecs, P(b0, None), P(b0, None)]
        if cfg.frontend_dim:
            args.append(_prefix(cfg, B))
            ins.append(P(b0, None, None))
        return Lowering(arch, shape_name, "train", step, tuple(args),
                        tuple(ins), donate=(0, 1), cfg=cfg)

    # ---------------- prefill -----------------------------------------
    if shape.kind == "prefill":
        step = steps.make_prefill_step(cfg)
        params = param_shapes(cfg)
        n_pre = cfg.n_prefix_tokens if cfg.frontend_dim else 0
        s_tok = S - n_pre
        args = [params, _sds((B, s_tok), jnp.int32), _sds((B,), jnp.int32)]
        ins = [pspecs, P(b0, None), P(b0)]
        if cfg.frontend_dim:
            args.append(_prefix(cfg, B))
            ins.append(P(b0, None, None))
        return Lowering(arch, shape_name, "prefill", step, tuple(args),
                        tuple(ins), cfg=cfg)

    # ---------------- decode ------------------------------------------
    windowed = shape_name == "long_500k" and \
        LONG_CTX_MODE.get(arch, "").endswith("windowed")
    fam = cfg.family
    hd, KV = cfg.hd, cfg.n_kv_heads
    La = _n_attn_cache_layers(cfg)
    kv_spec = P(None, b0, None, "model", None)
    w_spec = P(None, b0, "model", None, None)

    if fam in ("dense", "moe", "vlm", "audio"):
        step = steps.make_decode_step(cfg, windowed=windowed)
        params = param_shapes(cfg)
        if windowed:
            W = cfg.sliding_window
            caches = [_sds((La, B, KV, W, hd), BF16),
                      _sds((La, B, KV, W, hd), BF16)]
            cspecs = [w_spec, w_spec]
        else:
            caches = [_sds((La, B, S, KV, hd), BF16),
                      _sds((La, B, S, KV, hd), BF16)]
            cspecs = [kv_spec, kv_spec]
        args = [params, *caches, _sds((B,), jnp.int32), _sds((B,), jnp.int32)]
        ins = [pspecs, *cspecs, P(b0), P(b0)]
        return Lowering(arch, shape_name, "decode", step, tuple(args),
                        tuple(ins), donate=(1, 2), cfg=cfg)

    if fam == "ssm":
        step = steps.make_decode_step(cfg)
        params = param_shapes(cfg)
        sc = cfg.ssm
        conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
        st = _sds((cfg.n_layers, B, cfg.n_ssm_heads, sc.head_dim,
                   sc.d_state), jnp.float32)
        tail = _sds((cfg.n_layers, B, sc.conv_kernel - 1, conv_dim), BF16)
        args = [params, st, tail, _sds((B,), jnp.int32),
                _sds((B,), jnp.int32)]
        ins = [pspecs, shd.ssm_state_spec(mesh, B),
               shd.conv_tail_spec(mesh, B), P(b0), P(b0)]
        return Lowering(arch, shape_name, "decode", step, tuple(args),
                        tuple(ins), donate=(1, 2), cfg=cfg)

    if fam == "hybrid":
        step = steps.make_decode_step(cfg, windowed=windowed)
        params = param_shapes(cfg)
        sc = cfg.ssm
        conv_dim = cfg.d_inner + 2 * sc.n_groups * sc.d_state
        st = _sds((cfg.n_layers, B, cfg.n_ssm_heads, sc.head_dim,
                   sc.d_state), jnp.float32)
        tail = _sds((cfg.n_layers, B, sc.conv_kernel - 1, conv_dim), BF16)
        if windowed:
            W = cfg.sliding_window
            ck = _sds((La, B, KV, W, hd), BF16)
            cspec = w_spec
        else:
            ck = _sds((La, B, S, KV, hd), BF16)
            cspec = kv_spec
        args = [params, st, tail, ck, ck, _sds((B,), jnp.int32),
                _sds((B,), jnp.int32)]
        ins = [pspecs, shd.ssm_state_spec(mesh, B),
               shd.conv_tail_spec(mesh, B), cspec, cspec, P(b0), P(b0)]
        return Lowering(arch, shape_name, "decode", step, tuple(args),
                        tuple(ins), donate=(1, 2, 3, 4), cfg=cfg)

    raise ValueError(fam)


def all_pairs():
    for arch in configs.ARCH_IDS:
        dashed = {v: k for k, v in configs.ALIASES.items()}[arch]
        for shape in SHAPES:
            yield dashed, shape
