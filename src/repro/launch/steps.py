"""Distributed serve-step definitions (the jobs MuxServe schedules).

One definition per (family × phase), lowered both by the 512-device
dry-run (full configs, ShapeDtypeStructs) and by CPU-scale examples
(reduced configs, real arrays).  The layer loop is a ``jax.lax.scan``
over stacked params with the per-layer KV/state cache as scanned xs/ys,
so the HLO stays one-layer-sized regardless of depth.

Phases (paper §2.1):
  * ``prefill``: full causal forward over the prompt, emit KV/state
    caches + last-token logits (compute-bound job).
  * ``decode``: ONE new token against a cache of ``seq_len`` context
    (memory-bound job) — this is what decode_32k / long_500k lower.

Cache layouts:
  dense/moe/vlm/audio : cache_k/v [L, B, S, KV, hd]
  windowed (long_500k): wkey/wval [L, B, KV, W, hd] ring buffers
  ssm                 : state [L, B, H, P, N] f32, conv_tail [L, B, K-1, C]
  hybrid (zamba2)     : ssm caches for all L + attn cache for the
                        n_attn shared-block applications
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import mamba2 as M2
from repro.models import moe as MoE
from repro.models.layers import (attn_qkv, blocked_causal_attention,
                                 causal_attention, lm_logits, mlp, rms_norm)
from repro.serving.cache_ops import windowed_decode_attention, write_window


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _attention_prefill(x, lp, li, cfg, positions, window):
    h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
    q, k, v = attn_qkv(h, lp, li, cfg, positions)
    if x.shape[1] >= 1024:
        o = blocked_causal_attention(q, k, v, window=window)
    else:
        o = causal_attention(q, k, v, window=window)
    b, s, _, _ = o.shape
    return x + o.reshape(b, s, -1) @ lp["wo"][li], k, v


def _decode_attend_dense(q, ck, cv, lens, chunk: int = 2048):
    """q: [B,H,hd]; ck/cv: [B,S,KV,hd]; lens: [B] incl current token.

    Chunked online softmax over the context so the f32 score/prob
    temporaries stay O(chunk) rather than O(S) — at 32k context × 128
    batch the naive version's two [B,KV,G,S] f32 tensors dominate the
    per-device temp memory (measured in the dry-run; see EXPERIMENTS.md
    §Perf)."""
    B, H, hd = q.shape
    S, KV = ck.shape[1], ck.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    ckc = ck.reshape(B, nc, chunk, KV, hd)
    cvc = cv.reshape(B, nc, chunk, KV, hd)

    def body(carry, ci):
        m, l, acc = carry
        k = ckc[:, ci].astype(jnp.float32)               # [B,chunk,KV,hd]
        v = cvc[:, ci].astype(jnp.float32)
        s = jnp.einsum("bkgd,bskd->bkgs", qh, k) * scale
        t = ci * chunk + jnp.arange(chunk)[None, None, None, :]
        s = jnp.where(t < lens[:, None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgs,bskd->bkgd", p, v)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G), jnp.float32)
    a0 = jnp.zeros((B, KV, G, hd), jnp.float32)
    if nc == 1:
        (m, l, acc), _ = body((m0, l0, a0), 0)
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, H, hd).astype(q.dtype)


def _write_dense(ck, cv, k_new, v_new, pos):
    """Insert one token's KV at pos[b].  ck: [B,S,KV,hd]; k_new [B,KV,hd]."""
    b_idx = jnp.arange(ck.shape[0])
    ck = ck.at[b_idx, pos].set(k_new.astype(ck.dtype))
    cv = cv.at[b_idx, pos].set(v_new.astype(cv.dtype))
    return ck, cv


def _attn_decode_token(x, lp, li, cfg, pos):
    """QKV for one token.  x: [B,d] → q/k/v [B,·,hd]."""
    h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
    q, k, v = attn_qkv(h[:, None, :], lp, li, cfg, pos[:, None])
    return q[:, 0], k[:, 0], v[:, 0]


def _ffn_decode(x, lp, li, cfg, dropless):
    h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
    if cfg.family == "moe":
        fn = MoE.moe_ffn_dropless if dropless else MoE.moe_ffn
        out, _ = fn(h[:, None, :], lp, li, cfg)
        return x + out[:, 0]
    return x + mlp(h, lp, li)


def _decode_attend_dense_q(q, ckq, cvq, sk, sv, lens, chunk: int = 2048):
    """Chunked online-softmax decode attention over an int8 KV cache.

    ckq/cvq: [B,S,KV,hd] int8; sk/sv: [B,S,KV] f32 per-token scales."""
    B, H, hd = q.shape
    S, KV = ckq.shape[1], ckq.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd).astype(jnp.float32)
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    ckc = ckq.reshape(B, nc, chunk, KV, hd)
    cvc = cvq.reshape(B, nc, chunk, KV, hd)
    skc = sk.reshape(B, nc, chunk, KV)
    svc = sv.reshape(B, nc, chunk, KV)

    def body(carry, ci):
        m, l, acc = carry
        k = ckc[:, ci].astype(jnp.float32) * skc[:, ci][..., None]
        v = cvc[:, ci].astype(jnp.float32) * svc[:, ci][..., None]
        s = jnp.einsum("bkgd,bskd->bkgs", qh, k) * scale
        t = ci * chunk + jnp.arange(chunk)[None, None, None, :]
        s = jnp.where(t < lens[:, None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgs,bskd->bkgd", p, v)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G), jnp.float32)
    a0 = jnp.zeros((B, KV, G, hd), jnp.float32)
    if nc == 1:
        (m, l, acc), _ = body((m0, l0, a0), 0)
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, H, hd).astype(q.dtype)


def make_decode_step_w8kv8(cfg: ModelConfig, moe_dropless: bool = False):
    """int8-weight + int8-KV decode step (dense/moe/vlm/audio families).

    §Perf beyond-paper variant: storage halves twice over, so the
    weights serve with model-axis-only sharding (no FSDP all-gathers)
    and the KV read per step halves.  Params come from
    ``serving.quantize.quantize_params``; caches carry int8 values plus
    per-(token, head) f32 scales.
    """
    from repro.serving.quantize import (QLayerView, qmatmul, quantize_kv)
    assert cfg.family in ("dense", "moe", "vlm", "audio")

    def decode(qparams, cache_k, cache_v, scale_k, scale_v, last_tok,
               lens):
        tok = qparams["tok"]
        x = (tok["embed_q"][last_tok].astype(jnp.bfloat16)
             * jnp.squeeze(tok["embed_s"]).astype(jnp.bfloat16))
        pos = (lens - 1).astype(jnp.int32)
        b_idx = jnp.arange(x.shape[0])

        def layer(carry, li):
            x, cks, cvs, sks, svs = carry
            lp = QLayerView(qparams["layers"], li)
            q, k, v = _attn_decode_token(x, lp, 0, cfg, pos)
            kq, ks_ = quantize_kv(k)
            vq, vs_ = quantize_kv(v)
            ck = jax.lax.dynamic_index_in_dim(cks, li, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cvs, li, keepdims=False)
            sk = jax.lax.dynamic_index_in_dim(sks, li, keepdims=False)
            sv = jax.lax.dynamic_index_in_dim(svs, li, keepdims=False)
            ck = ck.at[b_idx, pos].set(kq)
            cv = cv.at[b_idx, pos].set(vq)
            sk = sk.at[b_idx, pos].set(ks_)
            sv = sv.at[b_idx, pos].set(vs_)
            o = _decode_attend_dense_q(q, ck, cv, sk, sv, lens)
            x = x + o.reshape(x.shape[0], -1) @ lp["wo"][0]
            x = _ffn_decode(x, lp, 0, cfg, moe_dropless)
            cks = jax.lax.dynamic_update_index_in_dim(cks, ck, li, 0)
            cvs = jax.lax.dynamic_update_index_in_dim(cvs, cv, li, 0)
            sks = jax.lax.dynamic_update_index_in_dim(sks, sk, li, 0)
            svs = jax.lax.dynamic_update_index_in_dim(svs, sv, li, 0)
            return (x, cks, cvs, sks, svs), None

        (x, ck2, cv2, sk2, sv2), _ = jax.lax.scan(
            layer, (x, cache_k, cache_v, scale_k, scale_v),
            jnp.arange(cfg.n_layers))
        h = rms_norm(x, tok["out_norm"], cfg.rms_eps)
        if cfg.tie_embeddings:
            # embed scales are per-d column: fold into h, exact
            hs = (h.astype(jnp.float32)
                  * jnp.squeeze(tok["embed_s"])).astype(jnp.bfloat16)
            logits = hs @ tok["embed_q"].astype(jnp.bfloat16).T
        else:
            logits = qmatmul(h, tok["lm_head_q"], tok["lm_head_s"])
        return {"logits": logits[..., :cfg.vocab_size],
                "cache_k": ck2, "cache_v": cv2,
                "scale_k": sk2, "scale_v": sv2}

    return decode


# ---------------------------------------------------------------------------
# prefill steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, window: Optional[int] = None,
                      moe_dropless: bool = False):
    """Returns prefill(params, tokens, lens[, prefix_emb]) → outputs dict.

    ``moe_dropless``: per-token gathered experts (batch-composition-
    independent outputs — the CPU engine/consistency-test path); default
    is capacity-based dispatch (the distributed path).
    """
    fam = cfg.family

    if fam in ("dense", "moe", "vlm", "audio"):
        def prefill(params, tokens, lens, prefix_emb=None):
            x = params["tok"]["embed"][tokens]
            if prefix_emb is not None:
                x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            lp = params["layers"]

            def layer(x, li):
                x, k, v = _attention_prefill(x, lp, li, cfg, positions,
                                             window)
                h = rms_norm(x, lp["ln2"][li], cfg.rms_eps)
                if fam == "moe":
                    fn = MoE.moe_ffn_dropless if moe_dropless else MoE.moe_ffn
                    out, _ = fn(h, lp, li, cfg)
                    x = x + out
                else:
                    x = x + mlp(h, lp, li)
                return x, (k, v)

            x, (ks, vs) = jax.lax.scan(layer, x, jnp.arange(cfg.n_layers))
            n_pre = 0 if prefix_emb is None else prefix_emb.shape[1]
            idx = jnp.maximum(lens + n_pre - 1, 0)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
            logits = lm_logits(x_last, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "cache_k": ks, "cache_v": vs}
        return prefill

    if fam == "ssm":
        def prefill(params, tokens, lens, prefix_emb=None):
            x = params["tok"]["embed"][tokens]
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            mask = positions < lens[:, None]
            lp = params["layers"]
            from repro.models.layers import constrain, model_axis_size
            sp = model_axis_size()   # sequence-parallel SSD (§Perf)
            # residual stream stays sequence-sharded on the model axis
            # so the slab reshape inside the mixer is a local slice
            x = constrain(x, ("pod", "data"), "model", None)

            def layer(x, li):
                h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
                out, st, tail = M2.mamba2_mixer(h, lp, li, cfg,
                                                return_cache=True,
                                                length_mask=mask,
                                                seq_parallel=sp)
                return constrain(x + out, ("pod", "data"), "model",
                                 None), (st, tail)

            x, (sts, tails) = jax.lax.scan(layer, x, jnp.arange(cfg.n_layers))
            idx = jnp.maximum(lens - 1, 0)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
            logits = lm_logits(x_last, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "ssm_state": sts, "conv_tail": tails}
        return prefill

    if fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        tail_layers = cfg.n_layers - n_groups * cfg.attn_every

        def prefill(params, tokens, lens, prefix_emb=None):
            x = params["tok"]["embed"][tokens]
            B, S, _ = x.shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            mask = positions < lens[:, None]
            lp = params["layers"]
            sa = params["shared_attn"]

            from repro.models.layers import model_axis_size
            sp = model_axis_size()     # sequence-parallel SSD (§Perf)

            def ssm_layer(x, li):
                h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
                out, st, tail = M2.mamba2_mixer(h, lp, li, cfg,
                                                return_cache=True,
                                                length_mask=mask,
                                                seq_parallel=sp)
                return x + out, st, tail

            def group(x, gi):
                sts, tails = [], []
                for j in range(cfg.attn_every):
                    li = gi * cfg.attn_every + j
                    x, st, tail = ssm_layer(x, li)
                    sts.append(st)
                    tails.append(tail)
                x, k, v = _attention_prefill(x, sa, 0, cfg, positions,
                                             window)
                h = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h, sa, 0)
                return x, (jnp.stack(sts), jnp.stack(tails), k, v)

            x, (sts, tails, ks, vs) = jax.lax.scan(group, x,
                                                   jnp.arange(n_groups))
            sts = sts.reshape((-1,) + sts.shape[2:])
            tails = tails.reshape((-1,) + tails.shape[2:])
            for j in range(tail_layers):
                li = n_groups * cfg.attn_every + j
                x, st, tail = ssm_layer(x, li)
                sts = jnp.concatenate([sts, st[None]], 0)
                tails = jnp.concatenate([tails, tail[None]], 0)
            idx = jnp.maximum(lens - 1, 0)
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
            logits = lm_logits(x_last, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "ssm_state": sts, "conv_tail": tails,
                    "cache_k": ks, "cache_v": vs}
        return prefill

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# decode steps — ONE new token with a seq_len context cache
# ---------------------------------------------------------------------------
def make_decode_step(cfg: ModelConfig, windowed: bool = False,
                     moe_dropless: bool = False):
    """Returns decode(params, caches..., last_tok, lens) → outputs dict.

    ``lens`` is the context length INCLUDING the new token (position
    lens−1).  ``windowed=True`` uses ring-buffer sliding-window caches
    of width ``cfg.sliding_window`` (the sub-quadratic long_500k path).
    """
    fam = cfg.family

    if fam in ("dense", "moe", "vlm", "audio") and not windowed:
        def decode(params, cache_k, cache_v, last_tok, lens):
            x = params["tok"]["embed"][last_tok]          # [B, d]
            pos = (lens - 1).astype(jnp.int32)
            lp = params["layers"]

            # the cache rides the scan CARRY (not xs/ys): XLA aliases
            # while-loop carries in place, so the multi-GiB cache is a
            # single buffer (ys-stacking double-buffers it — measured
            # +2× temp on command-r decode_32k, EXPERIMENTS.md §Perf)
            def layer(carry, li):
                x, cks, cvs = carry
                ck = jax.lax.dynamic_index_in_dim(cks, li, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cvs, li, keepdims=False)
                q, k, v = _attn_decode_token(x, lp, li, cfg, pos)
                ck, cv = _write_dense(ck, cv, k, v, pos)
                o = _decode_attend_dense(q, ck, cv, lens)
                x = x + o.reshape(x.shape[0], -1) @ lp["wo"][li]
                x = _ffn_decode(x, lp, li, cfg, moe_dropless)
                cks = jax.lax.dynamic_update_index_in_dim(cks, ck, li, 0)
                cvs = jax.lax.dynamic_update_index_in_dim(cvs, cv, li, 0)
                return (x, cks, cvs), None

            (x, ck2, cv2), _ = jax.lax.scan(
                layer, (x, cache_k, cache_v), jnp.arange(cfg.n_layers))
            logits = lm_logits(x, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "cache_k": ck2, "cache_v": cv2}
        return decode

    if fam in ("dense", "moe", "vlm", "audio") and windowed:
        W = cfg.sliding_window
        assert W, f"{cfg.name} has no sliding_window — long_500k skipped"

        def decode(params, wkey, wval, last_tok, lens):
            x = params["tok"]["embed"][last_tok]
            pos = (lens - 1).astype(jnp.int32)
            lp = params["layers"]

            def layer(x, xs):
                li, wk, wv = xs
                q, k, v = _attn_decode_token(x, lp, li, cfg, pos)
                wk, wv = write_window(wk, wv, k, v, pos)
                o = windowed_decode_attention(q, wk, wv, lens, W)
                x = x + o.reshape(x.shape[0], -1) @ lp["wo"][li]
                x = _ffn_decode(x, lp, li, cfg, moe_dropless)
                return x, (wk, wv)

            x, (wk2, wv2) = jax.lax.scan(
                layer, x, (jnp.arange(cfg.n_layers), wkey, wval))
            logits = lm_logits(x, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "wkey": wk2, "wval": wv2}
        return decode

    if fam == "ssm":
        def decode(params, ssm_state, conv_tail, last_tok, lens):
            x = params["tok"]["embed"][last_tok]
            lp = params["layers"]

            def layer(x, xs):
                li, st, tail = xs
                h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
                out, tail2, st2 = M2.mamba2_decode_step(h, lp, li, cfg,
                                                        tail, st)
                return x + out, (st2, tail2)

            x, (st2, tail2) = jax.lax.scan(
                layer, x, (jnp.arange(cfg.n_layers), ssm_state, conv_tail))
            logits = lm_logits(x, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "ssm_state": st2, "conv_tail": tail2}
        return decode

    if fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        tail_layers = cfg.n_layers - n_groups * cfg.attn_every
        W = cfg.sliding_window if windowed else None

        def decode(params, ssm_state, conv_tail, cache_k, cache_v,
                   last_tok, lens):
            x = params["tok"]["embed"][last_tok]
            pos = (lens - 1).astype(jnp.int32)
            lp = params["layers"]
            sa = params["shared_attn"]

            def ssm_step(x, li, st, tail):
                h = rms_norm(x, lp["ln1"][li], cfg.rms_eps)
                out, tail2, st2 = M2.mamba2_decode_step(h, lp, li, cfg,
                                                        tail, st)
                return x + out, st2, tail2

            def group(x, xs):
                gi, sts, tails, ck, cv = xs
                new_sts, new_tails = [], []
                for j in range(cfg.attn_every):
                    li = gi * cfg.attn_every + j
                    x, st2, tail2 = ssm_step(x, li, sts[j], tails[j])
                    new_sts.append(st2)
                    new_tails.append(tail2)
                q, k, v = _attn_decode_token(x, sa, 0, cfg, pos)
                if windowed:
                    ck, cv = write_window(ck, cv, k, v, pos)
                    o = windowed_decode_attention(q, ck, cv, lens, W)
                else:
                    ck, cv = _write_dense(ck, cv, k, v, pos)
                    o = _decode_attend_dense(q, ck, cv, lens)
                x = x + o.reshape(x.shape[0], -1) @ sa["wo"][0]
                h = rms_norm(x, sa["ln2"][0], cfg.rms_eps)
                x = x + mlp(h, sa, 0)
                return x, (jnp.stack(new_sts), jnp.stack(new_tails), ck, cv)

            g_sts = ssm_state[:n_groups * cfg.attn_every].reshape(
                (n_groups, cfg.attn_every) + ssm_state.shape[1:])
            g_tails = conv_tail[:n_groups * cfg.attn_every].reshape(
                (n_groups, cfg.attn_every) + conv_tail.shape[1:])
            x, (sts2, tails2, ck2, cv2) = jax.lax.scan(
                group, x, (jnp.arange(n_groups), g_sts, g_tails,
                           cache_k, cache_v))
            sts2 = sts2.reshape((-1,) + sts2.shape[2:])
            tails2 = tails2.reshape((-1,) + tails2.shape[2:])
            for j in range(tail_layers):
                li = n_groups * cfg.attn_every + j
                x, st2, tail2 = ssm_step(x, li, ssm_state[li],
                                         conv_tail[li])
                sts2 = jnp.concatenate([sts2, st2[None]], 0)
                tails2 = jnp.concatenate([tails2, tail2[None]], 0)
            logits = lm_logits(x, params["tok"], cfg)
            return {"logits": logits[..., :cfg.vocab_size],
                    "ssm_state": sts2, "conv_tail": tails2,
                    "cache_k": ck2, "cache_v": cv2}
        return decode

    raise ValueError(fam)
