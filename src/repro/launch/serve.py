"""Multi-LLM SLO-attainment serving driver (CPU-scale, real engines).

The end-to-end MuxServe pipeline at laptop scale: colocate the
requested architectures' REDUCED variants on unified KV pools, replay
a popularity-skewed Poisson workload (``core/workload.py`` — the same
generator the simulator uses), and report per-LLM and aggregate
TTFT/TPOT/E2E percentiles, goodput and SLO attainment
(``serving/driver.py``; conventions in DESIGN.md §9).

Units come from one of two sources:

  * ``--archs a,b,...`` — one colocated unit holding every listed
    architecture (repeat an arch, e.g. ``qwen2-7b,qwen2-7b``, to
    colocate independent instances), rates power-law over the list;
  * ``--placement plan.json`` — the placement → runtime bridge: a
    ``core/placement.py`` plan instantiates one real unit per mesh
    (quota split ∝ rate, fused where same-architecture).
    ``--save-placement`` computes a plan for ``--archs`` at the
    workload rates on ``--devices`` devices, writes the JSON, and
    serves from it.

  PYTHONPATH=src python -m repro.launch.serve \
      --archs qwen2-7b,qwen2-7b,mamba2-2.7b --policy adbs --fused \
      --chunk-tokens 16 --alpha 2.1 --rate 2.0 --horizon 8
"""
from __future__ import annotations

import argparse
import json

from repro import configs
from repro.config import replace
from repro.core.estimator import LLMSpec
from repro.core.placement import (Mesh, Placement, load_placement, place,
                                  save_placement)
from repro.core.workload import (poisson_trace, power_law_rates,
                                 shared_prefix_trace)
from repro.serving.driver import (TickCostModel, build_unit_from_specs,
                                  requests_from_workload, serve_workload,
                                  units_from_placement)
from repro.serving.engine import TRACE_COUNTS, unique_tree_bytes
from repro.serving.faults import FaultPlan
from repro.serving.frontend import ServingFrontend, serve_and_collect
from repro.serving.metrics import MetricsServer, ServingMetrics
from repro.serving.mux import SHED_POLICIES
from repro.serving.reconfig import ReconfigController
from repro.serving.router import ROUTER_STRATEGIES


def _unit_names(archs):
    """Unit-unique engine names: repeated archs get a ``#i`` tag."""
    names = []
    for i, a in enumerate(archs):
        names.append(a if archs.count(a) == 1 else f"{a}#{i}")
    return names


def main() -> int:
    ap = argparse.ArgumentParser(
        description="SLO-attainment serving over real colocated engines")
    ap.add_argument("--archs", default="qwen2-7b,mamba2-2.7b",
                    help="comma list of architectures to colocate "
                         "(repeat one to colocate instances)")
    ap.add_argument("--policy", default="adbs",
                    choices=["adbs", "fcfs", "round_robin"])
    ap.add_argument("--alpha", type=float, default=2.1,
                    help="power-law exponent of per-LLM rates (paper "
                         "§4.2; larger = more popularity skew)")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="max per-LLM arrival rate (req/s)")
    ap.add_argument("--horizon", type=float, default=8.0,
                    help="arrival-window length (s)")
    ap.add_argument("--mean-prompt", type=int, default=24,
                    help="mean prompt length (ShareGPT-shaped dist; "
                         "paper scale is 161)")
    ap.add_argument("--mean-output", type=int, default=8,
                    help="mean output length (paper scale is 338)")
    ap.add_argument("--max-new", type=int, default=0,
                    help="hard cap on output tokens (0 = uncapped)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked prefill window (0 = whole-prompt jobs)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share KV blocks across requests with a common "
                         "prompt prefix (copy-on-write; needs "
                         "--chunk-tokens > 0; DESIGN.md §13)")
    ap.add_argument("--prefix-reuse", type=float, default=0.0,
                    help="fraction of requests that open with a popular "
                         "shared prefix (> 0 switches the workload to "
                         "core.workload.shared_prefix_trace; pairs with "
                         "--prefix-cache but works without it as the "
                         "uncached baseline)")
    ap.add_argument("--fused", action="store_true",
                    help="fused multi-LLM tick (one jitted sweep per "
                         "phase for same-architecture engines)")
    ap.add_argument("--slo-scales", default="2,4,6,8,12,16",
                    help="comma list of SLO scale factors")
    ap.add_argument("--deterministic", action="store_true",
                    help="logical tick-cost clock instead of wall time "
                         "(reproducible SLO numbers; DESIGN.md §9)")
    ap.add_argument("--pool-blocks", type=int, default=200_000)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="per-LLM admission-queue bound; arrivals past "
                         "it are shed with backpressure (needs a "
                         "--shed-policy other than 'none'; DESIGN.md §12)")
    ap.add_argument("--shed-policy", default="none",
                    choices=list(SHED_POLICIES),
                    help="graceful-degradation ladder: 'none' (never "
                         "drop), 'reject' (bound the queue), 'deadline' "
                         "(also shed requests whose solo-speed TTFT "
                         "can no longer meet the SLO)")
    ap.add_argument("--shed-scale", type=float, default=None,
                    help="SLO scale the deadline shedder targets "
                         "(default: the largest --slo-scales entry)")
    ap.add_argument("--faults", default=None, metavar="PLAN",
                    help="fault-injection plan: comma list of "
                         "crash:<llm>@<t>, block_loss:<llm>:<blocks>@<t>, "
                         "transient:<llm>:<ticks>@<t>, "
                         "migration_abort@<t> (deterministic chaos; "
                         "DESIGN.md §12)")
    ap.add_argument("--watchdog-ticks", type=int, default=1000,
                    help="busy ticks with zero progress before the "
                         "watchdog sheds all pending work (0 disables)")
    ap.add_argument("--sm-frac", default=None, metavar="SHARES",
                    help="per-LLM compute-share overrides: a comma list "
                         "aligned with --archs (e.g. 0.5,0.3,0.2) or "
                         "name=frac pairs (e.g. qwen2-7b#0=0.5); with "
                         "--placement the overrides patch the plan's "
                         "shares, without it they turn on share "
                         "enforcement for the colocated unit "
                         "(DESIGN.md §11)")
    ap.add_argument("--no-enforce-shares", action="store_true",
                    help="ignore planned sm_frac at runtime (legacy "
                         "temporal accounting: every job is charged as "
                         "if it held the whole mesh — the pure-temporal "
                         "baseline of benchmarks/spatial_mux.py)")
    ap.add_argument("--placement", default=None, metavar="PLAN_JSON",
                    help="build units from a core/placement.py plan")
    ap.add_argument("--save-placement", default=None, metavar="PLAN_JSON",
                    help="optimize a placement for --archs at the "
                         "workload rates, save it, and serve from it")
    ap.add_argument("--devices", type=int, default=8,
                    help="cluster size for --save-placement")
    ap.add_argument("--report", default=None, metavar="OUT_JSON",
                    help="write the full ServeReport JSON here")
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the async streaming front end "
                         "(serving/frontend.py): open-loop ingestion + "
                         "per-request token streams over the same "
                         "scheduling loop as the closed-loop driver")
    ap.add_argument("--router", default=None,
                    choices=list(ROUTER_STRATEGIES),
                    help="cross-LLM routing strategy for --frontend "
                         "(serving/router.py); requests naming a model "
                         "family resolve to a replica at submit time")
    ap.add_argument("--metrics-json", default=None, metavar="OUT_JSON",
                    help="arm the metrics layer (serving/metrics.py) and "
                         "write the final snapshot JSON here")
    ap.add_argument("--port", type=int, default=None,
                    help="arm the metrics layer and expose it over HTTP "
                         "while serving: GET /metrics (Prometheus text), "
                         "/metrics.json, /events (SSE); 0 picks an "
                         "ephemeral port")
    ap.add_argument("--sanitize", action="store_true",
                    help="arm the runtime invariant sanitizer "
                         "(serving/sanitize.py): re-validate pool, "
                         "grant-algebra and request-disposition laws "
                         "after every tick and fail fast on the first "
                         "violation (also: MUXSERVE_SANITIZE=1)")
    ap.add_argument("--reconfig", action="store_true",
                    help="live reconfiguration: watch arrival-rate "
                         "drift, re-solve the placement online and "
                         "migrate engines/KV between units "
                         "(serving/reconfig.py; DESIGN.md §10)")
    ap.add_argument("--reconfig-interval", type=float, default=1.0,
                    help="drift-monitor window length in clock seconds")
    ap.add_argument("--drift-threshold", type=float, default=2.0,
                    help="estimated/planned rate ratio that arms the "
                         "re-plan trigger (sustained for 2 windows)")
    args = ap.parse_args()

    # ---- scalar sanity (a bad flag should die here, not as an
    # assertion three layers down in the allocator) ---------------------
    positive = [("--rate", args.rate), ("--horizon", args.horizon),
                ("--alpha", args.alpha),
                ("--pool-blocks", args.pool_blocks),
                ("--max-slots", args.max_slots),
                ("--mean-prompt", args.mean_prompt),
                ("--mean-output", args.mean_output),
                ("--devices", args.devices),
                ("--reconfig-interval", args.reconfig_interval),
                ("--drift-threshold", args.drift_threshold)]
    for flag, v in positive:
        if v <= 0:
            ap.error(f"{flag} must be > 0 (got {v})")
    nonneg = [("--chunk-tokens", args.chunk_tokens),
              ("--max-new", args.max_new),
              ("--watchdog-ticks", args.watchdog_ticks)]
    for flag, v in nonneg:
        if v < 0:
            ap.error(f"{flag} must be >= 0 (got {v})")
    if args.max_queue is not None and args.max_queue <= 0:
        ap.error(f"--max-queue must be > 0 (got {args.max_queue})")
    if args.max_queue is not None and args.shed_policy == "none":
        ap.error("--max-queue needs --shed-policy reject or deadline "
                 "('none' never drops, so the bound is unenforceable)")
    if args.shed_scale is not None and args.shed_scale <= 0:
        ap.error(f"--shed-scale must be > 0 (got {args.shed_scale})")
    try:
        slo_check = tuple(float(s) for s in args.slo_scales.split(","))
    except ValueError:
        ap.error(f"--slo-scales could not be parsed: {args.slo_scales!r}")
    if any(s <= 0 for s in slo_check):
        ap.error(f"--slo-scales entries must be > 0: {args.slo_scales!r}")

    if not 0.0 <= args.prefix_reuse <= 1.0:
        ap.error(f"--prefix-reuse must be in [0, 1] "
                 f"(got {args.prefix_reuse})")
    if args.prefix_cache and args.chunk_tokens == 0:
        ap.error("--prefix-cache requires --chunk-tokens > 0: a partial "
                 "prefix hit resumes prefill mid-prompt, which only the "
                 "chunked path can do (DESIGN.md §13)")
    if args.placement and args.save_placement:
        ap.error("--placement and --save-placement are mutually "
                 "exclusive (load a plan OR optimize and save one)")
    if args.reconfig and args.policy == "fcfs":
        ap.error("--reconfig needs a multiplexing policy (adbs or "
                 "round_robin); fcfs has no quotas to rebalance")
    if args.reconfig and not args.deterministic:
        # previously rejected; now the driver computes analytic SLO
        # references from a TickCostModel at the owning mesh's current
        # size, so references follow migrated engines (DESIGN.md §14)
        print("[serve] note: --reconfig under the wall clock uses "
              "analytic SLO references (TickCostModel at the owning "
              "mesh's size) instead of startup solo probes")
    if args.router is not None and not args.frontend:
        ap.error("--router needs --frontend (routing happens at the "
                 "front end's submit path)")
    if args.port is not None and args.port < 0:
        ap.error(f"--port must be >= 0 (got {args.port})")
    archs = args.archs.split(",")
    names = _unit_names(archs)
    slo_scales = tuple(float(s) for s in args.slo_scales.split(","))

    # ---- per-LLM compute-share overrides -----------------------------
    sm_overrides = {}
    if args.sm_frac:
        parts = args.sm_frac.split(",")
        try:
            if any("=" in p for p in parts):
                for p in parts:
                    k, eq, v = p.partition("=")
                    if not eq:
                        raise ValueError(p)
                    sm_overrides[k.strip()] = float(v)
            else:
                if len(parts) != len(names):
                    ap.error(f"--sm-frac has {len(parts)} values for "
                             f"{len(names)} archs (use name=frac pairs to "
                             "override a subset)")
                sm_overrides = {n: float(v) for n, v in zip(names, parts)}
        except ValueError:
            ap.error(f"--sm-frac could not be parsed: {args.sm_frac!r} "
                     "(use a comma list of fractions aligned with --archs, "
                     "or name=frac pairs — not a mix)")
        bad = [f"{n}={v}" for n, v in sm_overrides.items()
               if not 0.0 < v <= 1.0]
        if bad:
            ap.error(f"--sm-frac values must be in (0, 1]: {', '.join(bad)}")

    # ---- units: placement bridge or a single colocated unit ----------
    pl = None
    if args.placement:
        pl = load_placement(args.placement, configs.get_reduced)
        print(f"[serve] placement plan {args.placement} "
              f"(est. {pl.total_tpt:.2f} req/s):\n{pl.describe()}")
        rates = {s.name: s.rate for m in pl.meshes for s in m.specs}
    else:
        rates = power_law_rates(names, args.alpha, args.rate)
        if args.save_placement:
            models_rates = []
            for name, arch in zip(names, archs):
                cfg = replace(configs.get(arch), name=name)
                models_rates.append((cfg, rates[name]))
            pl = place(models_rates, n_devices=args.devices,
                       mean_prompt=args.mean_prompt,
                       mean_output=args.mean_output)
            save_placement(pl, args.save_placement)
            print(f"[serve] optimized placement for {args.devices} devices "
                  f"(est. {pl.total_tpt:.2f} req/s) → "
                  f"{args.save_placement}:\n{pl.describe()}")
    if pl is not None:
        plan_names = {s.name for m in pl.meshes for s in m.specs}
        unknown = sorted(set(sm_overrides) - plan_names)
        if unknown:
            ap.error(f"--sm-frac names not in the plan: {unknown} "
                     f"(plan has {sorted(plan_names)})")
        for m in pl.meshes:
            for s in m.specs:
                if s.name in sm_overrides:
                    s.sm_frac = sm_overrides[s.name]
        units = units_from_placement(
            pl, pool_blocks=args.pool_blocks, max_slots=args.max_slots,
            chunk_tokens=args.chunk_tokens, seed=args.seed,
            policy=args.policy, fused=args.fused,
            enforce_shares=not args.no_enforce_shares,
            max_queue=args.max_queue, shed_policy=args.shed_policy,
            prefix_cache=args.prefix_cache)
    else:
        unknown = sorted(set(sm_overrides) - set(names))
        if unknown:
            ap.error(f"--sm-frac names not in --archs: {unknown} "
                     f"(unit names are {names})")
        specs = [(n, a, rates[n]) for n, a in zip(names, archs)]
        # a bare-archs unit enforces shares only when the user supplies
        # them (there is no plan to take shares from)
        sm_fracs = None
        if sm_overrides and not args.no_enforce_shares:
            sm_fracs = {n: sm_overrides.get(n, 1.0) for n in names}
        units = [build_unit_from_specs(
            specs, pool_blocks=args.pool_blocks,
            max_slots=args.max_slots, chunk_tokens=args.chunk_tokens,
            seed=args.seed, policy=args.policy, fused=args.fused,
            sm_fracs=sm_fracs,
            max_queue=args.max_queue, shed_policy=args.shed_policy,
            prefix_cache=args.prefix_cache)]

    # ---- fault-injection plan ----------------------------------------
    fault_plan = None
    if args.faults:
        try:
            fault_plan = FaultPlan.parse(args.faults)
        except ValueError as e:
            ap.error(f"--faults could not be parsed: {e}")
        engine_names = {n for u in units for n in u.engines}
        unknown = sorted(set(fault_plan.targets()) - engine_names)
        if unknown:
            ap.error(f"--faults targets not served here: {unknown} "
                     f"(engines are {sorted(engine_names)})")
        if not args.deterministic:
            print("[serve] note: fault times fire against the wall "
                  "clock; use --deterministic for reproducible chaos")
        if any(e.kind == "migration_abort" for e in fault_plan.events)\
                and not args.reconfig:
            print("[serve] note: migration_abort faults are inert "
                  "without --reconfig")
        print(f"[serve] fault plan armed: {len(fault_plan.events)} "
              f"event(s), shed_policy={args.shed_policy}")

    if args.fused and args.policy == "fcfs":
        # fcfs is the temporal-multiplexing baseline: one LLM at a
        # time, nothing to fuse — the scheduler already ignores it
        print("[serve] --fused has no effect under --policy fcfs")
    for u in units:
        for g in u.fused_groups:
            print(f"[serve] fused group ({len(g.engines)} engines): "
                  f"{[e.cfg.name for e in g.engines]}, "
                  f"{'fused' if g.chunk_tokens else 'serial'} prefill, "
                  f"{g.weight_bytes() / 1e6:.1f} MB shared weights "
                  f"(zero-copy)")
        if u.reclaimed_weight_bytes:
            print(f"[serve] weight de-dup reclaimed "
                  f"{u.reclaimed_weight_bytes / 1e6:.1f} MB → pool grew "
                  f"to {u.pool.n_head_blocks} head-blocks")
        if u.enforce_shares:
            print(f"[serve] unit mesh[{u.mesh_id}] enforces compute "
                  f"shares: "
                  + ", ".join(f"{n}:{f:.2f}"
                              for n, f in u.sm_frac.items()))

    # ---- workload: shared generator with the simulator ---------------
    if args.prefix_reuse > 0.0:
        wl = shared_prefix_trace(rates, args.horizon, seed=args.seed,
                                 mean_prompt=args.mean_prompt,
                                 mean_output=args.mean_output,
                                 reuse=args.prefix_reuse)
    else:
        wl = poisson_trace(rates, args.horizon, seed=args.seed,
                           mean_prompt=args.mean_prompt,
                           mean_output=args.mean_output)
    src = "plan rates" if args.placement else f"α={args.alpha}"
    print(f"[serve] {len(wl.requests)} requests over {args.horizon}s for "
          f"{len(rates)} LLMs ({src}: "
          f"{{{', '.join(f'{n}:{r:.2f}' for n, r in rates.items())}}}), "
          f"policy={args.policy}, fused={args.fused}, "
          f"clock={'logical' if args.deterministic else 'wall'}")

    cost = TickCostModel() if args.deterministic else None
    if cost is None and len(units) > 1:
        print("[serve] note: realtime mode ticks multiple units "
              "sequentially on one host thread — per-mesh latencies "
              "absorb the other meshes' compute; use --deterministic "
              "to model units as parallel hardware")

    # ---- live reconfiguration control plane --------------------------
    ctrl = None
    if args.reconfig:
        if pl is None:
            # single colocated unit: wrap it in a one-mesh placement so
            # the re-planner has a plan to diff against (moves are
            # impossible with one mesh, quota rebalances still apply)
            specs = [LLMSpec(replace(configs.get(a), name=n), rates[n],
                             mean_prompt=args.mean_prompt,
                             mean_output=args.mean_output,
                             tp=1, sm_frac=1.0, arch=a)
                     for n, a in zip(names, archs)]
            pl_ctrl = Placement([Mesh(0, args.devices, specs)],
                                sum(rates.values()))
        else:
            pl_ctrl = pl
        ctrl = ReconfigController(pl_ctrl, units,
                                  interval=args.reconfig_interval,
                                  drift_threshold=args.drift_threshold)
        print(f"[serve] reconfig on: window={args.reconfig_interval}s, "
              f"drift threshold {args.drift_threshold}×, "
              f"{len(ctrl.units)} unit(s)")

    # ---- observability layer -----------------------------------------
    metrics = None
    server = None
    if args.metrics_json or args.port is not None:
        metrics = ServingMetrics()
        if args.port is not None:
            server = MetricsServer(metrics, port=args.port).start()
            print(f"[serve] metrics endpoint live at {server.url}/metrics "
                  f"(also /metrics.json, /events)")

    if args.frontend:
        engines = {}
        for u in units:
            engines.update(u.engines)
        reqs = requests_from_workload(wl, engines, seed=args.seed,
                                      max_new_cap=args.max_new)
        fe = ServingFrontend(units, reqs, strategy=args.router,
                             metrics=metrics,
                             planned_rates=dict(wl.rates),
                             slo_scales=slo_scales, cost=cost,
                             reconfig=ctrl, faults=fault_plan,
                             watchdog_ticks=args.watchdog_ticks,
                             shed_scale=args.shed_scale,
                             sanitize=args.sanitize)
        report, outs = serve_and_collect(fe)
        streamed = sum(len(o) for o in outs.values() if isinstance(o, list))
        errors = sum(1 for o in outs.values() if isinstance(o, Exception))
        print(f"[serve] frontend streamed {streamed} tokens across "
              f"{len(outs)} request streams "
              f"({errors} terminated by shed/cancel)"
              + (f", router={args.router}" if args.router else ""))
    else:
        report = serve_workload(units, wl, seed=args.seed,
                                max_new_cap=args.max_new,
                                slo_scales=slo_scales, cost=cost,
                                reconfig=ctrl, faults=fault_plan,
                                watchdog_ticks=args.watchdog_ticks,
                                shed_scale=args.shed_scale,
                                metrics=metrics,
                                sanitize=args.sanitize)

    # ---- report ------------------------------------------------------
    agg = report.aggregate
    print(f"[serve] finished {agg.finished}/{agg.submitted} over "
          f"{report.ticks} ticks in {report.wall_s:.1f}s wall")
    for line in report.summary().splitlines():
        print(f"[serve] {line}")
    if report.faults is not None:
        for ev in report.faults.log:
            extra = (f", {ev['stalled_ticks']} stalled ticks"
                     if ev["kind"] == "watchdog" else
                     f", target={ev.get('target')}")
            print(f"[serve] fault @{ev['t']:.2f}s {ev['kind']}: "
                  f"{ev.get('requeued', 0)} requeued, "
                  f"{ev.get('shed', 0)} shed, "
                  f"{ev.get('blocks', 0)} blocks{extra}")
    if report.reconfig is not None:
        for ev in report.reconfig.log:
            moves = ", ".join(f"{n}: mesh{src}→mesh{dst}"
                              for n, src, dst in ev["moves"])\
                or "quotas/shares only"
            print(f"[serve] reconfig @{ev['t']:.2f}s "
                  f"(drift {ev['drift']:.1f}×): {moves}; "
                  f"{ev['migrated_blocks']} blocks migrated, "
                  f"{ev['requeued']} prefills requeued, "
                  f"{ev['quota_moved']} quota moved, "
                  f"Σ|Δsm_frac|={ev.get('share_moved', 0.0):.2f}")
    for u in units:
        pool = u.pool
        print(f"[serve] pool: free={pool.allocator.free_blocks}"
              f"/{pool.n_head_blocks} head-blocks, fragmentation="
              f"{pool.allocator.fragmentation():.3f}, shrinkable tail="
              f"{pool.allocator.shrinkable_tail()}")
        for name, view in pool.views.items():
            print(f"[serve]   {name}: quota={view.quota} used={view.used}")
        if args.prefix_cache:
            for name, st in pool.prefix_stats().items():
                print(f"[serve]   {name} prefix cache: "
                      f"{st['hits']}/{st['lookups']} hits "
                      f"({st['hit_rate']:.0%}), {st['hit_tokens']} tokens "
                      f"adopted, {st['entries']} entries holding "
                      f"{st['held_blocks']} head-blocks")
        print(f"[serve] HBM: "
              f"{unique_tree_bytes([e.params for e in u.engines.values()]) / 1e6:.1f}"
              f" MB weights (de-duplicated), {pool.hbm_bytes() / 1e6:.0f} MB "
              f"pool arena")
    print(f"[serve] jit traces by step: {dict(TRACE_COUNTS)} "
          f"(bounded by the shape buckets — DESIGN.md §5)")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report.to_json(), f, indent=1)
        print(f"[serve] report JSON → {args.report}")
    if metrics is not None:
        snap = metrics.snapshot()
        n_series = sum(len(f["series"]) for f in snap["families"])
        print(f"[serve] metrics: {len(snap['families'])} families, "
              f"{n_series} live series, {metrics.log.seq} log records")
        if args.metrics_json:
            with open(args.metrics_json, "w") as f:
                json.dump(snap, f, indent=1)
            print(f"[serve] metrics snapshot JSON → {args.metrics_json}")
    if server is not None:
        server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
