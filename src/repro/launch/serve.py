"""Multi-LLM serving driver (CPU-scale, real engines).

Colocates the requested architectures' REDUCED variants on one unified
KV pool and serves a synthetic Poisson workload with the chosen
scheduling policy — the end-to-end MuxServe pipeline at laptop scale.
``--fused`` runs the fused multi-LLM tick (DESIGN.md §2): one jitted
decode sweep per tick for same-architecture engines (and, with
``--chunk-tokens``, one fused prefill sweep for their in-flight prompt
chunks) off a single zero-copy stacked weight tree per group — the
HBM reclaimed by the de-duplication is granted to the pool as extra
head-blocks.  Repeating an arch (e.g. ``--archs qwen2-7b,qwen2-7b``)
colocates independent instances.

  PYTHONPATH=src python -m repro.launch.serve \
      --archs qwen2-7b,mamba2-2.7b --policy adbs --rate 2.0 \
      --horizon 10 --max-new 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import replace
from repro.models.transformer import init_params
from repro.serving.engine import (TRACE_COUNTS, Engine, Request,
                                  unique_tree_bytes)
from repro.serving.kvcache import UnifiedKVPool
from repro.serving.mux import MuxScheduler


def build_unit(archs: List[str], pool_blocks: int = 400_000,
               max_slots: int = 4, seed: int = 0,
               chunk_tokens: int = 0):
    pool = UnifiedKVPool(pool_blocks, 64, dtype=jnp.float32)
    engines: Dict[str, Engine] = {}
    for i, a in enumerate(archs):
        cfg = configs.get_reduced(a)
        if cfg.name in engines:
            # repeated arch → colocate a distinct instance (own weights,
            # own quota) under a unique engine name
            cfg = replace(cfg, name=f"{cfg.name}#{i}")
        params = init_params(jax.random.PRNGKey(seed + i), cfg,
                             jnp.float32)
        view = pool.register_model(cfg, pool_blocks // len(archs))
        engines[cfg.name] = Engine(cfg, params, view, max_slots=max_slots,
                                   chunk_tokens=chunk_tokens or None)
    return engines, pool


def synth_requests(engines: Dict[str, Engine], rate: float,
                   horizon: float, max_new: int, seed: int = 0
                   ) -> List[Request]:
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = 0
    for name, eng in engines.items():
        n = rng.poisson(rate * horizon)
        times = np.sort(rng.uniform(0, horizon, n))
        for t in times:
            plen = int(rng.integers(4, 24))
            prompt = list(rng.integers(1, eng.cfg.vocab_size, plen))
            reqs.append(Request(rid, name, prompt, max_new, arrival=float(t)))
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen2-7b,mamba2-2.7b")
    ap.add_argument("--policy", default="adbs",
                    choices=["adbs", "fcfs", "round_robin"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--horizon", type=float, default=8.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="chunked prefill window (0 = whole-prompt jobs)")
    ap.add_argument("--fused", action="store_true",
                    help="fused multi-LLM decode tick (one jitted sweep "
                         "for same-architecture engines per tick)")
    args = ap.parse_args()

    archs = args.archs.split(",")
    engines, pool = build_unit(archs, seed=args.seed,
                               chunk_tokens=args.chunk_tokens)
    if args.fused and args.policy == "fcfs":
        # fcfs is the temporal-multiplexing baseline: one LLM at a
        # time, nothing to fuse — don't pretend otherwise
        print("[serve] --fused has no effect under --policy fcfs; "
              "ignoring")
        args.fused = False
    mux = MuxScheduler(engines, pool, policy=args.policy, fused=args.fused)
    reqs = synth_requests(engines, args.rate, args.horizon, args.max_new,
                          args.seed)
    print(f"[serve] {len(reqs)} requests for {len(archs)} colocated LLMs, "
          f"policy={args.policy}, fused={args.fused}")
    if args.fused:
        for g in mux.fused_groups:
            print(f"[serve] fused group ({len(g.engines)} engines): "
                  f"{[e.cfg.name for e in g.engines]}, "
                  f"{'fused' if g.chunk_tokens else 'serial'} prefill, "
                  f"{g.weight_bytes() / 1e6:.1f} MB shared weights "
                  f"(zero-copy)")
        if mux.reclaimed_weight_bytes:
            print(f"[serve] weight de-dup reclaimed "
                  f"{mux.reclaimed_weight_bytes / 1e6:.1f} MB → pool grew "
                  f"to {pool.n_head_blocks} head-blocks")

    t0 = time.perf_counter()
    idx = 0
    while idx < len(reqs) or mux.pending():
        now = time.perf_counter() - t0
        while idx < len(reqs) and reqs[idx].arrival <= now:
            mux.submit(reqs[idx])
            idx += 1
        if mux.pending():
            mux.tick()
        elif idx < len(reqs):
            time.sleep(min(0.01, reqs[idx].arrival - now))
    wall = time.perf_counter() - t0

    st = mux.stats
    lat = [r.finish - (t0 + r.arrival) for r in st.finished if r.finish > 0]
    print(f"[serve] finished {len(st.finished)}/{len(reqs)} in {wall:.1f}s "
          f"→ {len(st.finished) / wall:.2f} req/s, "
          f"{(st.prefill_tokens + st.decode_tokens) / wall:.0f} tok/s")
    if lat:
        print(f"[serve] latency p50={np.percentile(lat, 50):.2f}s "
              f"p99={np.percentile(lat, 99):.2f}s")
    print(f"[serve] pool utilization peak-free={pool.allocator.free_blocks}"
          f"/{pool.n_head_blocks}, fragmentation="
          f"{pool.allocator.fragmentation():.3f}")
    for name, view in pool.views.items():
        print(f"[serve]   {name}: quota={view.quota} used={view.used}")
    print(f"[serve] HBM: "
          f"{unique_tree_bytes([e.params for e in engines.values()]) / 1e6:.1f}"
          f" MB weights (de-duplicated), {pool.hbm_bytes() / 1e6:.0f} MB "
          f"pool arena")
    print(f"[serve] jit traces by step: {dict(TRACE_COUNTS)} "
          f"(bounded by the shape buckets — DESIGN.md §5)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
