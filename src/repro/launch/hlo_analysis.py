"""Trip-count-aware FLOP / byte / collective analysis of optimized HLO.

``compiled.cost_analysis()`` counts a ``while`` body once regardless of
its trip count (measured: a 2-layer and an 8-layer scan report the same
FLOPs), which breaks the roofline for scan-over-layers models.  This
module re-derives the counts from ``compiled.as_text()``:

  * builds a per-computation instruction table (name → dtype/shape/op),
  * resolves ``while`` trip counts from the loop condition's
    ``compare(counter, constant)``,
  * FLOPs: 2·|out|·|contracted| for every dot (incl. inside fusions),
    multiplied through the call tree (fusion × 1, while × trip);
  * bytes: per *top-level* instruction of each computation, operand +
    result bytes (post-fusion HLO ⇒ ≈ one read per operand, one write
    per result), whiles multiplied by trip count;
  * collectives: ring-model traffic per device, × trip count when the
    collective sits in a loop body.

Shapes in post-SPMD HLO are already per-device, so everything here is
per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "s4": 0.5, "s8": 1, "s16": 2, "s32": 4,
    "s64": 8, "u2": 0.25, "u4": 0.5, "u8": 1, "u16": 2, "u32": 4,
    "u64": 8, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "f16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT )?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_NAME = re.compile(r"^\(?[\w\[\],{}\s/*]*?\)?\s*([a-z][\w\-]*)\(")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLS = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class Instr:
    name: str
    rhs: str
    op: str
    shapes: List[Tuple[str, Tuple[int, ...]]]   # result type(s)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def _result_shapes(rhs: str) -> List[Tuple[str, Tuple[int, ...]]]:
    head = rhs.split("(", 1)[0] if "(" in rhs else rhs
    out = []
    for dt, dims in _SHAPE.findall(head):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _operands(rhs: str) -> List[str]:
    # operand list of the first call parens
    m = re.search(r"[a-z][\w\-]*\((.*)$", rhs)
    if not m:
        return []
    args = m.group(1)
    return re.findall(r"%([\w.\-]+)", args.split("),", 1)[0])


def _op_of(rhs: str) -> str:
    # strip result type(s), take the op token before '('
    after = rhs
    # drop leading type annotation(s): e.g. "f32[1,2]{1,0} dot(...)"
    m = re.match(r"^(?:\([^)]*\)|[\w\[\],{}]+)\s+([a-z][\w\-]*)", after)
    if m:
        return m.group(1)
    m = _OP_NAME.search(after)
    return m.group(1) if m else ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        if not s.startswith(" ") and ("->" in s) and s.endswith("{"):
            m = _COMP_HDR.match(s.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
                continue
        if s.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        ins = Instr(name=name, rhs=rhs, op=_op_of(rhs),
                    shapes=_result_shapes(rhs), operands=_operands(rhs))
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    if entry and entry != "__entry__":
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.rhs)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare":
            for o in ins.operands:
                if o in consts:
                    return max(1, consts[o])
    # fall back: any constant in the condition
    return max(1, max(consts.values(), default=1))


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 1
    for _, dims in ins.shapes:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    contracted = 1
    if m and ins.operands:
        lhs = comp.by_name.get(ins.operands[0])
        if lhs and lhs.shapes:
            dims = lhs.shapes[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contracted *= dims[idx]
    return 2.0 * out_elems * contracted


def _called(ins: Instr) -> List[str]:
    out = []
    for m in _CALLS.finditer(ins.rhs):
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    return out


def _called_attrs(ins: Instr) -> Dict[str, List[str]]:
    """Named computation refs: {'body': [...], 'condition': [...], ...}.

    Comma-separated name lists only occur inside braces (e.g.
    ``branch_computations={%a, %b}``); unbraced attrs are single names.
    """
    out: Dict[str, List[str]] = {}
    for m in re.finditer(
            r"(calls|body|condition|to_apply|branch_computations)="
            r"(?:\{([^}]*)\}|%?([\w.\-]+))", ins.rhs):
        names = m.group(2) if m.group(2) is not None else m.group(3)
        out[m.group(1)] = [x.strip().lstrip("%")
                           for x in names.split(",") if x.strip()]
    return out


def _group_size(rhs: str) -> int:
    m = _GROUPS.search(rhs)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS.search(rhs)
    if m:
        return int(m.group(2))
    return 2


def _collective_traffic(ins: Instr) -> Tuple[str, float]:
    kind = next((k for k in COLLECTIVES if ins.op.startswith(k)), None)
    if kind is None:
        return "", 0.0
    r = _nbytes(ins.shapes)
    n = _group_size(ins.rhs)
    if kind == "all-gather":
        t = r * (n - 1) / n
    elif kind == "all-reduce":
        t = 2 * r * (n - 1) / n
    elif kind == "reduce-scatter":
        t = r * (n - 1)
    elif kind == "all-to-all":
        t = r * (n - 1) / n
    else:
        t = r
    return kind, t


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + int(v * mult)


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "call", "after-all",
                   "iota",
                   # defensive whole-buffer copies the CPU backend
                   # inserts around loop-carried aliasing; the TPU
                   # backend aliases these in place (donation), so they
                   # are excluded from the HBM-traffic model
                   "copy", "copy-start", "copy-done"}


def _analyze_comp(comps, name, cache) -> Costs:
    if name in cache:
        return cache[name]
    comp = comps.get(name)
    c = Costs()
    cache[name] = c
    if comp is None:
        return c
    def _operand_bytes(ins: Instr, cap_mult: Optional[float] = None
                       ) -> float:
        total = 0.0
        res = _nbytes(ins.shapes)
        for o in ins.operands:
            ref = comp.by_name.get(o)
            if ref is None:
                continue
            b = _nbytes(ref.shapes)
            if cap_mult is not None:
                # slicing fusions read only a window of big operands;
                # cap each operand's counted traffic at cap_mult× the
                # result (reduction fusions read more than they write,
                # hence a multiple rather than 1×)
                b = min(b, cap_mult * max(res, 1.0))
            total += b
        return total

    for ins in comp.instrs:
        if ins.op == "dot":
            c.flops += _dot_flops(comp, ins)
            c.bytes += _nbytes(ins.shapes) + _operand_bytes(ins)
        elif ins.op in ("dynamic-slice", "slice", "gather"):
            # reads only the slice it produces
            c.bytes += 2 * _nbytes(ins.shapes)
        elif ins.op in ("dynamic-update-slice",):
            # in-place window write: traffic ≈ 2× the update operand
            upd = comp.by_name.get(ins.operands[1]) if \
                len(ins.operands) > 1 else None
            c.bytes += 2 * (_nbytes(upd.shapes) if upd
                            else _nbytes(ins.shapes))
        elif ins.op == "scatter":
            upd = comp.by_name.get(ins.operands[2]) if \
                len(ins.operands) > 2 else None
            c.bytes += 2 * (_nbytes(upd.shapes) if upd
                            else _nbytes(ins.shapes))
        elif ins.op == "while":
            attrs = _called_attrs(ins)
            body = (attrs.get("body") or [None])[0]
            cond = (attrs.get("condition") or [None])[0]
            trips = _trip_count(comps, cond) if cond else 1
            if body:
                c.add(_analyze_comp(comps, body, cache), trips)
        elif ins.op in ("fusion", "call", "conditional", "map",
                        "reduce-window", "reduce", "sort",
                        "custom-call", "select-and-scatter"):
            # flops of nested dots; bytes at this level (fusion reads
            # operands once, writes result once; big operands that are
            # only windowed inside the fusion are capped)
            for sub in _called(ins):
                nested = _analyze_comp(comps, sub, cache)
                c.flops += nested.flops
                c.coll_bytes += nested.coll_bytes
                for k, v in nested.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            c.bytes += _nbytes(ins.shapes) + _operand_bytes(ins,
                                                            cap_mult=32.0)
        elif ins.op in _SKIP_BYTES_OPS:
            continue
        else:
            kind, t = _collective_traffic(ins)
            if kind:
                c.coll_bytes += t
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + t
                c.coll_counts[kind] = c.coll_counts.get(kind, 0) + 1
            c.bytes += _nbytes(ins.shapes) + _operand_bytes(ins)
    return c


def analyze(hlo_text: str) -> Costs:
    """Per-device Costs for the entry computation of an optimized HLO
    module (trip-count-aware)."""
    comps = parse_hlo(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs),
                    default=None)
        if entry is None:
            return Costs()
        comps["__entry__"] = entry
    cache: Dict[str, Costs] = {}
    # avoid self-recursion via the alias
    return _analyze_comp(comps, entry.name, cache)
