"""Training driver (CPU-scale on reduced configs; the same step is
lowered at production scale by dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b \
      --steps 200 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, synth_batch
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs real accelerators)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full\
        else configs.get_reduced(args.arch)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                      total_steps=args.steps)
    params = init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    state = init_state(params)
    start = 0
    if args.ckpt and ckpt_lib.latest_step(args.ckpt) is not None:
        tree, start, _ = ckpt_lib.restore(args.ckpt,
                                          {"p": params, "o": state})
        params, state = tree["p"], tree["o"]
        print(f"[train] resumed from step {start}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      frontend_dim=cfg.frontend_dim,
                      n_prefix_tokens=cfg.n_prefix_tokens)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False,
                                      microbatches=args.microbatches))

    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} × seq {args.seq}")
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        toks, labels, prefix = synth_batch(dcfg, i)
        a = [params, state, jnp.asarray(toks), jnp.asarray(labels)]
        if prefix is not None:
            a.append(jnp.asarray(prefix))
        params, state, m = step_fn(*a)
        if (i + 1) % args.log_every == 0 or i == start:
            tps = args.batch * args.seq * (i + 1 - start)\
                / (time.perf_counter() - t0)
            print(f"[train] step {i + 1:5d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}  "
                  f"gnorm={float(m['grad_norm']):.2f}  tok/s={tps:.0f}")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            path = ckpt_lib.save(args.ckpt, {"p": params, "o": state},
                                 step=i + 1)
            print(f"[train] checkpoint → {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
