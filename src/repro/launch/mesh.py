"""Production mesh construction (TPU v5e target).

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Trivial 1-device mesh for CPU-scale examples/tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_ways(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def tp_ways(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
