"""PartitionSpec rules for every architecture family.

Weight layout (GSPMD / pjit):
  * tensor-parallel dims (heads, d_ff, experts, vocab) on ``model``;
  * the d_model dim of matrices additionally on ``data`` (FSDP-style —
    weights are gathered per layer inside the scan; for a 104B model
    this is what makes 16 GiB/chip feasible);
  * replicated across ``pod`` (data parallelism over DCN).

Attention-head geometry is padded first (``physical_config``) so the
head dims divide the ``model`` axis exactly: kv heads are replicated
``tp/gcd(kv,tp)``× and q heads padded to a multiple.  The padding is
real compute/memory waste, surfaced in the roofline useful-FLOPs ratio.

Optimizer state (AdamW m/v) shards exactly like its parameter.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, tp_geometry

Pytree = Any


def physical_config(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad head counts so attention shards exactly ``tp`` ways."""
    if cfg.family == "ssm" or cfg.n_heads == 0:
        return cfg
    hd = cfg.hd
    g = tp_geometry(cfg.n_heads, cfg.n_kv_heads, tp)
    if g.h_padded == cfg.n_heads and g.kv_padded == cfg.n_kv_heads:
        return cfg
    return dataclasses.replace(cfg, n_heads=g.h_padded,
                               n_kv_heads=g.kv_padded, head_dim=hd)


# ---------------------------------------------------------------------------
# per-leaf rules
# ---------------------------------------------------------------------------
def _leaf_spec(path: str, leaf, *, fsdp: bool) -> P:
    """PartitionSpec for one param leaf, keyed by name + rank."""
    d = "data" if fsdp else None
    name = path.split("/")[-1]
    nd = leaf.ndim

    # quantized serving tree (serving/quantize.py): int8 weights keep
    # the base weight's spec; scales have singleton middle dims
    if name.endswith("_q"):
        name = name[:-2]
        if name == "embed":
            return P("model", d)
        if name == "lm_head":
            return P(d, "model")
    elif name.endswith("_s"):
        base = _leaf_spec(path[:-2], leaf, fsdp=fsdp)
        return P(*[a if leaf.shape[i] > 1 else None
                   for i, a in enumerate(base)])

    if name == "embed":                       # [V, d]
        return P("model", d)
    if name == "lm_head":                     # [d, V]
        return P(d, "model")
    if name in ("out_norm",):
        return P(None)

    if name in ("wq", "wk", "wv"):            # [L, d, heads*hd]
        return P(None, d, "model")
    if name == "wo":                          # [L, heads*hd, d]
        return P(None, "model", d)
    if name in ("bq", "bk", "bv"):            # [L, heads*hd]
        return P(None, "model")
    if name in ("q_norm", "k_norm"):          # [L, hd]
        return P(None, None)

    if name in ("w_gate", "w_up"):
        if nd == 4:                           # MoE [L, E, d, fe]
            return P(None, "model", d, None)
        return P(None, d, "model")            # dense [L, d, f]
    if name == "w_down":
        if nd == 4:                           # MoE [L, E, fe, d]
            return P(None, "model", None, d)
        return P(None, "model", d)            # dense [L, f, d]
    if name == "router":                      # [L, d, E]
        return P(None, d, None)

    # --- Mamba2: SSD runs head-parallel on the model axis (§Perf);
    # out_proj rows follow the head-sharded d_inner, in_proj's output
    # dim stays unsharded (mixed z/x/B/C/dt segments)
    if name == "in_proj":                     # [L, d, d_in_proj]
        return P(None, d, None)
    if name == "out_proj":                    # [L, di, d]
        return P(None, "model", d)
    if name in ("conv_w", "conv_b", "a_log", "dt_bias", "d_skip", "gnorm"):
        return P(*([None] * nd))

    if name in ("ln1", "ln2"):                # [L, d]
        return P(None, None)
    # fallback: replicate
    return P(*([None] * nd))


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def param_specs(params_shape: Pytree, *, fsdp: bool = True,
                attn_tp: bool = True) -> Pytree:
    """PartitionSpec pytree matching a params (shape) pytree.

    ``attn_tp=False`` drops the model axis from attention weights
    (data-parallel attention for MoE-EP layouts — §Perf)."""
    attn_names = {"wq", "wk", "wv", "wo", "bq", "bk", "bv"}
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for p, l in flat:
        spec = _leaf_spec(_path_str(p), l, fsdp=fsdp)
        name = _path_str(p).split("/")[-1]
        if name.endswith(("_q", "_s")):
            name = name[:-2]
        if not attn_tp and name in attn_names:
            spec = P(*[a if a != "model" else None for a in spec])
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(pspecs: Pytree, opt_state_shape) -> Any:
    """AdamWState specs: step replicated, m/v like params."""
    from repro.train.optimizer import AdamWState
    return AdamWState(step=P(), m=pspecs, v=jax.tree.map(lambda s: s,
                                                         pspecs))


def named(mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation / cache specs
# ---------------------------------------------------------------------------
def batch_spec(mesh, batch: int) -> P:
    """Shard the batch dim over (pod, data) when divisible (long_500k's
    batch=1 stays replicated — the data axis is idle, which the
    roofline table reports honestly)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    ways = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % ways == 0:
        return P(tuple(axes))
    return P(None)


def token_specs(mesh, batch: int):
    return P(*batch_spec(mesh, batch)), None


def kv_cache_spec(mesh, batch: int) -> P:
    """[L, B, S, KV, hd]: batch over (pod,data), kv heads over model."""
    b = batch_spec(mesh, batch)
    return P(None, b[0] if len(b) else None, None, "model", None)


def ssm_state_spec(mesh, batch: int) -> P:
    """[L, B, H, P, N]: batch over (pod,data), heads over model."""
    b = batch_spec(mesh, batch)
    return P(None, b[0] if len(b) else None, "model", None, None)


def conv_tail_spec(mesh, batch: int) -> P:
    """[L, B, K-1, conv_dim]: batch over (pod,data)."""
    b = batch_spec(mesh, batch)
    return P(None, b[0] if len(b) else None, None, None)
